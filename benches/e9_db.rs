//! E9 — §3.4/§7 memory-centric database: put/fetch latency across value
//! sizes, replication fan-out cost, TTL purge throughput, and the
//! read-one-retry-next availability path.

use onepiece::bench;
use onepiece::db::{DbClient, MemDb};
use onepiece::util::{NodeId, SystemClock, Uid};
use std::sync::Arc;

fn main() {
    let clock = Arc::new(SystemClock);
    let mut report = bench::Report::new("e9_db");

    bench::header("E9a: put + fetch-purge per result");
    for size in [1 << 10, 64 << 10, 1 << 20, 16 << 20] {
        let db = MemDb::new(clock.clone(), u64::MAX);
        let data = vec![5u8; size];
        let r = bench::quick(&format!("value {:>6} KiB", size / 1024), || {
            let uid = Uid::fresh(NodeId(1));
            db.put(uid, data.clone());
            assert!(db.fetch(uid).is_some());
        });
        report.add_result(&format!("put_fetch_{}kib", size / 1024), &r);
    }

    bench::header("E9b: replication fan-out (put to N replicas)");
    for replicas in [1usize, 2, 3] {
        let dbs: Vec<Arc<MemDb>> = (0..replicas)
            .map(|_| Arc::new(MemDb::new(clock.clone(), u64::MAX)))
            .collect();
        let data = vec![7u8; 256 << 10];
        let r = bench::quick(&format!("replicas={replicas} value=256KiB"), || {
            let uid = Uid::fresh(NodeId(1));
            for db in &dbs {
                db.put(uid, data.clone());
            }
            // One fetch purges the primary; peers expire by TTL.
            assert!(dbs[0].fetch(uid).is_some());
        });
        report.add_result(&format!("replicated_put_r{replicas}"), &r);
    }

    bench::header("E9c: client fall-through on replica failure");
    {
        let dbs: Vec<Arc<MemDb>> = (0..3)
            .map(|_| Arc::new(MemDb::new(clock.clone(), u64::MAX)))
            .collect();
        let client = DbClient::new(dbs.clone());
        client.set_alive(0, false); // dead primary
        let r = bench::quick("fetch with dead primary (2 hops)", || {
            let uid = Uid::fresh(NodeId(1));
            dbs[1].put(uid, vec![1u8; 1024]);
            assert!(client.fetch(uid).is_some());
        });
        report.add_result("fetch_dead_primary", &r);
    }

    bench::header("E9d: TTL purge sweep");
    {
        use onepiece::util::ManualClock;
        let mclock = ManualClock::new();
        let db = MemDb::new(Arc::new(mclock.clone()), 1_000);
        let r = bench::quick("purge 10k expired entries", || {
            for i in 0..10_000u32 {
                db.put(Uid(i as u128), vec![0u8; 64]);
            }
            mclock.advance(10_000);
            assert_eq!(db.purge_expired(), 10_000);
        });
        report.add_result("ttl_purge_10k", &r);
    }
    report.write();
}
