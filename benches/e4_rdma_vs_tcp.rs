//! E5 — §6 transport comparison: one-sided RDMA (modelled InfiniBand
//! latency through the ring buffer) vs kernel TCP (real loopback sockets,
//! measured) vs the NCCL stub (restrictions demonstrated, not raced),
//! across payload sizes 4 KB – 16 MB.
//!
//! Two views are printed:
//!  1. *modelled* fabric time per message for both latency models —
//!     apples-to-apples against the paper's hardware claims;
//!  2. *measured wall time* of the full software path (ring-buffer
//!     protocol vs socket write/read) on this host — the CPU-overhead
//!     argument (§2.1: TCP burns CPU on copies and syscalls).

use onepiece::bench;
use onepiece::metrics::Registry;
use onepiece::rdma::{Fabric, FabricConfig, LatencyModel, WaitMode};
use onepiece::ringbuf::RingConfig;
use onepiece::transport::{
    AppId, MessageHeader, NcclStub, Payload, RdmaEndpoint, RingMetrics, StageId,
    TcpEndpoint, WorkflowMessage,
};
use onepiece::util::{NodeId, Uid};
use std::time::Duration;

/// Modelled host memcpy cost per critical-path copied byte (see the
/// E15b twin of this sweep for the accounting argument).
const MEMCPY_NS_PER_BYTE: f64 = 0.25;

fn msg(bytes: usize) -> WorkflowMessage {
    WorkflowMessage {
        header: MessageHeader {
            uid: Uid(1),
            ts_ns: 0,
            app: AppId(1),
            stage: StageId(0),
            origin: NodeId(0),
        },
        payload: Payload::Bytes(vec![0xAB; bytes]),
    }
}

fn main() {
    let sizes = [4 << 10, 64 << 10, 1 << 20, 16 << 20];
    let mut report = onepiece::bench::Report::new("e4_rdma_vs_tcp");

    println!("=== E5a: modelled one-way transfer time (latency model only) ===");
    println!(
        "{:<12} {:>14} {:>14} {:>8}",
        "payload", "RDMA(100G IB)", "TCP(kernel)", "ratio"
    );
    let rdma = LatencyModel::infiniband_100g();
    let tcp = LatencyModel::tcp_datacenter();
    for &s in &sizes {
        let r = rdma.duration_ns(s) as f64;
        let t = tcp.duration_ns(s) as f64;
        println!(
            "{:<12} {:>11.1} µs {:>11.1} µs {:>7.1}x",
            format!("{} KiB", s / 1024),
            r / 1e3,
            t / 1e3,
            t / r
        );
        report.add(format!("modelled_tcp_over_rdma_{}kib", s / 1024), t / r);
    }

    println!("\n=== E5b: measured software-path time per message (this host) ===");
    println!("(ring-buffer one-sided protocol vs loopback socket round trip)");
    bench::header("send+recv, per message");
    for &s in &sizes {
        let m = msg(s);

        // RDMA path: ring buffer with no modelled latency => pure
        // software/protocol cost (what the remote CPU would NOT spend).
        let fabric = Fabric::new(FabricConfig {
            latency: None,
            wait: WaitMode::None,
            ..Default::default()
        });
        let mut ep = RdmaEndpoint::new(
            &fabric,
            RingConfig { nslots: 64, cap_bytes: 64 << 20, ..Default::default() },
        );
        let mut tx = ep.sender();
        let ring = bench::quick(&format!("ringbuf  {:>6} KiB", s / 1024), || {
            assert!(tx.send(&m));
            while ep.recv().is_none() {}
        });
        report.add_result(&format!("ringbuf_{}kib", s / 1024), &ring);

        // TCP path: real sockets through the kernel.
        let mut tep = TcpEndpoint::new().unwrap();
        let mut ttx = tep.sender().unwrap();
        let sock = bench::quick(&format!("tcp      {:>6} KiB", s / 1024), || {
            assert!(ttx.send(&m));
            while tep.recv_timeout(Duration::from_secs(5)).is_none() {}
        });
        report.add_result(&format!("tcp_{}kib", s / 1024), &sock);
    }

    println!("\n=== E5d: eager vs rendezvous ring path (modelled IB, per message) ===");
    println!(
        "{:<12} {:>14} {:>14} {:>10} {:>16}",
        "payload", "eager ns/msg", "rdv ns/msg", "rdv/eager", "copied B/msg e/r"
    );
    for &s in &sizes {
        let m = msg(s);
        let plane = |threshold: usize| -> (f64, f64) {
            let fabric = Fabric::new(FabricConfig {
                latency: Some(LatencyModel::infiniband_100g()),
                wait: WaitMode::None,
                ..Default::default()
            });
            let reg = Registry::new();
            let rm = RingMetrics::from_registry(&reg);
            let mut ep = RdmaEndpoint::new(
                &fabric,
                RingConfig { nslots: 64, cap_bytes: 64 << 20, ..Default::default() },
            );
            ep.set_metrics(rm.clone());
            let mut tx = ep.sender();
            tx.set_metrics(rm.clone());
            tx.set_rendezvous_threshold(threshold);
            assert!(tx.send(&m)); // warm-up round
            assert!(ep.recv().is_some());
            let rounds = if s >= 1 << 20 { 8u64 } else { 64 };
            let ns0 = fabric.simulated_ns();
            let copied0 = rm.payload_bytes_copied.get();
            for _ in 0..rounds {
                assert!(tx.send(&m));
                assert!(ep.recv().is_some());
            }
            let copied = (rm.payload_bytes_copied.get() - copied0) as f64 / rounds as f64;
            let fabric_ns = (fabric.simulated_ns() - ns0) as f64 / rounds as f64;
            // Eager's two copies ride the transfer path; the rendezvous
            // staging copy is the serialization ingress and does not.
            let critical = if threshold == 0 { copied } else { 0.0 };
            (fabric_ns + MEMCPY_NS_PER_BYTE * critical, copied)
        };
        let (eager_ns, eager_copied) = plane(0);
        let (rdv_ns, rdv_copied) = plane(4 << 10);
        println!(
            "{:<12} {:>11.0} ns {:>11.0} ns {:>9.2}x {:>8.0}/{:<8.0}",
            format!("{} KiB", s / 1024),
            eager_ns,
            rdv_ns,
            eager_ns / rdv_ns,
            eager_copied,
            rdv_copied
        );
        let kib = s / 1024;
        report.add(format!("eager_{kib}kib.modelled_ns_per_msg"), eager_ns);
        report.add(format!("eager_{kib}kib.bytes_copied_per_msg"), eager_copied);
        report.add(format!("rdv_{kib}kib.modelled_ns_per_msg"), rdv_ns);
        report.add(format!("rdv_{kib}kib.bytes_copied_per_msg"), rdv_copied);
        report.add(format!("rdv_over_eager_{kib}kib"), eager_ns / rdv_ns);
    }
    println!("(crossover sits in the tens of KiB: below it the descriptor+READ verbs");
    println!(" outweigh the saved copies, above it the saved memcpys dominate)");

    println!("\n=== E5c: NCCL limitations (L1-L4, §6) ===");
    let mut nccl = NcclStub::new(1024);
    nccl.send(&vec![0.0; 1024]).unwrap();
    let err = nccl.send(&vec![0.0; 512]).unwrap_err();
    println!("L2 fixed size: {err}");
    println!(
        "L3 GPU interference: transferring 1024 elems charged {} ns of GPU busy time",
        nccl.gpu_busy_ns
    );
    println!("L1 tensor-only + L4 no message context: enforced by the NcclStub API types");
    report.write();
}
