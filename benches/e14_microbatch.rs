//! E14 — adaptive micro-batching: throughput vs p99 per SLO tier.
//!
//! The 1-vs-N amortization curve behind the paper's utilization
//! argument (§6): a stage executor whose per-invocation overhead
//! dominates (the amortized `I2vLogic` cost model,
//! `cost(n) = busy × (α + (1−α)·n)` with α = `I2V_BATCH_FIXED_FRAC`)
//! serves far more Batch-tier traffic per GPU once the data plane
//! coalesces compatible requests — while the Interactive bypass plus
//! the reserved fast lane keep Interactive p99 at the unbatched
//! baseline *in the same run*.
//!
//! Harness: one diffusion-style instance driven directly through its
//! ring (no proxy, so admission control cannot mask the data-plane
//! effect). A feeder saturates the Batch band at `offered` req/s while
//! the main thread probes with Interactive requests and measures their
//! end-to-end latency. Sweeps offered load × batch policy.
//!
//! Run: `cargo bench --bench e14_microbatch`

use onepiece::batch::BatchPolicy;
use onepiece::bench::Report;
use onepiece::client::{Priority, RequestTracker};
use onepiece::config::{BatchSettings, SchedMode};
use onepiece::db::{DbClient, MemDb};
use onepiece::metrics::Registry;
use onepiece::rdma::Fabric;
use onepiece::runtime::{ExecutorPool, StageExecutor};
use onepiece::sim::percentile;
use onepiece::transport::{
    AppId, MessageHeader, Payload, RdmaEndpoint, StageId, WorkflowMessage,
};
use onepiece::util::{Clock, NodeId, SystemClock, Uid};
use onepiece::workflow::{
    Assignment, ControlPlane, Instance, InstanceConfig, I2vLogic, NextHop, StageRole,
};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-request stage cost at batch = 1.
const EXEC: Duration = Duration::from_millis(8);
/// Worker pool (logical GPUs) per instance.
const WORKERS: usize = 8;
const WARMUP: Duration = Duration::from_millis(600);
const MEASURE: Duration = Duration::from_secs(3);
/// Interactive probe period (sparse: the probes measure latency, they
/// must not become the load).
const PROBE_EVERY: Duration = Duration::from_millis(25);

struct Fixed(Assignment);

impl ControlPlane for Fixed {
    fn get_assignment(&self, _node: NodeId) -> Assignment {
        self.0.clone()
    }
    fn report_utilization(&self, _node: NodeId, _util: f64) {}
}

struct Outcome {
    /// Batch-tier completions per second over the measure window.
    batch_tp: f64,
    /// Interactive probe latency percentiles, ms.
    int_p50_ms: f64,
    int_p99_ms: f64,
    probes: usize,
    /// Median formed-batch size (0 when batching is off).
    batch_size_p50: u64,
}

fn policy(max_batch: usize) -> Option<BatchPolicy> {
    (max_batch > 1).then(|| {
        BatchPolicy::from_settings(&BatchSettings {
            max_batch,
            max_wait_us: 3_000,
            adaptive: true,
            interactive_bypass: true,
            max_starvation_ms: 0,
        })
    })
}

fn run(offered_rps: f64, batch: Option<BatchPolicy>) -> Outcome {
    let fabric = Fabric::ideal();
    let clock: Arc<dyn Clock> = Arc::new(SystemClock);
    let db = Arc::new(MemDb::new(clock.clone(), u64::MAX));
    let db_client = DbClient::new(vec![db.clone()]);
    let metrics = Registry::new();
    let tracker = Arc::new(RequestTracker::new(clock.clone(), metrics.clone()));
    let mut pool = ExecutorPool::new();
    pool.insert("diffusion", StageExecutor::Simulated { busy: EXEC });
    let assignment = Assignment {
        version: 1,
        role: Some(StageRole {
            app: AppId(1),
            stage_index: 0,
            stage_name: "diffusion".into(),
            mode: SchedMode::Individual,
            workers: WORKERS,
            routes: vec![(AppId(1), vec![NextHop::Database])],
            batch,
        }),
    };
    let inst = Instance::spawn(
        InstanceConfig { node: NodeId(1), max_workers: WORKERS, ..Default::default() },
        &fabric,
        Arc::new(Fixed(assignment)),
        Arc::new(I2vLogic::new(4, 8, 2)),
        pool,
        vec![db.clone()],
        tracker.clone(),
        clock,
    );
    std::thread::sleep(Duration::from_millis(60)); // assignment settles

    let uid_src = Arc::new(AtomicU64::new(1));
    let stop = Arc::new(AtomicBool::new(false));
    // --- Batch-tier feeder: paced offered load with catch-up bursts so
    // sleep granularity cannot under-drive the target rate. ---
    let feeder = {
        let (stop, tracker, fabric) = (stop.clone(), tracker.clone(), fabric.clone());
        let (region, uid_src) = (inst.region_id(), uid_src.clone());
        std::thread::spawn(move || {
            let mut tx = RdmaEndpoint::sender_for(&fabric, region);
            let interval = Duration::from_secs_f64(1.0 / offered_rps);
            let mut next = Instant::now();
            while !stop.load(Ordering::Relaxed) {
                let now = Instant::now();
                if now < next {
                    std::thread::sleep((next - now).min(Duration::from_millis(2)));
                    continue;
                }
                next += interval;
                let uid = Uid(uid_src.fetch_add(1, Ordering::Relaxed) as u128);
                tracker.register(uid, Priority::Batch, None);
                // A full ring sheds offered load — that is the backlog
                // working as intended.
                let _ = tx.send(&mk_msg(uid));
            }
        })
    };

    std::thread::sleep(WARMUP);
    let p0 = inst.stats().processed;
    let t0 = Instant::now();
    // --- Interactive prober (same run as the saturating feeder). ---
    let mut tx = RdmaEndpoint::sender_for(&fabric, inst.region_id());
    let mut latencies_ms: Vec<f64> = Vec::new();
    while t0.elapsed() < MEASURE {
        let uid = Uid(uid_src.fetch_add(1, Ordering::Relaxed) as u128);
        tracker.register(uid, Priority::Interactive, None);
        let sent_at = Instant::now();
        if tx.send(&mk_msg(uid)) && db_client.wait_entry(uid, Duration::from_secs(2)).is_some()
        {
            latencies_ms.push(sent_at.elapsed().as_secs_f64() * 1e3);
        }
        std::thread::sleep(PROBE_EVERY);
    }
    let secs = t0.elapsed().as_secs_f64();
    let completed = (inst.stats().processed - p0) as f64;
    stop.store(true, Ordering::Relaxed);
    let _ = feeder.join();
    let batch_size_p50 = metrics.histogram("batch_size").snapshot().p50;
    inst.shutdown();

    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Outcome {
        batch_tp: (completed - latencies_ms.len() as f64).max(0.0) / secs,
        int_p50_ms: percentile(&latencies_ms, 0.5),
        int_p99_ms: percentile(&latencies_ms, 0.99),
        probes: latencies_ms.len(),
        batch_size_p50,
    }
}

fn mk_msg(uid: Uid) -> WorkflowMessage {
    WorkflowMessage {
        header: MessageHeader {
            uid,
            ts_ns: 0,
            app: AppId(1),
            stage: StageId(0),
            origin: NodeId(0),
        },
        payload: Payload::Bytes(vec![0; 64]),
    }
}

fn main() {
    let single_cap = WORKERS as f64 * 1_000.0 / EXEC.as_millis() as f64;
    println!("=== E14: adaptive micro-batching — offered load × policy ===");
    println!(
        "stage: diffusion sim {}ms × {WORKERS} workers (unbatched capacity {single_cap:.0} req/s) | \
         amortized I2vLogic cost model α={}",
        EXEC.as_millis(),
        onepiece::workflow::I2V_BATCH_FIXED_FRAC,
    );
    println!(
        "batching on: max_wait 3 ms adaptive, Interactive bypass + reserved fast lane\n"
    );
    println!(
        "{:<26} {:>12} {:>12} {:>10} {:>12} {:>12}",
        "configuration", "offered", "batch tp/s", "p50 batch", "int p50 ms", "int p99 ms"
    );

    let mut report = Report::new("e14_microbatch");
    let low = single_cap * 0.4;
    let saturating = single_cap * 4.0;
    let mut table: Vec<(String, f64, usize, Outcome)> = Vec::new();
    for (offered, max_batch) in [
        (low, 1),
        (low, 16),
        (saturating, 1),
        (saturating, 8),
        (saturating, 16),
    ] {
        let label = format!(
            "{} / max_batch={max_batch}",
            if offered < single_cap { "underload" } else { "saturated" }
        );
        let out = run(offered, policy(max_batch));
        println!(
            "{:<26} {:>12.0} {:>12.0} {:>10} {:>12.1} {:>12.1}",
            label, offered, out.batch_tp, out.batch_size_p50, out.int_p50_ms, out.int_p99_ms
        );
        let key = format!(
            "{}.b{max_batch}",
            if offered < single_cap { "underload" } else { "saturated" }
        );
        report
            .add(format!("{key}.batch_tp"), out.batch_tp)
            .add(format!("{key}.interactive_p99_ms"), out.int_p99_ms)
            .add(format!("{key}.batch_size_p50"), out.batch_size_p50 as f64);
        table.push((label, offered, max_batch, out));
    }

    let find = |offered: f64, mb: usize| {
        table
            .iter()
            .find(|(_, o, m, _)| (*o - offered).abs() < 1e-9 && *m == mb)
            .map(|(_, _, _, out)| out)
            .unwrap()
    };
    let base = find(saturating, 1);
    let b8 = find(saturating, 8);
    let b16 = find(saturating, 16);
    let speedup8 = b8.batch_tp / base.batch_tp;
    let speedup16 = b16.batch_tp / base.batch_tp;
    report
        .add("saturated.speedup_b8", speedup8)
        .add("saturated.speedup_b16", speedup16)
        .add("saturated.interactive_p99_ratio_b16", b16.int_p99_ms / base.int_p99_ms);
    report.write();

    println!(
        "\nBatch-tier speedup at saturation: max_batch=8 → {speedup8:.2}x, \
         max_batch=16 → {speedup16:.2}x (asymptotic amortization bound \
         1/(1−α) = {:.2}x per batching worker)",
        1.0 / (1.0 - onepiece::workflow::I2V_BATCH_FIXED_FRAC),
    );
    println!(
        "Interactive p99 (same run): unbatched {:.1} ms vs batched(b16) {:.1} ms \
         ({} / {} probes)",
        base.int_p99_ms, b16.int_p99_ms, base.probes, b16.probes
    );

    // --- the claims this experiment pins down ---
    assert!(
        base.probes > 0 && b16.probes > 0,
        "interactive probes must complete in both runs"
    );
    assert!(
        speedup16 >= 2.0,
        "Batch-tier throughput with max_batch=16 must be ≥ 2x the unbatched \
         baseline under the amortized I2vLogic cost model (got {speedup16:.2}x)"
    );
    assert!(
        b16.int_p99_ms <= base.int_p99_ms * 1.10,
        "Interactive p99 with bypass + reserved lane must stay within 10% of the \
         unbatched baseline: batched {:.1} ms vs baseline {:.1} ms",
        b16.int_p99_ms,
        base.int_p99_ms
    );
    println!(
        "\nshape: coalescing amortizes the per-invocation cost into ≥2x Batch-tier \
         throughput while the bypass + reserved lane hold the Interactive tail flat"
    );
}
