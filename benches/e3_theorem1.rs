//! E4 — Theorem 1 validation sweep: for K ∈ 1..8 and T_Y/T_X ∈ 1..8 the
//! simulated steady-state output rate of the Theorem-1-sized pipeline
//! must equal the entrance rate K/T_X (rate matching), and M-1 instances
//! must NOT suffice (tightness).

use onepiece::bench::Report;
use onepiece::pipeline::{instances_needed, trace_schedule, TraceStage};

fn main() {
    println!("=== E4: Theorem 1 rate-matching sweep ===");
    println!(
        "{:<6} {:<8} {:<4} {:>12} {:>12} {:>8}",
        "K", "Ty/Tx", "M", "target(s)", "measured(s)", "tight?"
    );
    let tx = 2.0;
    let mut checked = 0;
    for k in 1..=8usize {
        for ratio in 1..=8usize {
            let ty = tx * ratio as f64;
            let m = instances_needed(k, tx, ty);
            let target = tx / k as f64;
            let stages = vec![
                TraceStage { name: "X".into(), exec_s: tx, instances: 1, workers: k },
                TraceStage { name: "Y".into(), exec_s: ty, instances: m, workers: 1 },
            ];
            let n = (m * 6).max(24);
            let trace = trace_schedule(&stages, n, target);
            let ok = (trace.output_interval_s - target).abs() < 1e-6;

            // Tightness: with M-1 instances the interval must degrade.
            let tight = if m > 1 {
                let under = vec![
                    TraceStage { name: "X".into(), exec_s: tx, instances: 1, workers: k },
                    TraceStage {
                        name: "Y".into(),
                        exec_s: ty,
                        instances: m - 1,
                        workers: 1,
                    },
                ];
                let t2 = trace_schedule(&under, n, target);
                t2.output_interval_s > target + 1e-9
            } else {
                true
            };
            println!(
                "{:<6} {:<8} {:<4} {:>12.3} {:>12.3} {:>8}",
                k, ratio, m, target, trace.output_interval_s, tight
            );
            assert!(ok, "rate matching violated at K={k} ratio={ratio}");
            checked += 1;
        }
    }
    println!("\nall {checked} (K, Ty/Tx) combinations match Theorem 1");
    let mut report = Report::new("e3_theorem1");
    report.add("combinations_checked", checked as f64);
    report.add("rate_matching_violations", 0.0);
    report.write();
}
