//! E8 — §5 fast-reject: offered load sweep across the capacity point.
//! With the Request Monitor on, in-system latency stays flat and goodput
//! plateaus at capacity; with it off (headroom → ∞), queues grow and p99
//! explodes. Regenerates the paper's overload-stability argument.

use onepiece::client::Priority;
use onepiece::pipeline::{instances_needed, trace_schedule, TraceStage};
use onepiece::proxy::RequestMonitor;
use onepiece::sim::ArrivalProcess;
use onepiece::util::ManualClock;
use std::sync::Arc;

/// Queueing model of one workflow set entrance: capacity C req/s, each
/// admitted request takes the Theorem-1 pipeline latency; without
/// fast-reject the backlog adds waiting time.
fn run(offered_rps: f64, capacity_rps: f64, fast_reject: bool) -> (f64, f64, f64) {
    let duration = 300.0;
    let arrivals = ArrivalProcess::Poisson { rate_rps: offered_rps }.generate(7, duration);
    let clock = ManualClock::new();
    clock.set(1);
    let monitor = RequestMonitor::new(
        Arc::new(clock.clone()),
        1_000_000_000,
        if fast_reject { 1.0 } else { 1e9 },
        0.0, // pure capacity sweep: no interactive reserve
    );
    // Admitted requests flow through a single-stage queue with
    // `capacity` servers of 1 s each (normalized pipeline).
    let mut server_free = vec![0.0f64; capacity_rps.ceil() as usize];
    let service = capacity_rps.ceil() / capacity_rps; // keeps rate = C
    let mut admitted = 0u64;
    let mut latencies = Vec::new();
    for &t in &arrivals {
        clock.set((t * 1e9) as u64 + 1);
        if !monitor.admit(capacity_rps, Priority::Standard) {
            continue; // fast-rejected: client retries another set
        }
        admitted += 1;
        let (idx, &earliest) = server_free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let start = t.max(earliest);
        let end = start + service;
        server_free[idx] = end;
        latencies.push(end - t);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99 = latencies
        .get((latencies.len() * 99 / 100).min(latencies.len().saturating_sub(1)))
        .copied()
        .unwrap_or(0.0);
    let goodput = latencies.iter().filter(|&&l| l < 10.0 * service).count() as f64 / duration;
    (admitted as f64 / duration, goodput, p99)
}

fn main() {
    let capacity = 10.0;
    let mut report = onepiece::bench::Report::new("e6_fast_reject");
    println!("=== E8: fast-reject under offered-load sweep (capacity {capacity} req/s) ===");
    println!(
        "{:<12} {:>14} {:>12} {:>12} | {:>14} {:>12} {:>12}",
        "offered", "FR admit/s", "goodput", "p99 (s)", "noFR admit/s", "goodput", "p99 (s)"
    );
    for mult in [0.5, 0.8, 1.0, 1.2, 2.0, 4.0, 8.0] {
        let offered = capacity * mult;
        let (a1, g1, p1) = run(offered, capacity, true);
        let (a2, g2, p2) = run(offered, capacity, false);
        println!(
            "{:<12} {:>14.1} {:>12.1} {:>12.2} | {:>14.1} {:>12.1} {:>12.2}",
            format!("{mult:.1}x"),
            a1,
            g1,
            p1,
            a2,
            g2,
            p2
        );
        report
            .add(format!("fr.goodput.x{mult}"), g1)
            .add(format!("fr.p99_s.x{mult}"), p1)
            .add(format!("nofr.goodput.x{mult}"), g2)
            .add(format!("nofr.p99_s.x{mult}"), p2);
    }
    report.write();
    println!(
        "\nshape: with fast-reject, p99 stays ~flat past capacity and goodput \
         plateaus; without it, p99 grows with offered load (unbounded queue)"
    );

    // The Theorem-1 tie-in (§5): K is computed from live instance info.
    let m = instances_needed(1, 4.0, 12.0);
    let stages = vec![
        TraceStage { name: "X".into(), exec_s: 4.0, instances: 1, workers: 1 },
        TraceStage { name: "Y".into(), exec_s: 12.0, instances: m, workers: 1 },
    ];
    let t = trace_schedule(&stages, 8, 4.0);
    println!(
        "\nadmission interval from Theorem 1: {:.1} s (K/T_X with K=1, T_X=4 s)",
        t.output_interval_s
    );
}
