//! E2/E3 — regenerate the paper's Figure 5 and Figure 6 pipelining
//! schedules exactly: stage X (4 s) feeding stage Y (12 s), Theorem-1
//! sized, printing the gantt and the steady-state output interval.

use onepiece::bench::Report;
use onepiece::pipeline::{instances_needed, trace_schedule, TraceStage};

fn run(title: &str, workers_x: usize, admit_s: f64) -> f64 {
    let m = instances_needed(workers_x, 4.0, 12.0);
    let stages = vec![
        TraceStage { name: "X".into(), exec_s: 4.0, instances: 1, workers: workers_x },
        TraceStage { name: "Y".into(), exec_s: 12.0, instances: m, workers: 1 },
    ];
    let trace = trace_schedule(&stages, 9, admit_s);
    println!("=== {title} ===");
    println!("Theorem 1: K={workers_x}, T_X=4s, T_Y=12s -> M={m} Y-instances");
    println!("{}", trace.render_gantt(&stages, 2.0));
    println!(
        "steady-state output interval: {:.1} s (paper: {:.0} s); first-request latency {:.0} s\n",
        trace.output_interval_s, admit_s, trace.completions[0]
    );
    assert!((trace.output_interval_s - admit_s).abs() < 1e-6);
    trace.output_interval_s
}

fn main() {
    let mut report = Report::new("e2_pipeline_schedule");
    let fig5 = run("Figure 5: 1 X-worker, 3 Y-instances", 1, 4.0);
    let fig6 = run("Figure 6: 2 X-workers, 6 Y-instances", 2, 2.0);
    report.add("fig5_output_interval_s", fig5);
    report.add("fig6_output_interval_s", fig6);

    // Ablation: undersized Y (Theorem-1 violated) degrades the interval.
    let stages = vec![
        TraceStage { name: "X".into(), exec_s: 4.0, instances: 1, workers: 1 },
        TraceStage { name: "Y".into(), exec_s: 12.0, instances: 2, workers: 1 },
    ];
    let trace = trace_schedule(&stages, 12, 4.0);
    println!("=== Ablation: Y undersized (2 instead of 3) ===");
    println!(
        "output interval degrades to {:.1} s (= T_Y / M = 6 s), queue grows unboundedly",
        trace.output_interval_s
    );
    report.add("undersized_output_interval_s", trace.output_interval_s);
    report.write();
}
