//! E12 — SLO tiers through the unified client gateway: at identical
//! offered load, Interactive traffic keeps a flat p99 while Batch
//! absorbs the overload.
//!
//! Setup: one Workflow Set whose diffusion stage is deliberately
//! under-provisioned relative to the entrance admission rate, so a
//! backlog builds at diffusion while the run lasts. Requests are
//! submitted in an Interactive/Standard/Batch round-robin at ~2× the
//! entrance capacity:
//!
//! - the proxy's **interactive admission reserve** keeps rejecting
//!   Standard/Batch first under overload;
//! - the RequestScheduler's **priority-banded pull queue** lets
//!   Interactive requests jump the diffusion backlog;
//! - per-priority **deadlines** exercise the deadline-drop path: stage
//!   work past its deadline is dropped and a tombstone published.
//!
//! Reported per priority: offered / accepted / rejected counts,
//! completed p50/p99 latency, and deadline-miss rate.
//!
//! Run: `cargo bench --bench e12_slo_tiers`

use onepiece::bench::Report;
use onepiece::client::{Gateway, Priority, RequestHandle, SubmitOptions, WaitOutcome};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind};
use onepiece::transport::{AppId, Payload};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Deadline per priority class: tight for Interactive (it rides the
/// fast lane and should virtually never miss), loose for Standard,
/// looser for Batch (which still misses once the backlog exceeds it).
fn deadline_for(p: Priority) -> Duration {
    match p {
        Priority::Interactive => Duration::from_millis(400),
        Priority::Standard => Duration::from_millis(1_500),
        Priority::Batch => Duration::from_millis(3_000),
    }
}

fn main() {
    // Entrance admits ~83 req/s (exec_ms 12, 1 worker); diffusion serves
    // only 50 req/s (exec 20 ms, 1 instance) — the admitted stream
    // itself overloads diffusion, so queueing delay grows there.
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    let stage_ms = [12.0, 1.0, 20.0, 1.0];
    for (s, &ms) in cfg.apps[0].stages.iter_mut().zip(&stage_ms) {
        s.exec = ExecModel::Simulated { ms };
        s.exec_ms = ms;
    }
    cfg.apps[0].stages[2].mode = onepiece::config::SchedMode::Individual;
    cfg.proxy.monitor_window_ms = 500;
    cfg.proxy.interactive_reserve = 0.2;
    cfg.idle_pool = 0;
    let pool = build_pool(&cfg, None);
    let capacity = 1000.0 / stage_ms[0];
    // Under-provision diffusion: 1 instance everywhere.
    let set = WorkflowSet::build(
        cfg,
        vec![vec![1, 1, 1, 1]],
        Arc::new(EchoLogic),
        pool,
    );
    std::thread::sleep(Duration::from_millis(100));

    println!("=== E12: SLO tiers at identical offered load ===");
    println!(
        "entrance capacity {capacity:.0} req/s | diffusion capacity 50 req/s | \
         offered {:.0} req/s, 1/3 per priority",
        capacity * 2.0
    );

    let offered_interval = Duration::from_secs_f64(1.0 / (capacity * 2.0));
    let run = Duration::from_secs(4);
    let mut offered = [0u64; 3];
    let mut rejected = [0u64; 3];
    let mut pending: Vec<(RequestHandle, Instant)> = Vec::new();
    let t0 = Instant::now();
    let mut i = 0u64;
    while t0.elapsed() < run {
        let prio = Priority::ALL[(i % 3) as usize];
        i += 1;
        offered[prio.index()] += 1;
        let opts = SubmitOptions::default()
            .with_priority(prio)
            .with_deadline(deadline_for(prio));
        match set.submit_with(AppId(1), Payload::Bytes(vec![0; 32]), opts) {
            Ok(handle) => pending.push((handle, Instant::now())),
            Err(_) => rejected[prio.index()] += 1,
        }
        std::thread::sleep(offered_interval);
    }

    // Drain every outstanding handle to its terminal state.
    let mut latencies: [Vec<f64>; 3] = Default::default();
    let mut missed = [0u64; 3];
    let mut other = [0u64; 3];
    for (handle, submitted) in pending {
        let idx = handle.priority().index();
        match handle.wait(Duration::from_secs(10)) {
            WaitOutcome::Done(_) => {
                latencies[idx].push(submitted.elapsed().as_secs_f64() * 1e3)
            }
            WaitOutcome::DeadlineExceeded => missed[idx] += 1,
            _ => other[idx] += 1,
        }
    }

    println!(
        "\n{:<13} {:>8} {:>9} {:>9} {:>10} {:>10} {:>10} {:>12}",
        "priority", "offered", "accepted", "rejected", "completed", "p50 (ms)", "p99 (ms)", "miss rate"
    );
    for p in Priority::ALL {
        let idx = p.index();
        let mut lat = std::mem::take(&mut latencies[idx]);
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let accepted = offered[idx] - rejected[idx];
        let terminal = lat.len() as u64 + missed[idx] + other[idx];
        println!(
            "{:<13} {:>8} {:>9} {:>9} {:>10} {:>10.1} {:>10.1} {:>11.1}%",
            p.label(),
            offered[idx],
            accepted,
            rejected[idx],
            lat.len(),
            onepiece::sim::percentile(&lat, 0.5),
            onepiece::sim::percentile(&lat, 0.99),
            100.0 * missed[idx] as f64 / terminal.max(1) as f64,
        );
        latencies[idx] = lat;
    }
    let metrics = set.metrics();
    println!(
        "\nlifecycle counters: deadline_missed {} | requests_cancelled {} | \
         sla-dropped stage work {}",
        metrics.counter("deadline_missed").get(),
        metrics.counter("requests_cancelled").get(),
        set.instance_stats()
            .iter()
            .map(|(_, s, _)| s.sla_dropped)
            .sum::<u64>(),
    );

    // Shape assertions (the claim this experiment pins down).
    let p99 = |idx: usize| onepiece::sim::percentile(&latencies[idx], 0.99);
    let int_idx = Priority::Interactive.index();
    let batch_idx = Priority::Batch.index();
    assert!(
        !latencies[int_idx].is_empty(),
        "interactive must complete under overload"
    );
    if !latencies[batch_idx].is_empty() {
        assert!(
            p99(int_idx) <= p99(batch_idx),
            "interactive p99 ({:.1} ms) must not exceed batch p99 ({:.1} ms)",
            p99(int_idx),
            p99(batch_idx)
        );
    }
    let miss_rate = |idx: usize| {
        let terminal = latencies[idx].len() as u64 + missed[idx] + other[idx];
        missed[idx] as f64 / terminal.max(1) as f64
    };
    assert!(
        miss_rate(int_idx) <= miss_rate(batch_idx) + 1e-9,
        "interactive must not miss deadlines more often than batch"
    );
    println!(
        "\nshape: interactive p99 stays flat (fast-lane admission + queue \
         priority) while batch absorbs the diffusion backlog and the \
         deadline misses"
    );
    let mut report = Report::new("e12_slo_tiers");
    for p in Priority::ALL {
        let idx = p.index();
        report
            .add(format!("{}.offered", p.label()), offered[idx] as f64)
            .add(format!("{}.rejected", p.label()), rejected[idx] as f64)
            .add(format!("{}.completed", p.label()), latencies[idx].len() as f64)
            .add(format!("{}.p99_ms", p.label()), p99(idx))
            .add(format!("{}.miss_rate", p.label()), miss_rate(idx));
    }
    report.write();
    set.shutdown();
}
