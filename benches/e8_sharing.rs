//! E10 — Figure 11: instance sharing between two workflows (the paper's
//! LTX multi-image-to-video and I2V share every stage except their
//! diffusion models). Measures the GPU saving from sharing the common
//! stages and verifies per-app routing through a live shared pipeline.

use onepiece::client::{Gateway, WaitOutcome};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind};
use onepiece::nm::StageKey;
use onepiece::transport::{AppId, Payload};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

fn two_app_config() -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 1.0 };
        s.exec_ms = 1.0;
    }
    let mut ltx = cfg.apps[0].clone();
    ltx.id = 2;
    ltx.name = "ltx".into();
    // LTX uses a different diffusion model (stage 2) but identical
    // encoder/decoder stages.
    ltx.stages[2].name = "ltx_diffusion".into();
    cfg.apps.push(ltx);
    cfg.idle_pool = 0;
    cfg
}

fn main() {
    println!("=== E10: Figure 11 instance sharing (I2V + LTX) ===");

    // --- resource accounting: shared vs duplicated stages ---
    let cfg = two_app_config();
    let per_app: usize = cfg.apps[0].stages.iter().map(|s| s.gpus_per_instance).sum();
    let shared_stages: usize = cfg.apps[0]
        .stages
        .iter()
        .zip(&cfg.apps[1].stages)
        .filter(|(a, b)| a.name == b.name)
        .map(|(a, _)| a.gpus_per_instance)
        .sum();
    let unshared = 2 * per_app - shared_stages;
    println!(
        "GPUs without sharing: {} | with sharing: {} | saving: {:.0}%",
        2 * per_app,
        unshared,
        100.0 * (2.0 * per_app as f64 - unshared as f64) / (2.0 * per_app as f64)
    );
    let mut report = onepiece::bench::Report::new("e8_sharing");
    report.add(
        "gpu_saving_frac",
        (2.0 * per_app as f64 - unshared as f64) / (2.0 * per_app as f64),
    );

    // --- live shared pipeline: one set serving both apps, sharing all
    //     stages except diffusion ---
    let pool = build_pool(&cfg, None);
    // App 1 gets full instance chain; app 2 only its own diffusion.
    let counts = vec![vec![1, 1, 1, 1], vec![0, 0, 1, 0]];
    let set = WorkflowSet::build(cfg, counts, Arc::new(EchoLogic), pool);
    // Declare sharing: app 2's stages 0, 1, 3 are served by app 1's.
    for stage in [0u32, 1, 3] {
        set.nm.share_stage(
            StageKey { app: AppId(2), stage },
            StageKey { app: AppId(1), stage },
        );
    }
    std::thread::sleep(Duration::from_millis(100));

    let mut handles = Vec::new();
    for i in 0..10u32 {
        let app = AppId(1 + i % 2);
        match set.submit(app, Payload::Bytes(vec![i as u8])) {
            Ok(handle) => handles.push((app, handle)),
            Err(e) => println!("req {i} rejected ({e})"),
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let mut done = [0usize; 2];
    for (app, handle) in &handles {
        if matches!(handle.wait(Duration::from_secs(10)), WaitOutcome::Done(_)) {
            done[(app.0 - 1) as usize] += 1;
        }
    }
    println!(
        "completed through shared stages: app1 {}/5, app2 {}/5",
        done[0], done[1]
    );
    assert!(done[0] >= 4 && done[1] >= 4, "both workflows must flow");
    report.add("app1_completed", done[0] as f64);
    report.add("app2_completed", done[1] as f64);
    report.write();
    set.shutdown();
    println!("both workflows complete over the SAME encoder/decoder instances; only diffusion differs");
}
