//! E17 — distributed-tracing overhead: the flight-recorder hot path
//! must be invisible next to AIGC-scale stage compute.
//!
//! Tracing records events unconditionally when enabled (`sample_rate`
//! governs *retention* at finalize, so the slow-tail rule can act on
//! requests the head-sampling hash would drop), which makes the
//! per-event record cost the whole hot-path story. This experiment:
//!
//! 1. microbenchmarks `TraceHook::record` (a clock read, five packed
//!    words, a seqlock slot write — no locks, no allocation);
//! 2. measures the drain-side stitching cost per event;
//! 3. counts how many events one end-to-end i2v request actually
//!    records, on a production-style `sample_rate = 0.01` deployment;
//! 4. models the per-request overhead against the paper-scale pipeline
//!    (the default i2v config's summed stage compute) and asserts it
//!    stays under 2%.
//!
//! Run: `cargo bench --bench e17_trace_overhead`

use onepiece::bench::{header, quick, Report};
use onepiece::client::{Gateway, WaitOutcome};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind, TraceSettings};
use onepiece::metrics::Registry;
use onepiece::trace::{EventKind, Tracer, Verdict};
use onepiece::transport::{AppId, Payload};
use onepiece::util::{SystemClock, Uid};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// End-to-end requests for the events-per-request measurement.
const REQUESTS: usize = 40;
/// Modelled-overhead ceiling (percent of request time).
const MAX_OVERHEAD_PCT: f64 = 2.0;

fn traced_config(sample_rate: f64) -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 1.0 };
        s.exec_ms = 1.0;
    }
    cfg.idle_pool = 0;
    cfg.trace = Some(TraceSettings {
        sample_rate,
        buffer_events: 4096,
        always_sample_slow_ms: 0,
    });
    cfg
}

fn main() {
    println!("=== E17: distributed-tracing overhead ===");

    // --- 1. record-path microbenchmark ------------------------------
    let metrics = Registry::new();
    let tracer = Tracer::new(
        &TraceSettings { sample_rate: 0.01, buffer_events: 4096, always_sample_slow_ms: 0 },
        Arc::new(SystemClock),
        0,
        &metrics,
    );
    let hook = tracer.hook(1);
    header("flight-recorder hot path");
    let mut i = 0u128;
    let record = quick("TraceHook::record (1 event)", || {
        hook.record(Uid(i), Some(2), EventKind::Enqueued);
        i += 1;
    });

    // --- 2. drain-side stitching cost per event ---------------------
    // Fill a fresh recorder to capacity with complete request pairs,
    // then time one drain (absorb + finalize for every pair).
    let drain_tracer = Tracer::new(
        &TraceSettings { sample_rate: 1.0, buffer_events: 4096, always_sample_slow_ms: 0 },
        Arc::new(SystemClock),
        0,
        &Registry::new(),
    );
    let drain_hook = drain_tracer.hook(1);
    for u in 0..2048u128 {
        drain_hook.record(Uid(u), None, EventKind::Admitted);
        drain_hook.record(Uid(u), None, EventKind::Terminal { verdict: Verdict::Done });
    }
    let t0 = Instant::now();
    drain_tracer.drain();
    let drain_ns_per_event = t0.elapsed().as_nanos() as f64 / 4096.0;
    println!(
        "{:<44} {:>10.0} ns/event (4096-event drain)",
        "Tracer::drain (stitch + finalize)", drain_ns_per_event
    );

    // --- 3. events per request, end to end --------------------------
    let cfg = traced_config(0.01);
    let pool = build_pool(&cfg, None);
    let set = WorkflowSet::build(cfg, vec![vec![1, 1, 1, 1]], Arc::new(EchoLogic), pool);
    std::thread::sleep(Duration::from_millis(80)); // assignments settle
    let mut completed = 0usize;
    for r in 0..REQUESTS {
        let Ok(handle) = set.submit(AppId(1), Payload::Bytes(vec![r as u8; 48])) else {
            continue;
        };
        if matches!(handle.wait(Duration::from_secs(10)), WaitOutcome::Done(_)) {
            completed += 1;
        }
    }
    assert!(
        completed >= REQUESTS * 9 / 10,
        "sequential submit→wait must complete (nearly) everything: {completed}/{REQUESTS}"
    );
    let events_total = set.metrics().counter("trace_events_total").get();
    let events_per_request = events_total as f64 / completed as f64;
    println!(
        "\nend-to-end: {completed} requests recorded {events_total} events \
         ({events_per_request:.1} events/request at sample_rate 0.01)"
    );
    set.shutdown();

    // --- 4. modelled overhead against the paper-scale pipeline ------
    // The bench pipeline runs shrunk 1 ms stages so the measurement is
    // fast; the overhead model uses the *default* i2v config's summed
    // stage compute (the paper-scale request this system is built for).
    let paper_request_ms: f64 = ClusterConfig::i2v_default().apps[0]
        .stages
        .iter()
        .map(|s| s.exec_ms)
        .sum();
    let overhead_ns_per_request =
        events_per_request * (record.mean_ns + drain_ns_per_event);
    let overhead_pct = 100.0 * overhead_ns_per_request / (paper_request_ms * 1e6);
    println!(
        "modelled: {events_per_request:.1} events × ({:.0} ns record + {:.0} ns drain) \
         = {:.1} µs per request — {:.4}% of a {paper_request_ms:.0} ms i2v request",
        record.mean_ns,
        drain_ns_per_event,
        overhead_ns_per_request / 1e3,
        overhead_pct
    );

    let mut report = Report::new("e17_trace_overhead");
    report
        .add_result("record", &record)
        .add("drain_ns_per_event", drain_ns_per_event)
        .add("events_per_request", events_per_request)
        .add("modelled_request_ms", paper_request_ms)
        .add("modelled_overhead_pct", overhead_pct);
    report.write();

    // --- the claims this experiment pins down ---
    assert!(
        (8.0..400.0).contains(&events_per_request),
        "events/request out of the instrumented-hop range: {events_per_request:.1}"
    );
    assert!(
        overhead_pct <= MAX_OVERHEAD_PCT,
        "tracing must stay under {MAX_OVERHEAD_PCT}% of request time, modelled \
         {overhead_pct:.4}%"
    );
    println!(
        "\nshape: recording is a clock read + seqlock slot write; at AIGC stage \
         costs the whole trace of a request is worth well under {MAX_OVERHEAD_PCT}% \
         of its compute"
    );
}
