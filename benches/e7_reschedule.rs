//! E9 — Figure 10: NM dynamic rescheduling. A load shift saturates the
//! diffusion stage; the NM's §8.2 loop moves an idle-pool instance and
//! then an underutilized VAE-decode instance into diffusion. Prints the
//! before/after utilization and the action log, and measures the
//! decision latency of a rebalance pass over growing fleets.

use onepiece::bench;
use onepiece::config::ClusterConfig;
use onepiece::nm::{NodeManager, StageKey};
use onepiece::rdma::RegionId;
use onepiece::transport::AppId;
use onepiece::util::NodeId;
use onepiece::workflow::ControlPlane; // report_utilization lives here

fn key(stage: u32) -> StageKey {
    StageKey { app: AppId(1), stage }
}

fn main() {
    println!("=== E9: Figure 10 rescheduling scenario ===");
    let nm = NodeManager::new(ClusterConfig::i2v_default().apps, 0.85);

    // Topology: prep (stage 0) ×1 at 60%, diffusion (stage 2) ×2 at 100%,
    // decode (stage 3) ×2 at 15%, plus one idle-pool instance — the
    // figure's starting state.
    let nodes: &[(u32, Option<u32>, f64)] = &[
        (1, Some(0), 0.60),
        (2, Some(2), 1.00),
        (3, Some(2), 1.00),
        (4, Some(3), 0.15),
        (5, Some(3), 0.12),
        (6, None, 0.0), // idle pool
    ];
    for &(n, stage, util) in nodes {
        nm.register_instance(NodeId(n), RegionId(n as u64 * 100));
        if let Some(s) = stage {
            nm.assign(NodeId(n), Some(key(s)));
        }
        nm.report_utilization(NodeId(n), util);
    }

    println!("before: diffusion util {:.0}%, instances {:?}; idle pool {:?}",
        nm.stage_utilization(key(2)) * 100.0,
        nm.stage_instances(key(2)),
        nm.idle_pool());

    // Pass 1: idle instance joins diffusion.
    let a1 = nm.rebalance().expect("must act above threshold");
    println!("action 1: {:?} -> {:?} (trigger {:.0}%)", a1.from, a1.to, a1.trigger_util * 100.0);
    assert_eq!(a1.from, None, "idle pool first");

    // Diffusion still hot (new instance hasn't absorbed load yet).
    nm.report_utilization(NodeId(2), 0.97);
    nm.report_utilization(NodeId(3), 0.97);
    nm.report_utilization(NodeId(6), 0.90);

    // Pass 2: steal from the underutilized decode stage (the figure's
    // "VAE Decode instance reassigned to Diffusion").
    let a2 = nm.rebalance().expect("second pass must act");
    println!("action 2: {:?} -> {:?} (trigger {:.0}%)", a2.from, a2.to, a2.trigger_util * 100.0);
    assert_eq!(a2.from, Some(key(3)));
    assert_eq!(a2.to, key(2));

    println!("after:  diffusion instances {:?}; decode instances {:?}; idle pool {:?}",
        nm.stage_instances(key(2)),
        nm.stage_instances(key(3)),
        nm.idle_pool());
    assert_eq!(nm.stage_instances(key(2)).len(), 4);
    assert_eq!(nm.stage_instances(key(3)).len(), 1);

    // --- decision latency vs fleet size ---
    let mut report = bench::Report::new("e7_reschedule");
    bench::header("E9b: rebalance decision latency vs fleet size");
    for fleet in [16usize, 64, 256, 1024] {
        let nm = NodeManager::new(ClusterConfig::i2v_default().apps, 0.85);
        for i in 0..fleet {
            let n = NodeId(i as u32 + 1);
            nm.register_instance(n, RegionId(i as u64));
            nm.assign(n, Some(key((i % 4) as u32)));
            nm.report_utilization(n, if i % 4 == 2 { 0.99 } else { 0.3 });
        }
        let r = bench::quick(&format!("fleet={fleet} instances"), || {
            // Rebalance + undo so each iteration sees the same state.
            if let Some(a) = nm.rebalance() {
                nm.assign(a.node, a.from);
                if let Some(f) = a.from {
                    let _ = f;
                }
                nm.report_utilization(a.node, 0.3);
            }
        });
        report.add_result(&format!("rebalance_fleet{fleet}"), &r);
    }
    report.write();
}
