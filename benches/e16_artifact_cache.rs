//! E16 — content-addressed artifact cache: throughput under repeated
//! prompts.
//!
//! AIGC request streams are heavily repeated (same prompt + config ⇒
//! same output for deterministic stages). The artifact cache keys
//! `hash(app, stage, salt, canonical input)` and serves hits without
//! re-executing: a full-workflow hit terminates at the proxy (the
//! request never enters the pipeline), per-stage hits skip `execute`
//! inside the instance worker loop.
//!
//! Harness: one Workflow Set (4 × 5 ms simulated stages, EchoLogic),
//! driven with prompts drawn from a Zipf popularity distribution over
//! 32 distinct values — submit → wait, sequentially, so admission
//! control never sheds load and every completion is byte-checked
//! against the submitted prompt. Sweeps {uncached, cached} × skew.
//!
//! Run: `cargo bench --bench e16_artifact_cache`

use onepiece::bench::Report;
use onepiece::client::{Gateway, WaitOutcome};
use onepiece::config::{CacheSettings, ClusterConfig, ExecModel, FabricKind};
use onepiece::sim::Zipf;
use onepiece::transport::{AppId, Payload, WorkflowMessage};
use onepiece::util::Rng;
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Distinct prompt population.
const DISTINCT: usize = 32;
/// Requests per run.
const REQUESTS: usize = 200;
/// Per-stage simulated execution cost (×4 stages per request).
const STAGE_MS: f64 = 5.0;

fn config(cached: bool) -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: STAGE_MS };
        s.exec_ms = STAGE_MS;
    }
    cfg.idle_pool = 1;
    if cached {
        cfg.cache = Some(CacheSettings::default());
    }
    cfg
}

struct Outcome {
    wall_s: f64,
    completed: usize,
    hits: u64,
    misses: u64,
    coalesced: u64,
    bytes_saved: u64,
}

fn run(cached: bool, skew: f64) -> Outcome {
    let cfg = config(cached);
    let pool = build_pool(&cfg, None);
    let counts = vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)];
    let set = WorkflowSet::build(cfg, counts, Arc::new(EchoLogic), pool);
    std::thread::sleep(Duration::from_millis(80)); // assignments settle

    let zipf = Zipf::new(DISTINCT, skew);
    let mut rng = Rng::new(16);
    let mut completed = 0usize;
    let t0 = Instant::now();
    for _ in 0..REQUESTS {
        let prompt = vec![zipf.sample(&mut rng) as u8; 48];
        let Ok(handle) = set.submit(AppId(1), Payload::Bytes(prompt.clone())) else {
            continue;
        };
        let WaitOutcome::Done(bytes) = handle.wait(Duration::from_secs(10)) else {
            continue;
        };
        let msg = WorkflowMessage::decode(&bytes).expect("stored result decodes");
        // The load-bearing correctness check: a cache hit must produce
        // exactly the bytes the uncached pipeline would have produced
        // (EchoLogic passes the prompt through all four stages).
        assert_eq!(
            msg.payload,
            Payload::Bytes(prompt),
            "cached result must be byte-identical to the uncached echo"
        );
        completed += 1;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let counters: HashMap<String, u64> =
        set.metrics().counters_snapshot().into_iter().collect();
    let prefix_sum = |p: &str| -> u64 {
        counters.iter().filter(|(k, _)| k.starts_with(p)).map(|(_, v)| *v).sum()
    };
    if !cached {
        assert!(
            counters.keys().all(|k| !k.starts_with("cache_")),
            "no `cache` config block ⇒ no cache machinery may be touched"
        );
    }
    let out = Outcome {
        wall_s,
        completed,
        hits: prefix_sum("cache_hits."),
        misses: prefix_sum("cache_misses."),
        coalesced: counters.get("cache_coalesced_total").copied().unwrap_or(0),
        bytes_saved: counters.get("cache_bytes_saved_total").copied().unwrap_or(0),
    };
    set.shutdown();
    out
}

fn main() {
    println!("=== E16: content-addressed artifact cache — repeat-heavy prompts ===");
    println!(
        "pipeline: 4 × {STAGE_MS} ms simulated stages | {REQUESTS} requests over \
         {DISTINCT} distinct prompts, submit→wait sequential\n"
    );
    println!(
        "{:<22} {:>9} {:>10} {:>12} {:>8} {:>8} {:>12}",
        "configuration", "done", "wall (s)", "thr (req/s)", "hits", "misses", "bytes_saved"
    );

    let rows = [
        ("uncached / zipf s=1.0", false, 1.0),
        ("cached / zipf s=1.0", true, 1.0),
        ("cached / uniform s=0", true, 0.0),
    ];
    let mut outcomes = Vec::new();
    for (label, cached, skew) in rows {
        let o = run(cached, skew);
        println!(
            "{:<22} {:>9} {:>10.2} {:>12.1} {:>8} {:>8} {:>12}",
            label,
            o.completed,
            o.wall_s,
            o.completed as f64 / o.wall_s,
            o.hits,
            o.misses,
            o.bytes_saved
        );
        outcomes.push(o);
    }
    let (base, zipf, uniform) = (&outcomes[0], &outcomes[1], &outcomes[2]);
    let speedup = base.wall_s / zipf.wall_s;

    let mut report = Report::new("e16_artifact_cache");
    report
        .add("uncached.wall_s", base.wall_s)
        .add("cached_zipf.wall_s", zipf.wall_s)
        .add("cached_zipf.speedup", speedup)
        .add("cached_zipf.hits", zipf.hits as f64)
        .add("cached_zipf.misses", zipf.misses as f64)
        .add("cached_zipf.coalesced", zipf.coalesced as f64)
        .add("cached_zipf.bytes_saved", zipf.bytes_saved as f64)
        .add("cached_uniform.hits", uniform.hits as f64)
        .add("cached_uniform.wall_s", uniform.wall_s);
    report.write();

    // --- the claims this experiment pins down ---
    assert!(
        base.completed >= REQUESTS * 9 / 10 && zipf.completed >= REQUESTS * 9 / 10,
        "sequential submit→wait must complete (nearly) everything: uncached {} cached {}",
        base.completed,
        zipf.completed
    );
    assert_eq!(base.hits + base.misses, 0, "uncached run must not count cache traffic");
    assert!(
        zipf.hits > 0,
        "Zipf-skewed repeats must produce cache hits (got {} hits / {} misses)",
        zipf.hits,
        zipf.misses
    );
    assert!(
        zipf.wall_s < base.wall_s * 0.7,
        "cache hits skip the 4-stage pipeline: cached wall {:.2}s must beat \
         uncached {:.2}s by ≥ 30%",
        zipf.wall_s,
        base.wall_s
    );
    println!(
        "\nshape: {speedup:.1}x end-to-end speedup at s=1.0 — repeat prompts are \
         served at admission (workflow tier) or before execute (stage tier), \
         byte-identical to the uncached path"
    );
}
