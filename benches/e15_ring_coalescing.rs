//! E15 — ring verb coalescing: modelled fabric time per message for the
//! three producer paths, swept over payload size × batch size.
//!
//! - **single** — `push` with the cached-header fast path disabled
//!   (~7 verbs: the vectored GH, packed lock, and CAS-pair UH are
//!   always on — the pre-coalescing 12-verb protocol no longer exists
//!   in code; its cost is the analytic "before" column of the DESIGN.md
//!   verb budget). Speedups below are against this *harder* baseline,
//!   so they understate the PR-over-PR win.
//! - **cached** — `push` with the fast path on: the slot read and
//!   Case-7 scan are skipped when the validation read matches.
//! - **push_many(k)** — the batched protocol: one lock, one GH, one
//!   coalesced WB, k WLs, one doorbell-batched UH, one unlock.
//!
//! The fabric runs the calibrated InfiniBand model in `WaitMode::None`,
//! so the numbers are the *modelled* verbs cost (`base_ns` per verb +
//! line-rate bytes), read from `Fabric::simulated_ns()` — wall-clock
//! noise does not enter. Target: ≥ 3× reduction in modelled ns/message
//! for `push_many` at batch ≥ 8 vs the per-message push (asserted).

use onepiece::bench;
use onepiece::rdma::{Fabric, FabricConfig, LatencyModel};
use onepiece::ringbuf::{create_ring, RingConfig, RingConsumer, RingProducer};
use onepiece::util::SystemClock;
use std::sync::Arc;

/// Modelled (ns_per_msg, verbs_per_msg) for `rounds` batches of `batch`
/// messages of `payload` bytes.
fn measure(payload: usize, batch: usize, cached: bool) -> (f64, f64) {
    let cfg = RingConfig {
        nslots: 1024,
        cap_bytes: 64 << 20,
        ..Default::default()
    };
    let fabric = Fabric::new(FabricConfig {
        latency: Some(LatencyModel::infiniband_100g()),
        ..Default::default()
    });
    let (id, region) = create_ring(&fabric, cfg);
    let prod = RingProducer::new(fabric.connect(id).unwrap(), cfg, Arc::new(SystemClock), 1);
    prod.set_caching(cached);
    let mut cons = RingConsumer::new(region, cfg);
    let msg = vec![7u8; payload];
    let refs: Vec<&[u8]> = (0..batch).map(|_| msg.as_slice()).collect();

    // Warm up (fills the producer cache when enabled).
    prod.push(&msg, None).unwrap();
    cons.pop().unwrap().unwrap();

    let rounds = 200usize;
    let ns0 = fabric.simulated_ns();
    let (ops0, _) = fabric.traffic();
    for _ in 0..rounds {
        if batch == 1 {
            prod.push(&msg, None).unwrap();
        } else {
            let out = prod.push_many(&refs, None).unwrap();
            assert_eq!(out.accepted, batch, "ring sized to fit the batch");
        }
        for r in cons.pop_many(batch) {
            r.unwrap();
        }
    }
    let msgs = (rounds * batch) as f64;
    let ns = (fabric.simulated_ns() - ns0) as f64 / msgs;
    let (ops1, _) = fabric.traffic();
    (ns, (ops1 - ops0) as f64 / msgs)
}

fn main() {
    let mut report = bench::Report::new("e15_ring_coalescing");
    println!("\n=== E15: modelled fabric time per message (2 µs/verb base) ===");
    println!(
        "{:<12} {:<14} {:>14} {:>12} {:>10}",
        "payload", "path", "ns/msg", "verbs/msg", "speedup"
    );

    for payload in [100usize, 1024, 16 << 10] {
        let (single_ns, single_verbs) = measure(payload, 1, false);
        let (cached_ns, cached_verbs) = measure(payload, 1, true);
        let mut rows = vec![
            ("single".to_string(), single_ns, single_verbs),
            ("cached".to_string(), cached_ns, cached_verbs),
        ];
        let mut batch8_ns = f64::INFINITY;
        for batch in [4usize, 8, 16] {
            let (ns, verbs) = measure(payload, batch, true);
            if batch == 8 {
                batch8_ns = ns;
            }
            rows.push((format!("push_many({batch})"), ns, verbs));
        }
        for (path, ns, verbs) in &rows {
            println!(
                "{:<12} {:<14} {:>12.0}ns {:>12.2} {:>9.2}x",
                format!("{payload} B"),
                path,
                ns,
                verbs,
                single_ns / ns
            );
            let key = path.replace('(', "_").replace(')', "");
            report.add(format!("{key}_{payload}b.ns_per_msg"), *ns);
            report.add(format!("{key}_{payload}b.verbs_per_msg"), *verbs);
        }
        let speedup = single_ns / batch8_ns;
        report.add(format!("speedup_batch8_{payload}b"), speedup);
        assert!(
            speedup >= 3.0,
            "{payload} B: push_many(8) must cut modelled fabric ns/msg ≥ 3x \
             vs per-message push (got {speedup:.2}x)"
        );
        println!();
    }
    println!("(push_many at batch 8 is ≥ 3x cheaper per message than per-message push)");
    report.write();
}
