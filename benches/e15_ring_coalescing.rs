//! E15 — ring verb coalescing: modelled fabric time per message for the
//! three producer paths, swept over payload size × batch size.
//!
//! - **single** — `push` with the cached-header fast path disabled
//!   (~7 verbs: the vectored GH, packed lock, and CAS-pair UH are
//!   always on — the pre-coalescing 12-verb protocol no longer exists
//!   in code; its cost is the analytic "before" column of the DESIGN.md
//!   verb budget). Speedups below are against this *harder* baseline,
//!   so they understate the PR-over-PR win.
//! - **cached** — `push` with the fast path on: the slot read and
//!   Case-7 scan are skipped when the validation read matches.
//! - **push_many(k)** — the batched protocol: one lock, one GH, one
//!   coalesced WB, k WLs, one doorbell-batched UH, one unlock.
//!
//! The fabric runs the calibrated InfiniBand model in `WaitMode::None`,
//! so the numbers are the *modelled* verbs cost (`base_ns` per verb +
//! line-rate bytes), read from `Fabric::simulated_ns()` — wall-clock
//! noise does not enter. Target: ≥ 3× reduction in modelled ns/message
//! for `push_many` at batch ≥ 8 vs the per-message push (asserted).

use onepiece::bench;
use onepiece::metrics::Registry;
use onepiece::rdma::{Fabric, FabricConfig, LatencyModel};
use onepiece::ringbuf::{create_ring, RingConfig, RingConsumer, RingProducer};
use onepiece::transport::{
    AppId, MessageHeader, Payload, RdmaEndpoint, RingMetrics, StageId, WorkflowMessage,
};
use onepiece::util::{NodeId, SystemClock, Uid};
use std::sync::Arc;

/// Modelled host memcpy cost (≈4 GB/s effective single-core copy
/// bandwidth) charged per *critical-path* copied byte: eager pays its
/// frame-build and pop-out copies on the transfer path; the rendezvous
/// staging copy is the serialization ingress (the payload had to be
/// materialized into registered memory regardless) and stays off it.
const MEMCPY_NS_PER_BYTE: f64 = 0.25;

/// One payload-plane sample: modelled delivery ns/msg, payload bytes
/// copied per message, and one-sided payload reads per message.
struct PlaneSample {
    modelled_ns: f64,
    copied_per_msg: f64,
    reads_per_msg: f64,
    enc_len: usize,
}

/// Drive `rounds` send+recv cycles of one `payload_bytes` message over
/// an instrumented endpoint with the given rendezvous cutover
/// (0 = eager) and read the modelled cost back from the fabric's
/// simulated-ns counter plus the copy-accounting metrics.
fn measure_plane(payload_bytes: usize, threshold: usize, rounds: usize) -> PlaneSample {
    let fabric = Fabric::new(FabricConfig {
        latency: Some(LatencyModel::infiniband_100g()),
        ..Default::default()
    });
    let reg = Registry::new();
    let m = RingMetrics::from_registry(&reg);
    let mut ep = RdmaEndpoint::new(
        &fabric,
        RingConfig { nslots: 64, cap_bytes: 64 << 20, ..Default::default() },
    );
    ep.set_metrics(m.clone());
    let mut tx = ep.sender();
    tx.set_metrics(m.clone());
    tx.set_rendezvous_threshold(threshold);
    let msg = WorkflowMessage {
        header: MessageHeader {
            uid: Uid(1),
            ts_ns: 0,
            app: AppId(1),
            stage: StageId(0),
            origin: NodeId(0),
        },
        payload: Payload::Bytes(vec![0xAB; payload_bytes]),
    };
    let enc_len = msg.encode().len();

    // Warm up: fills the producer header cache and registers the slab.
    assert!(tx.send(&msg));
    assert!(ep.recv().is_some());
    let ns0 = fabric.simulated_ns();
    let copied0 = m.payload_bytes_copied.get();
    let reads0 = m.rendezvous_reads.get();
    for _ in 0..rounds {
        assert!(tx.send(&msg));
        assert!(ep.recv().is_some(), "modelled plane must deliver");
    }
    let n = rounds as f64;
    let copied_per_msg = (m.payload_bytes_copied.get() - copied0) as f64 / n;
    PlaneSample {
        modelled_ns: (fabric.simulated_ns() - ns0) as f64 / n
            + MEMCPY_NS_PER_BYTE
                * if threshold == 0 {
                    copied_per_msg
                } else {
                    0.0 // the staging copy is off the transfer path
                },
        copied_per_msg,
        reads_per_msg: (m.rendezvous_reads.get() - reads0) as f64 / n,
        enc_len,
    }
}

/// Modelled (ns_per_msg, verbs_per_msg) for `rounds` batches of `batch`
/// messages of `payload` bytes.
fn measure(payload: usize, batch: usize, cached: bool) -> (f64, f64) {
    let cfg = RingConfig {
        nslots: 1024,
        cap_bytes: 64 << 20,
        ..Default::default()
    };
    let fabric = Fabric::new(FabricConfig {
        latency: Some(LatencyModel::infiniband_100g()),
        ..Default::default()
    });
    let (id, region) = create_ring(&fabric, cfg);
    let prod = RingProducer::new(fabric.connect(id).unwrap(), cfg, Arc::new(SystemClock), 1);
    prod.set_caching(cached);
    let mut cons = RingConsumer::new(region, cfg);
    let msg = vec![7u8; payload];
    let refs: Vec<&[u8]> = (0..batch).map(|_| msg.as_slice()).collect();

    // Warm up (fills the producer cache when enabled).
    prod.push(&msg, None).unwrap();
    cons.pop().unwrap().unwrap();

    let rounds = 200usize;
    let ns0 = fabric.simulated_ns();
    let (ops0, _) = fabric.traffic();
    for _ in 0..rounds {
        if batch == 1 {
            prod.push(&msg, None).unwrap();
        } else {
            let out = prod.push_many(&refs, None).unwrap();
            assert_eq!(out.accepted, batch, "ring sized to fit the batch");
        }
        for r in cons.pop_many(batch) {
            r.unwrap();
        }
    }
    let msgs = (rounds * batch) as f64;
    let ns = (fabric.simulated_ns() - ns0) as f64 / msgs;
    let (ops1, _) = fabric.traffic();
    (ns, (ops1 - ops0) as f64 / msgs)
}

fn main() {
    let mut report = bench::Report::new("e15_ring_coalescing");
    println!("\n=== E15: modelled fabric time per message (2 µs/verb base) ===");
    println!(
        "{:<12} {:<14} {:>14} {:>12} {:>10}",
        "payload", "path", "ns/msg", "verbs/msg", "speedup"
    );

    for payload in [100usize, 1024, 16 << 10] {
        let (single_ns, single_verbs) = measure(payload, 1, false);
        let (cached_ns, cached_verbs) = measure(payload, 1, true);
        let mut rows = vec![
            ("single".to_string(), single_ns, single_verbs),
            ("cached".to_string(), cached_ns, cached_verbs),
        ];
        let mut batch8_ns = f64::INFINITY;
        for batch in [4usize, 8, 16] {
            let (ns, verbs) = measure(payload, batch, true);
            if batch == 8 {
                batch8_ns = ns;
            }
            rows.push((format!("push_many({batch})"), ns, verbs));
        }
        for (path, ns, verbs) in &rows {
            println!(
                "{:<12} {:<14} {:>12.0}ns {:>12.2} {:>9.2}x",
                format!("{payload} B"),
                path,
                ns,
                verbs,
                single_ns / ns
            );
            let key = path.replace('(', "_").replace(')', "");
            report.add(format!("{key}_{payload}b.ns_per_msg"), *ns);
            report.add(format!("{key}_{payload}b.verbs_per_msg"), *verbs);
        }
        let speedup = single_ns / batch8_ns;
        report.add(format!("speedup_batch8_{payload}b"), speedup);
        assert!(
            speedup >= 3.0,
            "{payload} B: push_many(8) must cut modelled fabric ns/msg ≥ 3x \
             vs per-message push (got {speedup:.2}x)"
        );
        println!();
    }
    println!("(push_many at batch 8 is ≥ 3x cheaper per message than per-message push)");

    // --- E15b: eager vs rendezvous payload plane (DESIGN.md §2) ---
    //
    // Modelled delivery cost = simulated fabric ns (verbs + line-rate
    // bytes) + memcpy ns for critical-path host copies. Eager moves the
    // payload through the ring (2 copies: frame build, pop out);
    // rendezvous moves a 40-byte descriptor and pulls the staged payload
    // with one one-sided READ (0 critical-path copies).
    println!("\n=== E15b: payload plane, eager vs rendezvous (modelled) ===");
    println!(
        "{:<12} {:>14} {:>14} {:>10} {:>14} {:>12}",
        "payload", "eager ns/msg", "rdv ns/msg", "rdv/eager", "eager cp B/msg", "rdv cp B/msg"
    );
    let threshold = 4 << 10; // force every swept size onto the staged plane
    let mut speedup_16m = 0.0;
    for &size in &[4 << 10, 64 << 10, 1 << 20, 16 << 20] {
        let rounds = if size >= 1 << 20 { 8 } else { 64 };
        let eager = measure_plane(size, 0, rounds);
        let rdv = measure_plane(size, threshold, rounds);
        let speedup = eager.modelled_ns / rdv.modelled_ns;
        println!(
            "{:<12} {:>11.0} ns {:>11.0} ns {:>9.2}x {:>14.0} {:>12.0}",
            format!("{} KiB", size / 1024),
            eager.modelled_ns,
            rdv.modelled_ns,
            speedup,
            eager.copied_per_msg,
            rdv.copied_per_msg
        );
        let kib = size / 1024;
        report.add(format!("eager_{kib}kib.modelled_ns_per_msg"), eager.modelled_ns);
        report.add(format!("eager_{kib}kib.bytes_copied_per_msg"), eager.copied_per_msg);
        report.add(format!("rdv_{kib}kib.modelled_ns_per_msg"), rdv.modelled_ns);
        report.add(format!("rdv_{kib}kib.bytes_copied_per_msg"), rdv.copied_per_msg);
        report.add(format!("rdv_over_eager_{kib}kib"), speedup);

        // Zero-copy signature, asserted at every size: exactly one
        // staging copy and one one-sided READ per rendezvous message,
        // vs two full copies per eager message.
        assert_eq!(
            rdv.copied_per_msg, rdv.enc_len as f64,
            "{kib} KiB: rendezvous must pay exactly one staging copy"
        );
        assert_eq!(
            rdv.reads_per_msg, 1.0,
            "{kib} KiB: exactly one one-sided READ per message"
        );
        assert_eq!(eager.copied_per_msg, 2.0 * eager.enc_len as f64);
        if size == 16 << 20 {
            speedup_16m = speedup;
        }
    }
    assert!(
        speedup_16m >= 4.0,
        "16 MiB: rendezvous must cut modelled delivery ns/msg ≥ 4x vs eager \
         (got {speedup_16m:.2}x)"
    );
    println!("(rendezvous at 16 MiB is ≥ 4x cheaper per message than eager)");
    report.write();
}
