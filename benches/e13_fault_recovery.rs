//! E13 — worker-instance fault recovery: goodput dip and
//! time-to-recover under periodic instance kills.
//!
//! Setup: one Workflow Set with the failure detector on (150 ms
//! heartbeat silence), a steady offered stream with a 3-attempt
//! `RetryPolicy` (original dispatch + 2 crash replays), and a crash
//! injector killing the diffusion instance once per MTBF period. Each
//! kill is followed by `add_idle_instance` (the operator replacing the
//! dead hardware) so the idle pool never starves across rounds.
//!
//! Reported per MTBF:
//! - goodput per 250 ms bucket → steady-state goodput, the post-kill
//!   **dip** (worst bucket), and **time-to-recover** (buckets until
//!   goodput is back above 80% of steady state);
//! - `instances_failed` / `requests_recovered` / `requests_failed`
//!   counters and the `recovery_latency_ns` histogram (detector delay +
//!   replay, what a stranded request actually waited).
//!
//! Run: `cargo bench --bench e13_fault_recovery`

use onepiece::client::{Gateway, RequestHandle, RetryPolicy, SubmitOptions, WaitOutcome};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind};
use onepiece::nm::StageKey;
use onepiece::transport::{AppId, Payload};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

const BUCKET: Duration = Duration::from_millis(250);
const RUN: Duration = Duration::from_secs(4);

fn fault_config() -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    let stage_ms = [5.0, 1.0, 8.0, 1.0];
    for (s, &ms) in cfg.apps[0].stages.iter_mut().zip(&stage_ms) {
        s.exec = ExecModel::Simulated { ms };
        s.exec_ms = ms;
    }
    cfg.apps[0].stages[2].mode = onepiece::config::SchedMode::Individual;
    cfg.nm.heartbeat_ms = 10; // housekeeper sweeps every ~50 ms
    cfg.nm.instance_timeout_ms = 150;
    cfg.idle_pool = 1;
    cfg
}

struct Outcome {
    buckets: Vec<u64>,
    admitted: u64,
    done: u64,
    failed: u64,
    kills: u64,
}

fn run_one(mtbf: Option<Duration>) -> (Outcome, WorkflowSet) {
    let cfg = fault_config();
    let pool = build_pool(&cfg, None);
    // Two diffusion instances: one survives each kill, so goodput dips
    // instead of flatlining while the detector runs.
    let mut set = WorkflowSet::build(
        cfg,
        vec![vec![1, 1, 2, 1]],
        Arc::new(EchoLogic),
        pool,
    );
    std::thread::sleep(Duration::from_millis(100));

    let opts = SubmitOptions::default()
        .with_retry(RetryPolicy::attempts(3, Duration::ZERO));
    let offered_interval = Duration::from_millis(10); // 100 req/s offered
    let diffusion = StageKey { app: AppId(1), stage: 2 };
    let n_buckets = (RUN.as_millis() / BUCKET.as_millis()) as usize + 1;
    let mut out = Outcome {
        buckets: vec![0u64; n_buckets + 60], // slack for the drain tail
        admitted: 0,
        done: 0,
        failed: 0,
        kills: 0,
    };
    let mut pending: Vec<RequestHandle> = Vec::new();
    let t0 = Instant::now();
    let mut next_kill = mtbf;

    let drain = |pending: &mut Vec<RequestHandle>,
                 out: &mut Outcome,
                 t0: Instant| {
        pending.retain(|h| match h.status() {
            onepiece::client::RequestStatus::Done => {
                out.done += 1;
                let b = (t0.elapsed().as_millis() / BUCKET.as_millis()) as usize;
                if b < out.buckets.len() {
                    out.buckets[b] += 1;
                }
                false
            }
            onepiece::client::RequestStatus::Failed => {
                out.failed += 1;
                false
            }
            s => !s.is_terminal(),
        });
    };

    while t0.elapsed() < RUN {
        if let (Some(kill_at), Some(m)) = (next_kill, mtbf) {
            if t0.elapsed() >= kill_at {
                if set.inject_crash_at_stage(diffusion).is_some() {
                    out.kills += 1;
                    // Operator replaces the dead hardware: refill the
                    // idle pool so the *next* kill also has a donor.
                    set.add_idle_instance();
                }
                next_kill = Some(kill_at + m);
            }
        }
        if let Ok(h) = set.submit_with(AppId(1), Payload::Bytes(vec![7; 32]), opts) {
            out.admitted += 1;
            pending.push(h);
        }
        drain(&mut pending, &mut out, t0);
        std::thread::sleep(offered_interval);
    }
    // Drain the tail to terminal states (recovery may still be running).
    let drain_deadline = Instant::now() + Duration::from_secs(10);
    while !pending.is_empty() && Instant::now() < drain_deadline {
        drain(&mut pending, &mut out, t0);
        std::thread::sleep(Duration::from_millis(5));
    }
    for h in pending {
        match h.wait(Duration::from_secs(5)) {
            WaitOutcome::Done(_) => out.done += 1,
            WaitOutcome::Failed => out.failed += 1,
            _ => {}
        }
    }
    (out, set)
}

fn main() {
    let mut report = onepiece::bench::Report::new("e13_fault_recovery");
    println!("=== E13: fault recovery under periodic instance kills ===");
    println!(
        "offered 100 req/s | diffusion 2 instances, 8 ms | detector timeout \
         150 ms | retry budget 3 attempts\n"
    );
    println!(
        "{:<12} {:>9} {:>7} {:>7} {:>7} {:>12} {:>10} {:>14} {:>16}",
        "MTBF", "admitted", "done", "failed", "kills", "steady (r/s)",
        "dip (r/s)", "recover (ms)", "replay p50 (ms)"
    );
    for mtbf in [None, Some(Duration::from_millis(1500)), Some(Duration::from_millis(750))]
    {
        let (out, set) = run_one(mtbf);
        let m = set.metrics();
        // Steady state: the best bucket of the healthy warm-up second.
        let per_bucket_rate = 1.0 / BUCKET.as_secs_f64();
        let live = &out.buckets;
        let n_run = (RUN.as_millis() / BUCKET.as_millis()) as usize;
        let steady = live[..4].iter().copied().max().unwrap_or(0) as f64 * per_bucket_rate;
        // Dip: worst bucket after the first kill (skip warm-up buckets).
        let (dip, recover_ms) = (|| {
            if out.kills == 0 {
                return (steady, 0.0);
            }
            let from = (mtbf.unwrap().as_millis() / BUCKET.as_millis()) as usize;
            let end = n_run.min(live.len());
            let window = &live[from.min(end)..end];
            let Some(dip_idx) = window
                .iter()
                .enumerate()
                .min_by_key(|(_, v)| **v)
                .map(|(i, _)| i)
            else {
                return (steady, 0.0);
            };
            let dip = window[dip_idx] as f64 * per_bucket_rate;
            let recover_buckets = window[dip_idx..]
                .iter()
                .position(|&v| v as f64 * per_bucket_rate >= 0.8 * steady)
                .unwrap_or(window.len() - dip_idx);
            (dip, recover_buckets as f64 * BUCKET.as_millis() as f64)
        })();
        let lat = m.histogram("recovery_latency_ns").snapshot();
        println!(
            "{:<12} {:>9} {:>7} {:>7} {:>7} {:>12.0} {:>10.0} {:>14.0} {:>16.1}",
            mtbf.map_or("none".into(), |d| format!("{} ms", d.as_millis())),
            out.admitted,
            out.done,
            out.failed,
            out.kills,
            steady,
            dip,
            recover_ms,
            lat.p50 as f64 / 1e6,
        );
        // Shape assertions: every kill is detected, recovery replays
        // work, and nothing hangs (admitted = done + failed).
        if out.kills > 0 {
            assert!(
                m.counter("instances_failed").get() >= out.kills,
                "every kill must be detected"
            );
            assert!(
                m.counter("requests_recovered").get() >= 1,
                "stranded requests must be replayed"
            );
        }
        assert!(
            out.done + out.failed >= out.admitted,
            "every admitted request must reach a terminal state \
             (admitted {}, done {}, failed {})",
            out.admitted,
            out.done,
            out.failed
        );
        let key = mtbf.map_or("healthy".into(), |d| format!("mtbf{}", d.as_millis()));
        report
            .add(format!("{key}.steady_rps"), steady)
            .add(format!("{key}.dip_rps"), dip)
            .add(format!("{key}.recover_ms"), recover_ms)
            .add(format!("{key}.failed"), out.failed as f64)
            .add(format!("{key}.replay_p50_ms"), lat.p50 as f64 / 1e6);
        set.shutdown();
    }
    report.write();
    println!(
        "\nshape: goodput dips for roughly one detector timeout + replay \
         round after each kill, then returns to steady state; halving MTBF \
         doubles the dips but recovery time per incident stays flat"
    );
}
