//! E1 + E11 — the headline claim: GPU-resource reduction of the
//! disaggregated OnePiece deployment vs the monolithic baseline for the
//! Wan2.1-style I2V pipeline (paper: **16×**; conclusion text says 16%),
//! plus the §1 Triton-style throughput comparison at a fixed fleet size.
//!
//! Sweeps mean load and burstiness; prints the resource-consumption
//! ratio curve so the crossover structure is visible, not just one point.

use onepiece::bench::Report;
use onepiece::sim::{
    simulate_disaggregated, simulate_monolithic, wan_stages, ArrivalProcess,
    ResourceSimConfig,
};

fn cfg(duration_s: f64) -> ResourceSimConfig {
    ResourceSimConfig {
        stages: wan_stages(),
        monolithic_gpus: 8,
        rescale_period_s: 10.0,
        demand_window_s: 30.0,
        duration_s,
    }
}

fn main() {
    println!("=== E1: GPU resource consumption, monolithic vs OnePiece ===");
    println!("pipeline: t5_clip 1s | vae_enc 0.5s | diffusion 12s(4 GPU) | vae_dec 1.5s");
    println!("monolithic replica pins 8 GPUs end-to-end; fleet sized for peak\n");

    println!(
        "{:<26} {:>10} {:>12} {:>12} {:>8} {:>9} {:>9}",
        "workload", "peak(rps)", "mono GPU-h", "1p GPU-h", "ratio", "mono util", "1p util"
    );
    let c = cfg(3600.0);
    let mut ratios = Vec::new();
    for (name, process) in [
        (
            "diurnal 16:1 p=0.25",
            ArrivalProcess::Diurnal { base_rps: 0.25 / 16.0, peak_rps: 0.25, period_s: 600.0 },
        ),
        (
            "diurnal 16:1 p=0.5",
            ArrivalProcess::Diurnal { base_rps: 0.5 / 16.0, peak_rps: 0.5, period_s: 600.0 },
        ),
        (
            "diurnal 16:1 p=1",
            ArrivalProcess::Diurnal { base_rps: 1.0 / 16.0, peak_rps: 1.0, period_s: 600.0 },
        ),
        (
            "diurnal 16:1 p=2",
            ArrivalProcess::Diurnal { base_rps: 2.0 / 16.0, peak_rps: 2.0, period_s: 600.0 },
        ),
        (
            "bursty mmpp 10:1 p=1",
            ArrivalProcess::Mmpp { low_rps: 0.1, high_rps: 1.0, mean_dwell_s: 120.0 },
        ),
        ("steady poisson 0.5", ArrivalProcess::Poisson { rate_rps: 0.5 }),
        ("steady poisson 1.0", ArrivalProcess::Poisson { rate_rps: 1.0 }),
    ] {
        let mono = simulate_monolithic(&c, &process, 42);
        let dis = simulate_disaggregated(&c, &process, 42);
        let ratio = mono.gpu_s_provisioned / dis.gpu_s_provisioned;
        ratios.push((name, ratio));
        println!(
            "{:<26} {:>10.2} {:>12.1} {:>12.1} {:>7.1}x {:>8.1}% {:>8.1}%",
            name,
            process.peak_rps(),
            mono.gpu_s_provisioned / 3600.0,
            dis.gpu_s_provisioned / 3600.0,
            ratio,
            mono.utilization * 100.0,
            dis.utilization * 100.0,
        );
    }

    let max = ratios
        .iter()
        .cloned()
        .fold(("", 0.0f64), |a, b| if b.1 > a.1 { b } else { a });
    println!(
        "\nmax provisioned-vs-provisioned reduction: {:.1}x on '{}' \
         (shape: disaggregation wins everywhere, margin grows with burstiness)",
        max.1, max.0
    );
    let mut report = Report::new("e1_gpu_resource");
    report.add("max_provisioned_ratio", max.1);
    let min = ratios
        .iter()
        .cloned()
        .fold(("", f64::INFINITY), |a, b| if b.1 < a.1 { b } else { a });
    report.add("min_provisioned_ratio", min.1);

    // --- the paper's accounting: §8.2/§4.2 let OnePiece's idle instances
    // be repurposed for lower-priority work (model training), so the
    // GPU time *dedicated to inference* is its busy time; a monolithic
    // 8-GPU replica can repurpose nothing. Under a flash-crowd workload
    // with peak:mean ≈ 16:1 (the regime that motivates elastic serving),
    // this is where the headline 16x lives. ---
    println!("\n=== E1b: inference-dedicated GPU-time (idle OnePiece GPUs repurposed, §8.2) ===");
    println!(
        "{:<30} {:>12} {:>12} {:>8}",
        "workload", "mono GPU-h", "1p GPU-h", "ratio"
    );
    for (name, process) in [
        (
            "flash-crowd 16:1 duty=1/16",
            ArrivalProcess::Spike { base_rps: 0.02, peak_rps: 1.6, duty: 1.0 / 16.0, period_s: 900.0 },
        ),
        (
            "flash-crowd 32:1 duty=1/32",
            ArrivalProcess::Spike { base_rps: 0.01, peak_rps: 1.6, duty: 1.0 / 32.0, period_s: 900.0 },
        ),
        (
            "diurnal 16:1 p=1",
            ArrivalProcess::Diurnal { base_rps: 1.0 / 16.0, peak_rps: 1.0, period_s: 600.0 },
        ),
    ] {
        let mono = simulate_monolithic(&c, &process, 42);
        let dis = simulate_disaggregated(&c, &process, 42);
        // OnePiece dedicates: busy time + the small always-on entrance
        // floor (1 instance/stage while idle instances train).
        let dis_dedicated = dis.gpu_s_busy;
        println!(
            "{:<30} {:>12.1} {:>12.1} {:>7.1}x",
            name,
            mono.gpu_s_provisioned / 3600.0,
            dis_dedicated / 3600.0,
            mono.gpu_s_provisioned / dis_dedicated
        );
    }
    println!("(paper: 16x for Wan2.1 I2V — reproduced in shape; the exact factor is the workload's peak:mean ratio)");

    // E11: throughput at a FIXED fleet (64 GPUs), the Triton-style 2.4x.
    println!("\n=== E11: throughput at fixed 64-GPU fleet (Triton reference: 2.4x) ===");
    // Monolithic: 64/8 = 8 replicas; capacity 8 / 15 s.
    let mono_tp = 8.0 / 15.0;
    // OnePiece: balanced Theorem-1 shares — r * sum(T_i * G_i) <= 64.
    let gpu_s_per_req: f64 = wan_stages()
        .iter()
        .map(|s| s.exec_s * s.gpus_per_instance as f64)
        .sum();
    let one_tp = 64.0 / gpu_s_per_req;
    println!(
        "monolithic: {mono_tp:.3} req/s   onepiece: {one_tp:.3} req/s   ratio: {:.2}x",
        one_tp / mono_tp
    );
    println!("(paper's Ant Group reference reports 2.4x from the same mechanism: no idle pinned GPUs)");
    report.add("fixed_fleet_throughput_ratio", one_tp / mono_tp);
    report.write();
}
