//! E11 — multi-set federation vs a single Workflow Set (§3.1–§3.2 + the
//! federation layer): at identical offered load, N federated sets reject
//! less traffic than one set, the load-aware router spills less and
//! balances better than the paper's client-side random retry, and
//! elastic cross-set donation moves capacity toward skewed demand.
//!
//! Modelled (discrete-event) results — the real-stack analogue is
//! `onepiece federate --sets 3 --sim`.

use onepiece::sim::{simulate_federation, ArrivalProcess, FedPolicy, FedSimConfig};

const CAPACITY_PER_SET: f64 = 10.0;
const DURATION_S: f64 = 600.0;
const SEED: u64 = 17;

fn row(name: &str, out: &onepiece::sim::FedSimOutcome) {
    println!(
        "{:<26} {:>8} {:>8} {:>8.1}% {:>8} {:>6} {:>9.1}s {:>9.1}s  {:?}",
        name,
        out.offered,
        out.admitted,
        out.reject_rate() * 100.0,
        out.spilled,
        out.donations,
        out.p50_latency_s,
        out.p99_latency_s,
        out.per_set_admitted
    );
}

fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<26} {:>8} {:>8} {:>9} {:>8} {:>6} {:>10} {:>10}  per-set",
        "fleet", "offered", "admit", "reject", "spill", "don.", "p50", "p99"
    );
}

fn main() {
    let mut report = onepiece::bench::Report::new("e11_federation");
    // --- 1. Reject rate at identical offered load: 1 set vs 3 sets ---
    header("E11a: 1 set vs 3-set federation, identical offered load");
    for mult in [0.8, 1.5, 2.5] {
        let offered = ArrivalProcess::Poisson { rate_rps: CAPACITY_PER_SET * mult };
        let single = simulate_federation(
            &FedSimConfig::balanced(1, CAPACITY_PER_SET, DURATION_S),
            &offered,
            SEED,
        );
        let fed = simulate_federation(
            &FedSimConfig::balanced(3, CAPACITY_PER_SET, DURATION_S),
            &offered,
            SEED,
        );
        row(&format!("1 set @ {mult:.1}x"), &single);
        row(&format!("3-set federation @ {mult:.1}x"), &fed);
        assert!(
            fed.reject_rate() <= single.reject_rate(),
            "federation must not reject more than a single set at equal load"
        );
        report
            .add(format!("single.reject_rate.x{mult}"), single.reject_rate())
            .add(format!("fed3.reject_rate.x{mult}"), fed.reject_rate())
            .add(format!("fed3.p99_s.x{mult}"), fed.p99_latency_s);
    }

    // --- 2. Routing policy under regional skew ---
    header("E11b: routing policy, 3 sets, skewed clients, 2x one set's load");
    let offered = ArrivalProcess::Poisson { rate_rps: CAPACITY_PER_SET * 2.0 };
    let mut cfg = FedSimConfig::balanced(3, CAPACITY_PER_SET, DURATION_S);
    cfg.skew = 4.0;
    cfg.policy = FedPolicy::RandomSpill;
    let random = simulate_federation(&cfg, &offered, SEED);
    cfg.policy = FedPolicy::LoadAware;
    let load_aware = simulate_federation(&cfg, &offered, SEED);
    row("random retry (paper 3.2)", &random);
    row("load-aware router", &load_aware);
    println!(
        "balance (max-min admitted): random {} vs load-aware {}",
        random.admitted_spread(),
        load_aware.admitted_spread()
    );

    // --- 3. Elastic donation under bursty + skewed load ---
    header("E11c: elastic donation, MMPP bursts, affinity-pinned clients");
    let bursty = ArrivalProcess::Mmpp {
        low_rps: CAPACITY_PER_SET,
        high_rps: CAPACITY_PER_SET * 2.5,
        mean_dwell_s: 30.0,
    };
    let mut cfg = FedSimConfig::balanced(3, CAPACITY_PER_SET, DURATION_S);
    cfg.skew = 4.0;
    cfg.policy = FedPolicy::RandomSpill;
    let frozen = simulate_federation(&cfg, &bursty, SEED);
    cfg.elastic = true;
    let elastic = simulate_federation(&cfg, &bursty, SEED);
    row("static capacity", &frozen);
    row("elastic donation", &elastic);
    report
        .add("skew.random.spilled", random.spilled as f64)
        .add("skew.load_aware.spilled", load_aware.spilled as f64)
        .add("skew.random.spread", random.admitted_spread() as f64)
        .add("skew.load_aware.spread", load_aware.admitted_spread() as f64)
        .add("elastic.donations", elastic.donations as f64)
        .add("elastic.spilled", elastic.spilled as f64)
        .add("static.spilled", frozen.spilled as f64);
    report.write();

    println!(
        "\nshape: federation turns a hard per-set capacity wall into a fleet-wide \
         one (rejects only when every set is full); load-aware routing removes \
         the spill/imbalance cost of random retry; donation re-homes idle \
         capacity under skew."
    );
}
