//! E6 — double-ring buffer microbenchmarks: throughput/latency under
//! multi-producer contention, message-size sweep, consumer wait-freedom,
//! and the timeout-vs-corruption trade-off the paper argues in §6.1
//! ("thanks to the short timeout interval, obsolete updates can corrupt
//! at most one subsequent data entry").

use onepiece::bench;
use onepiece::rdma::Fabric;
use onepiece::ringbuf::{create_ring, PushError, RingConfig, RingConsumer, RingProducer};
use onepiece::util::{Rng, SystemClock};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn main() {
    let mut report = bench::Report::new("e5_ringbuf");
    // --- size sweep, single producer ---
    bench::header("E6a: push+pop per message (1 producer)");
    for size in [64usize, 1024, 16 << 10, 256 << 10] {
        let cfg = RingConfig { nslots: 256, cap_bytes: 32 << 20, ..Default::default() };
        let fabric = Fabric::ideal();
        let (id, region) = create_ring(&fabric, cfg);
        let prod = RingProducer::new(fabric.connect(id).unwrap(), cfg, Arc::new(SystemClock), 1);
        let mut cons = RingConsumer::new(region, cfg);
        let payload = vec![7u8; size];
        let r = bench::quick(&format!("msg {:>7} B", size), || {
            prod.push(&payload, None).unwrap();
            cons.pop().unwrap().unwrap();
        });
        report.add_result(&format!("push_pop_{size}b"), &r);
    }

    // --- contention sweep: N producer threads, 1 consumer ---
    bench::header("E6b: aggregate throughput under producer contention");
    for nprod in [1usize, 2, 4, 8] {
        let cfg = RingConfig {
            nslots: 1024,
            cap_bytes: 8 << 20,
            // Timeout must dwarf worst-case lock-hold time: on a
            // preempted host a holder can be descheduled for tens of ms,
            // and a "steal" from a *live* holder is exactly the Case-2..6
            // corruption path (detected, but noisy for a clean bench).
            lock_timeout_ns: 2_000_000_000,
            max_lock_spins: 1 << 22,
        };
        let fabric = Fabric::ideal();
        let (id, region) = create_ring(&fabric, cfg);
        let stop = Arc::new(AtomicBool::new(false));
        let handles: Vec<_> = (0..nprod)
            .map(|p| {
                let qp = fabric.connect(id).unwrap();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let prod = RingProducer::new(qp, cfg, Arc::new(SystemClock), p as u64 + 1);
                    let payload = vec![p as u8; 256];
                    let mut sent = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        match prod.push(&payload, None) {
                            Ok(_) => sent += 1,
                            Err(PushError::Full) | Err(PushError::LostRace) => {
                                std::thread::yield_now()
                            }
                            Err(e) => panic!("{e:?}"),
                        }
                    }
                    sent
                })
            })
            .collect();

        let mut cons = RingConsumer::new(region, cfg);
        let t0 = std::time::Instant::now();
        let mut got = 0u64;
        let mut corrupted = 0u64;
        while t0.elapsed() < std::time::Duration::from_millis(500) {
            match cons.pop() {
                Some(Ok(_)) => got += 1,
                // Possible only if a holder is descheduled past the
                // timeout (host preemption) — detected, bounded, counted.
                Some(Err(_)) => corrupted += 1,
                None => std::thread::yield_now(),
            }
        }
        assert!(corrupted < got / 100 + 10, "corruption must be rare");
        stop.store(true, Ordering::Relaxed);
        let sent: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        println!(
            "{:<44} {:>10.2} Mmsg/s consumed ({} sent)",
            format!("producers={nprod} msg=256B"),
            got as f64 / t0.elapsed().as_secs_f64() / 1e6,
            sent
        );
        report.add(
            format!("contended_msgs_per_sec_p{nprod}"),
            got as f64 / t0.elapsed().as_secs_f64(),
        );
    }

    // --- consumer wait-freedom: pop cost with a dead lock-holder ---
    bench::header("E6c: consumer wait-freedom under producer failure");
    {
        let cfg = RingConfig { nslots: 64, cap_bytes: 1 << 20, ..Default::default() };
        let fabric = Fabric::ideal();
        let (id, region) = create_ring(&fabric, cfg);
        let prod = RingProducer::new(fabric.connect(id).unwrap(), cfg, Arc::new(SystemClock), 1);
        for _ in 0..32 {
            prod.push(&[1u8; 128], None).unwrap();
        }
        // A second producer dies holding the lock.
        let dead = RingProducer::new(fabric.connect(id).unwrap(), cfg, Arc::new(SystemClock), 2);
        let _session = dead.begin().unwrap(); // never released
        let mut cons = RingConsumer::new(region, cfg);
        let mut n = 0;
        bench::quick("pop with dead lock-holder", || {
            if let Some(r) = cons.pop() {
                r.unwrap();
                n += 1;
            }
        });
        assert!(n >= 32, "consumer must drain everything despite the dead producer");
    }

    // --- timeout vs corruption probability (the §6.1 trade-off) ---
    bench::header("E6d: lock-timeout vs corruption (10k messages, 5% stale writers)");
    for timeout_ns in [1_000u64, 10_000, 100_000, 1_000_000] {
        let cfg = RingConfig {
            nslots: 128,
            cap_bytes: 4 << 20,
            lock_timeout_ns: timeout_ns,
            max_lock_spins: 4096,
        };
        let fabric = Fabric::ideal();
        let (id, region) = create_ring(&fabric, cfg);
        let clock = onepiece::util::ManualClock::new();
        clock.set(1);
        let mk = |pid| {
            RingProducer::new(
                fabric.connect(id).unwrap(),
                cfg,
                Arc::new(clock.clone()),
                pid,
            )
        };
        let healthy = mk(1);
        let mut cons = RingConsumer::new(region, cfg);
        let mut rng = Rng::new(timeout_ns);
        let (mut ok, mut corrupted, mut steals) = (0u64, 0u64, 0u64);
        for i in 0..10_000u64 {
            if rng.f64() < 0.05 {
                // A writer dies mid-protocol at a random point.
                let victim = mk(100 + i);
                let die = match rng.below(3) {
                    0 => onepiece::ringbuf::DieAt::AfterLock,
                    1 => onepiece::ringbuf::DieAt::AfterWb,
                    _ => onepiece::ringbuf::DieAt::AfterWl,
                };
                let _ = victim.push(&[9u8; 64], Some(die));
                clock.advance(timeout_ns + 1); // next push steals
            }
            clock.advance(100);
            match healthy.push(&[(i % 251) as u8; 64], None) {
                Ok(out) => {
                    if out.stole_lock {
                        steals += 1;
                    }
                }
                Err(PushError::Full) => {}
                Err(e) => panic!("{e:?}"),
            }
            while let Some(r) = cons.pop() {
                match r {
                    Ok(_) => ok += 1,
                    Err(_) => corrupted += 1,
                }
            }
        }
        println!(
            "{:<44} {:>8} ok {:>6} corrupted {:>6} steals",
            format!("timeout={} µs", timeout_ns / 1000),
            ok,
            corrupted,
            steals
        );
    }
    println!("\n(corruption stays bounded regardless of timeout: blast radius is one entry)");
    report.write();
}
