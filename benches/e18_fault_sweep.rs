//! E18 — fabric fault sweep: goodput under injected verb loss and a
//! directed partition-and-heal, versus the fault-free baseline.
//!
//! Setup: one Workflow Set on the ideal fabric with the `faults` config
//! block sweeping seeded verb-loss probability {0, 1%, 5%}, plus one row
//! that adds a directed node-pair partition cut at t=1 s and healed at
//! t=2 s. A steady offered stream carries a 3-attempt `RetryPolicy`, so
//! verbs lost beyond the verb-retry budget resolve through checkpoint
//! replay rather than hanging.
//!
//! Reported per row: admitted/done/failed, goodput, and the fault-plane
//! counters (`verbs_lost`, `verb_retries`, `partitioned_ops`).
//!
//! Shape asserted: the fault-free row finishes with *zero* fault
//! counters and full goodput; every faulted row keeps a bounded goodput
//! dip (no collapse, no hangs: admitted = done + failed) and shows
//! non-zero loss + retry counters; the partition row also counts
//! rejected verbs on the victim links and drains after the heal.
//!
//! Run: `cargo bench --bench e18_fault_sweep`

use onepiece::client::{Gateway, RequestHandle, RetryPolicy, SubmitOptions, WaitOutcome};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind, FaultSettings};
use onepiece::rdma::FaultStats;
use onepiece::transport::{AppId, Payload};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

const RUN: Duration = Duration::from_secs(3);

fn sweep_config(loss: f64) -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 1.0 };
        s.exec_ms = 1.0;
    }
    cfg.nm.heartbeat_ms = 10;
    cfg.nm.instance_timeout_ms = 150;
    cfg.idle_pool = 1;
    if loss > 0.0 {
        cfg.faults = Some(FaultSettings {
            verb_loss_prob: loss,
            ..Default::default()
        });
    }
    cfg
}

struct Outcome {
    admitted: u64,
    done: u64,
    failed: u64,
    wall_s: f64,
    stats: Option<FaultStats>,
}

fn run_one(loss: f64, partition: bool) -> Outcome {
    let cfg = sweep_config(loss);
    let pool = build_pool(&cfg, None);
    let set = WorkflowSet::build(cfg, vec![vec![1, 1, 1, 1]], Arc::new(EchoLogic), pool);
    std::thread::sleep(Duration::from_millis(100));

    let opts = SubmitOptions::default()
        .with_retry(RetryPolicy::attempts(3, Duration::ZERO));
    let mut out = Outcome {
        admitted: 0,
        done: 0,
        failed: 0,
        wall_s: 0.0,
        stats: None,
    };
    let mut pending: Vec<RequestHandle> = Vec::new();
    let drain = |pending: &mut Vec<RequestHandle>, out: &mut Outcome| {
        pending.retain(|h| match h.status() {
            onepiece::client::RequestStatus::Done => {
                out.done += 1;
                false
            }
            onepiece::client::RequestStatus::Failed => {
                out.failed += 1;
                false
            }
            s => !s.is_terminal(),
        });
    };
    let t0 = Instant::now();
    let mut cut = false;
    let mut healed = false;
    while t0.elapsed() < RUN {
        if partition && !cut && t0.elapsed() >= Duration::from_secs(1) {
            set.fabric.start_partition(4, 1);
            cut = true;
        }
        if partition && cut && !healed && t0.elapsed() >= Duration::from_secs(2) {
            set.fabric.heal_partition();
            healed = true;
        }
        if let Ok(h) = set.submit_with(AppId(1), Payload::Bytes(vec![7; 32]), opts) {
            out.admitted += 1;
            pending.push(h);
        }
        drain(&mut pending, &mut out);
        std::thread::sleep(Duration::from_millis(10)); // 100 req/s offered
    }
    if cut && !healed {
        set.fabric.heal_partition();
    }
    let drain_deadline = Instant::now() + Duration::from_secs(10);
    while !pending.is_empty() && Instant::now() < drain_deadline {
        drain(&mut pending, &mut out);
        std::thread::sleep(Duration::from_millis(5));
    }
    for h in pending {
        match h.wait(Duration::from_secs(5)) {
            WaitOutcome::Done(_) => out.done += 1,
            WaitOutcome::Failed => out.failed += 1,
            _ => {}
        }
    }
    out.wall_s = t0.elapsed().as_secs_f64();
    set.sync_fault_counters();
    out.stats = set.fault_stats();
    set.shutdown();
    out
}

fn main() {
    let mut report = onepiece::bench::Report::new("e18_fault_sweep");
    println!("=== E18: goodput under injected fabric faults ===");
    println!(
        "offered 100 req/s | 4-stage simulated pipeline | verb-retry budget 4 \
         attempts/5 ms | request retry budget 3 attempts\n"
    );
    println!(
        "{:<18} {:>9} {:>7} {:>7} {:>12} {:>11} {:>13} {:>13}",
        "row", "admitted", "done", "failed", "goodput(r/s)", "verbs_lost",
        "verb_retries", "partitioned"
    );
    let rows: [(f64, bool); 4] =
        [(0.0, false), (0.01, false), (0.05, false), (0.01, true)];
    let mut baseline_goodput = 0.0;
    for (loss, partition) in rows {
        let out = run_one(loss, partition);
        let goodput = out.done as f64 / out.wall_s;
        let s = out.stats.unwrap_or_default();
        let label = if partition {
            format!("loss {loss} + cut")
        } else {
            format!("loss {loss}")
        };
        println!(
            "{:<18} {:>9} {:>7} {:>7} {:>12.0} {:>11} {:>13} {:>13}",
            label, out.admitted, out.done, out.failed, goodput, s.verbs_lost,
            s.verb_retries, s.partitioned_ops
        );
        assert!(
            out.done + out.failed == out.admitted,
            "every admitted request must reach a terminal state \
             (admitted {}, done {}, failed {})",
            out.admitted,
            out.done,
            out.failed
        );
        if loss == 0.0 && !partition {
            baseline_goodput = goodput;
            assert!(
                out.stats.is_none(),
                "no faults block: no fault state may be allocated"
            );
            assert_eq!(out.failed, 0, "the healthy baseline must not fail requests");
        } else {
            assert!(s.verbs_lost >= 1, "{label}: loss injection must fire");
            assert!(s.verb_retries >= 1, "{label}: lost verbs must be retried");
            assert!(
                goodput >= 0.5 * baseline_goodput,
                "{label}: the goodput dip must stay bounded \
                 ({goodput:.0} vs baseline {baseline_goodput:.0} r/s)"
            );
            if partition {
                assert!(
                    s.partitioned_ops >= 1,
                    "the partition window must reject verbs on victim links"
                );
            }
        }
        let key = if partition {
            format!("loss{}_cut", (loss * 100.0) as u64)
        } else {
            format!("loss{}", (loss * 100.0) as u64)
        };
        report
            .add(format!("{key}.goodput_rps"), goodput)
            .add(format!("{key}.failed"), out.failed as f64)
            .add(format!("{key}.verbs_lost"), s.verbs_lost as f64)
            .add(format!("{key}.verb_retries"), s.verb_retries as f64)
            .add(format!("{key}.partitioned_ops"), s.partitioned_ops as f64);
    }
    report.write();
    println!(
        "\nshape: the verb-retry layer absorbs 1% loss with a flat goodput \
         curve; 5% loss spends visibly more retries for a still-bounded dip; \
         the partition row sheds only during the cut window and drains fully \
         after the heal"
    );
}
