//! E13 — §8.1 Paxos primary election: failover decision latency across
//! replica-set sizes, plus a safety demonstration with concurrent
//! candidates (at most one winner per term — always).

use onepiece::bench;
use onepiece::nm::NmCluster;
use onepiece::util::{ManualClock, NodeId};
use std::sync::Arc;

fn main() {
    let mut report = bench::Report::new("e10_election");
    bench::header("E13a: election latency vs replica-set size");
    for n in [3u32, 5, 7, 9] {
        let clock = ManualClock::new();
        let cluster = NmCluster::new(
            (0..n).map(NodeId).collect(),
            Arc::new(clock.clone()),
            1_000,
        );
        let mut term_candidate = 1u32;
        let r = bench::quick(&format!("replicas={n}"), || {
            term_candidate = (term_candidate + 1) % n;
            cluster.elect(NodeId(term_candidate)).unwrap();
        });
        report.add_result(&format!("election_r{n}"), &r);
    }

    println!("\n=== E13b: failover walkthrough ===");
    let clock = ManualClock::new();
    let cluster = NmCluster::new((0..5).map(NodeId).collect(), Arc::new(clock.clone()), 1_000);
    let p = cluster.elect(NodeId(0)).unwrap();
    println!("initial primary: {p} (term {})", cluster.term());
    cluster.set_alive(NodeId(0), false);
    clock.advance(2_000);
    assert!(cluster.primary_lost(), "heartbeat timeout must be detected");
    let p2 = cluster.elect(NodeId(3)).unwrap();
    println!("after primary death + timeout: new primary {p2} (term {})", cluster.term());
    assert_ne!(p2, NodeId(0));

    println!("\n=== E13c: safety under concurrent candidates ===");
    let mut collisions = 0;
    for term in 10..110u64 {
        let winners: Vec<_> = (1..=4u32)
            .filter_map(|c| cluster.elect_term(NodeId(c), term))
            .collect();
        let first = winners[0];
        if winners.iter().any(|&w| w != first) {
            collisions += 1;
        }
    }
    println!("100 terms × 4 concurrent candidates: {collisions} safety violations");
    assert_eq!(collisions, 0, "Paxos must never elect two leaders in one term");
    report.add("safety_violations", collisions as f64);
    report.write();
}
