//! Figure-11 live demo: two workflows (I2V and an LTX-style multi-image
//! app) sharing every stage except their diffusion models (§8.3). Shows
//! per-app routing through the shared instances and the GPU saving.
//!
//! Run: `cargo run --release --example multi_workflow_sharing`

use onepiece::client::{Gateway, WaitOutcome};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind};
use onepiece::nm::StageKey;
use onepiece::transport::{AppId, Payload};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 2.0 };
        s.exec_ms = 2.0;
    }
    // Second app: identical pipeline except its own diffusion model.
    let mut ltx = cfg.apps[0].clone();
    ltx.id = 2;
    ltx.name = "ltx".into();
    ltx.stages[2].name = "ltx_diffusion".into();
    cfg.apps.push(ltx);
    cfg.idle_pool = 0;

    let gpus_dedicated: usize = cfg
        .apps
        .iter()
        .flat_map(|a| a.stages.iter())
        .map(|s| s.gpus_per_instance)
        .sum();

    let pool = build_pool(&cfg, None);
    // I2V gets the full chain; LTX only its own diffusion instance —
    // everything else is shared.
    let counts = vec![vec![1, 1, 1, 1], vec![0, 0, 1, 0]];
    let gpus_shared = 5;
    let set = WorkflowSet::build(cfg, counts, Arc::new(EchoLogic), pool);
    for stage in [0u32, 1, 3] {
        set.nm.share_stage(
            StageKey { app: AppId(2), stage },
            StageKey { app: AppId(1), stage },
        );
    }
    std::thread::sleep(Duration::from_millis(120));

    println!("GPUs if each workflow had its own stages: {gpus_dedicated}");
    println!("GPUs with §8.3 sharing (only diffusion duplicated): {gpus_shared}");
    println!(
        "saving: {:.0}%\n",
        100.0 * (gpus_dedicated - gpus_shared) as f64 / gpus_dedicated as f64
    );

    // Interleave requests from both apps through the same entrance
    // instances.
    let mut handles = Vec::new();
    for i in 0..16u32 {
        let app = AppId(1 + i % 2);
        if let Ok(handle) = set.submit(app, Payload::Bytes(vec![i as u8; 32])) {
            handles.push((app, handle));
        }
        std::thread::sleep(Duration::from_millis(6));
    }
    let mut done = [0u32; 2];
    for (app, handle) in &handles {
        if matches!(handle.wait(Duration::from_secs(15)), WaitOutcome::Done(_)) {
            done[(app.0 - 1) as usize] += 1;
        }
    }
    println!("completed: i2v {}/8, ltx {}/8", done[0], done[1]);
    println!("\nshared-instance utilization:");
    for (node, stats, util) in set.instance_stats() {
        if stats.processed > 0 {
            println!(
                "  {node}: processed={} (serving both apps where shared) util={:.0}%",
                stats.processed,
                util * 100.0
            );
        }
    }
    set.shutdown();
}
