//! **End-to-end validation driver** (DESIGN.md §5): serve real
//! image-to-video requests through the full three-layer stack.
//!
//! - L1/L2: the four AOT-compiled stage models (Pallas kernels inside)
//!   loaded from `artifacts/*.hlo.txt` via the PJRT CPU client;
//! - L3: proxy (fast-reject) → text_encoder → vae_encode → diffusion
//!   (N Euler steps per request) → vae_decode → replicated DB, all over
//!   the simulated one-sided RDMA fabric with double-ring buffers.
//!
//! Reports per-request latency, throughput, per-stage utilization and
//! fabric traffic. Results are recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example i2v_serving`

use onepiece::client::{Gateway, WaitOutcome};
use onepiece::config::ClusterConfig;
use onepiece::runtime::PjrtRuntime;
use onepiece::transport::{AppId, Payload, WorkflowMessage};
use onepiece::util::now_ns;
use onepiece::workflow::I2vLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let steps: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);

    // --- load the AOT artifacts (L2 models with L1 Pallas kernels) ---
    let rt = Arc::new(PjrtRuntime::load(Path::new("artifacts"))?);
    println!("PJRT platform: {} | stages: {:?}", rt.platform(), rt.stage_names());
    let vid_tokens = rt.manifest().dim("vid_tokens").unwrap_or(256) as usize;
    let d_latent = rt.manifest().dim("d_latent").unwrap_or(16) as usize;
    let frames = rt.manifest().dim("frames").unwrap_or(4) as usize;

    // --- build the Workflow Set ---
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = onepiece::config::FabricKind::Infiniband100g;
    let pool = build_pool(&cfg, Some(rt));
    let counts = vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)];
    println!("Theorem-1 instance plan per stage: {:?}", counts[0]);
    let logic = Arc::new(I2vLogic::new(steps, vid_tokens, d_latent));
    let set = WorkflowSet::build(cfg, counts, logic, pool);
    std::thread::sleep(Duration::from_millis(150));

    // --- drive real requests: an image + a prompt each ---
    println!("\nserving {n_requests} I2V requests ({steps} diffusion steps each)...");
    let mut handles = Vec::new();
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        let image: Vec<f32> = (0..32 * 32 * 3)
            .map(|p| ((p + i * 131) % 255) as f32 / 255.0)
            .collect();
        let tokens: Vec<f32> = (0..32).map(|t| ((t * 31 + i * 7) % 512) as f32).collect();
        let payload = Payload::Tensors(vec![
            ("tokens".into(), vec![32], tokens),
            ("image".into(), vec![32, 32, 3], image),
        ]);
        match set.submit(AppId(1), payload) {
            Ok(handle) => handles.push((i, handle, now_ns())),
            Err(e) => println!("  request {i}: fast-rejected ({e})"),
        }
        std::thread::sleep(Duration::from_millis(15));
    }

    // --- collect results ---
    let mut latencies_ms = Vec::new();
    for (i, handle, submitted) in &handles {
        match handle.wait(Duration::from_secs(120)) {
            WaitOutcome::Done(bytes) => {
                let msg = WorkflowMessage::decode(&bytes).expect("stored result decodes");
                let Payload::Tensors(ts) = &msg.payload else { panic!("tensor result") };
                let (name, _shape, video) = &ts[0];
                assert_eq!(name, "video");
                assert_eq!(video.len(), frames * 32 * 32 * 3, "full video tensor");
                assert!(video.iter().all(|x| x.is_finite() && x.abs() <= 1.0));
                let lat = (now_ns() - submitted) as f64 / 1e6;
                latencies_ms.push(lat);
                println!("  request {i}: {frames}-frame video, {:.1} ms end-to-end", lat);
            }
            other => println!("  request {i}: {other:?}"),
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    // --- report ---
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = latencies_ms.len();
    assert!(n >= n_requests * 9 / 10, "≥90% of requests must complete");
    println!("\n=== i2v_serving results ===");
    println!("completed:   {n}/{n_requests}");
    println!("throughput:  {:.2} req/s", n as f64 / wall_s);
    println!(
        "latency:     p50 {:.1} ms | p95 {:.1} ms | p99 {:.1} ms",
        latencies_ms[n / 2],
        latencies_ms[(n * 95 / 100).min(n - 1)],
        latencies_ms[(n * 99 / 100).min(n - 1)]
    );
    let (ops, bytes) = set.fabric.traffic();
    println!(
        "fabric:      {} one-sided ops, {:.1} MiB moved, {:.2} ms simulated IB time",
        ops,
        bytes as f64 / (1 << 20) as f64,
        set.fabric.simulated_ns() as f64 / 1e6
    );
    println!("stage utilization (busy fraction over window):");
    for (node, stats, util) in set.instance_stats() {
        if stats.processed > 0 {
            println!(
                "  {node}: processed={} delivered={} util={:.0}%",
                stats.processed,
                stats.delivered,
                util * 100.0
            );
        }
    }
    set.shutdown();
    Ok(())
}
