//! Quickstart: the smallest complete OnePiece deployment.
//!
//! Builds one Workflow Set (simulated executors, no artifacts needed),
//! submits a handful of requests through the proxy, and polls results
//! from the database layer — the full §3 request lifecycle in ~60 lines.
//!
//! Run: `cargo run --release --example quickstart`

use onepiece::config::{ClusterConfig, ExecModel, FabricKind};
use onepiece::proxy::Admission;
use onepiece::transport::{AppId, Payload};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    // 1. Configuration: the default Wan2.1-style I2V pipeline, with each
    //    stage's compute replaced by a 2 ms simulated executor so this
    //    example runs without `make artifacts`.
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 2.0 };
        s.exec_ms = 2.0;
    }

    // 2. Executor pool + Theorem-1 instance counts per stage.
    let pool = build_pool(&cfg, None);
    let counts = vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)];
    println!("Theorem-1 instance plan: {:?}", counts[0]);

    // 3. Bring up the set: NM (with Paxos-elected primary), proxy,
    //    instances, replicated DB — all on one simulated RDMA fabric.
    let set = WorkflowSet::build(cfg, counts, Arc::new(EchoLogic), pool);
    std::thread::sleep(Duration::from_millis(100)); // assignments settle
    println!(
        "NM primary: {:?} | idle pool: {:?}",
        set.nm_cluster.primary(),
        set.nm.idle_pool()
    );

    // 4. Submit requests through the proxy (UID assigned per request;
    //    fast-reject protects the set under overload).
    let mut uids = Vec::new();
    for i in 0..5u8 {
        match set.submit(AppId(1), Payload::Bytes(vec![i; 64])) {
            Admission::Accepted(uid) => {
                println!("request {i}: accepted, uid={uid}");
                uids.push(uid);
            }
            Admission::Rejected => println!("request {i}: fast-rejected"),
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // 5. Poll results (stored in the memory-centric DB, purged on fetch).
    for uid in uids {
        match set.wait_result(uid, Duration::from_secs(10)) {
            Some(bytes) => println!("uid={uid}: result {} bytes", bytes.len()),
            None => println!("uid={uid}: timed out"),
        }
    }

    set.shutdown();
    println!("done");
}
