//! Quickstart: the smallest complete OnePiece deployment.
//!
//! Builds one Workflow Set (simulated executors, no artifacts needed),
//! submits a handful of requests through the unified `Gateway` API, and
//! waits on the typed `RequestHandle`s — the full §3 request lifecycle
//! in ~60 lines.
//!
//! Run: `cargo run --release --example quickstart`

use onepiece::client::{Gateway, SubmitOptions, WaitOutcome};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind};
use onepiece::transport::{AppId, Payload};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    // 1. Configuration: the default Wan2.1-style I2V pipeline, with each
    //    stage's compute replaced by a 2 ms simulated executor so this
    //    example runs without `make artifacts`.
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 2.0 };
        s.exec_ms = 2.0;
    }

    // 2. Executor pool + Theorem-1 instance counts per stage.
    let pool = build_pool(&cfg, None);
    let counts = vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)];
    println!("Theorem-1 instance plan: {:?}", counts[0]);

    // 3. Bring up the set: NM (with Paxos-elected primary), proxy,
    //    instances, replicated DB — all on one simulated RDMA fabric.
    let set = WorkflowSet::build(cfg, counts, Arc::new(EchoLogic), pool);
    std::thread::sleep(Duration::from_millis(100)); // assignments settle
    println!(
        "NM primary: {:?} | idle pool: {:?}",
        set.nm_cluster.primary(),
        set.nm.idle_pool()
    );

    // 4. Submit requests through the Gateway (UID assigned per request;
    //    fast-reject protects the set under overload). Interactive
    //    requests carry a deadline — the SLO envelope travels with the
    //    submission.
    let opts = SubmitOptions::interactive().with_deadline(Duration::from_secs(5));
    let mut handles = Vec::new();
    for i in 0..5u8 {
        match set.submit_with(AppId(1), Payload::Bytes(vec![i; 64]), opts) {
            Ok(handle) => {
                println!("request {i}: accepted, uid={}", handle.uid());
                handles.push(handle);
            }
            Err(e) => println!("request {i}: fast-rejected ({e})"),
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // 5. Wait on the handles (blocking on the DB layer's condvar — no
    //    polling loop; the result is purged on observation).
    for handle in handles {
        match handle.wait(Duration::from_secs(10)) {
            WaitOutcome::Done(bytes) => {
                println!("uid={}: result {} bytes", handle.uid(), bytes.len())
            }
            other => println!("uid={}: {other:?}", handle.uid()),
        }
    }

    set.shutdown();
    println!("done");
}
