//! Figure-10 live demo: a running Workflow Set whose diffusion stage
//! saturates under load; the NM's §8.2 loop pulls the idle-pool instance
//! (and then an underutilized decoder) into diffusion, and measured
//! throughput recovers — with the TaskManagers hot-swapping executors
//! and routing live.
//!
//! Run: `cargo run --release --example reschedule_demo`

use onepiece::client::{Gateway, RequestHandle, WaitOutcome};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind};
use onepiece::nm::StageKey;
use onepiece::transport::{AppId, Payload};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    // Light encoders/decoder, heavy diffusion: the Fig-10 imbalance.
    let ms = [1.0, 1.0, 30.0, 2.0];
    for (s, &m) in cfg.apps[0].stages.iter_mut().zip(&ms) {
        s.exec = ExecModel::Simulated { ms: m };
        s.exec_ms = m;
    }
    cfg.idle_pool = 1;
    cfg.nm.util_window_ms = 300;
    // Deliberately under-provision diffusion: 1 instance instead of the
    // Theorem-1 count.
    let counts = vec![vec![1usize, 1, 1, 1]];
    let pool = build_pool(&cfg, None);
    let set = WorkflowSet::build(cfg, counts, Arc::new(EchoLogic), pool);
    std::thread::sleep(Duration::from_millis(100));
    let diffusion = StageKey { app: AppId(1), stage: 2 };

    println!("initial diffusion instances: {:?}", set.nm.stage_instances(diffusion));
    println!("idle pool: {:?}\n", set.nm.idle_pool());

    // Phase 1: saturating load, no rebalancing.
    let submit_burst = |dur: Duration| {
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        while t0.elapsed() < dur {
            if let Ok(handle) = set.submit(AppId(1), Payload::Bytes(vec![0; 64])) {
                handles.push(handle);
            }
            std::thread::sleep(Duration::from_millis(8));
        }
        handles
    };
    // Drain and report how long the backlog takes to clear — the
    // observable effect of an under-provisioned stage.
    let drain = |handles: &[RequestHandle]| {
        let t0 = std::time::Instant::now();
        let mut done = 0;
        for h in handles {
            if matches!(h.wait(Duration::from_secs(30)), WaitOutcome::Done(_)) {
                done += 1;
            }
        }
        (done, t0.elapsed().as_secs_f64())
    };

    println!("phase 1: 2s of load with 1 diffusion instance...");
    let u1 = submit_burst(Duration::from_secs(2));
    let util1 = set.nm.stage_utilization(diffusion);
    let (d1, t1) = drain(&u1);
    println!(
        "  completed {d1}/{} | drain took {t1:.1}s | diffusion util {:.0}%",
        u1.len(),
        util1 * 100.0
    );

    // Phase 2: run the NM rebalance loop (the paper runs it on a timer).
    println!("\nphase 2: NM rebalancing (threshold 85%)...");
    let mut actions = 0;
    for _ in 0..3 {
        if let Some(a) = set.rebalance() {
            println!("  NM action: node {} {:?} -> {:?} (trigger {:.0}%)",
                a.node, a.from, a.to, a.trigger_util * 100.0);
            actions += 1;
            std::thread::sleep(Duration::from_millis(100)); // TMs re-sync
        }
    }
    println!(
        "  {} action(s); diffusion instances now: {:?}",
        actions,
        set.nm.stage_instances(diffusion)
    );

    // Phase 3: same load, scaled stage.
    println!("\nphase 3: 2s of the same load after rescheduling...");
    let u2 = submit_burst(Duration::from_secs(2));
    let (d2, t2) = drain(&u2);
    println!(
        "  completed {d2}/{} | drain took {t2:.1}s | diffusion util {:.0}%",
        u2.len(),
        set.nm.stage_utilization(diffusion) * 100.0
    );
    println!(
        "\nbacklog drain time {t1:.1}s -> {t2:.1}s after NM rescheduling \
         ({}x diffusion capacity)",
        set.nm.stage_instances(diffusion).len()
    );
    set.shutdown();
}
