//! Micro-batching demo: a Workflow Set with the adaptive batching
//! engine enabled, serving a Batch-tier burst alongside Interactive
//! traffic.
//!
//! Loads `examples/configs/microbatch.json` when run from the repo root
//! (a top-level `batch` block plus a fatter per-stage override on the
//! diffusion stage), falling back to an equivalent inline config. The
//! burst coalesces into micro-batches (watch `batches_executed` and the
//! `batch_size` histogram) while the Interactive requests bypass
//! formation and ride the reserved fast lane (`batch_bypass`).
//!
//! Run: `cargo run --release --example microbatch_demo`

use onepiece::client::{Gateway, SubmitOptions, WaitOutcome};
use onepiece::config::{BatchSettings, ClusterConfig, ExecModel, SchedMode};
use onepiece::transport::{AppId, Payload};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

fn fallback_config() -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = onepiece::config::FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 2.0 };
        s.exec_ms = 2.0;
        s.mode = SchedMode::Individual;
        s.workers = 2;
    }
    cfg.proxy.headroom = 4.0;
    cfg.idle_pool = 0;
    cfg.batch = Some(BatchSettings {
        max_batch: 8,
        max_wait_us: 4_000,
        adaptive: true,
        interactive_bypass: true,
        max_starvation_ms: 250,
    });
    cfg
}

fn main() {
    let path = std::path::Path::new("examples/configs/microbatch.json");
    let cfg = match ClusterConfig::from_file(path) {
        Ok(cfg) => {
            println!("config: {}", path.display());
            cfg
        }
        Err(e) => {
            println!("config fallback (inline): {e}");
            fallback_config()
        }
    };
    let batch = cfg.batch.expect("demo config must carry a batch block");
    println!(
        "batch block: max_batch {} | window {} µs (adaptive: {}) | interactive \
         bypass: {} | starvation guard {} ms",
        batch.max_batch,
        batch.max_wait_us,
        batch.adaptive,
        batch.interactive_bypass,
        batch.max_starvation_ms
    );
    for s in &cfg.apps[0].stages {
        if let Some(b) = cfg.stage_batch(s) {
            println!("  stage {:<14} max_batch {:>3}, window {:>6} µs", s.name, b.max_batch, b.max_wait_us);
        }
    }

    let pool = build_pool(&cfg, None);
    let set = WorkflowSet::build(cfg, vec![vec![1, 1, 1, 1]], Arc::new(EchoLogic), pool);
    std::thread::sleep(Duration::from_millis(100));

    // A Batch-tier burst (coalesces) + Interactive probes (bypass).
    let mut handles = Vec::new();
    for i in 0..24u8 {
        let opts = if i % 6 == 5 {
            SubmitOptions::interactive()
        } else {
            SubmitOptions::batch()
        };
        match set.submit_with(AppId(1), Payload::Bytes(vec![i; 32]), opts) {
            Ok(h) => handles.push(h),
            Err(e) => println!("request {i}: rejected ({e})"),
        }
    }
    let mut done = 0;
    for h in &handles {
        if matches!(h.wait(Duration::from_secs(10)), WaitOutcome::Done(_)) {
            done += 1;
        }
    }
    println!("\ncompleted {done}/{} requests", handles.len());

    let m = set.metrics();
    let size = m.histogram("batch_size").snapshot();
    let wait = m.histogram("batch_wait_ns").snapshot();
    println!(
        "batches executed: {} | bypassed (Interactive / fast lane): {}",
        m.counter("batches_executed").get(),
        m.counter("batch_bypass").get()
    );
    println!(
        "batch size p50/max: {}/{} | formation wait p50: {:.2} ms",
        size.p50,
        size.max,
        wait.p50 as f64 / 1e6
    );
    let ring_pushes = m.counter("ring_pushes_total").get();
    let ring_messages = m.counter("ring_messages_total").get();
    let ring_verbs = m.counter("ring_verbs_total").get();
    println!(
        "ring data plane: {} messages in {} pushes ({:.2} verbs/message)",
        ring_messages,
        ring_pushes,
        ring_verbs as f64 / ring_messages.max(1) as f64
    );
    assert_eq!(done, handles.len(), "every admitted request must complete");
    assert!(
        m.counter("batches_executed").get() >= 1,
        "the burst must form at least one micro-batch"
    );
    // The e15 coalescing invariant: with batched delivery on, a
    // micro-batch crosses each ring as one locked push, so the set-wide
    // push count must stay below the per-member message count.
    assert!(
        ring_pushes < ring_messages,
        "coalesced delivery must push fewer times ({ring_pushes}) than \
         members delivered ({ring_messages})"
    );
    println!(
        "batched delivery invariant OK: ring_pushes_total ({ring_pushes}) < \
         members_delivered ({ring_messages})"
    );
    set.shutdown();
    println!("done: batching amortized the burst; Interactive bypassed it");
}
