//! Overload demo (§5): drive a Workflow Set far past its Theorem-1
//! capacity and watch the Request Monitor fast-reject the excess while
//! in-system latency stays flat. Then the multi-set behaviour (§3.2):
//! rejected clients retry against a second set through the same
//! `Gateway` API and overall goodput doubles.
//!
//! Run: `cargo run --release --example overload_fast_reject`

use onepiece::client::{Gateway, RequestHandle, SubmitError};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind};
use onepiece::transport::{AppId, Payload};
use onepiece::util::now_ns;
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, MultiSet, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

fn small_config() -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 5.0 };
        s.exec_ms = 5.0;
    }
    // Short monitor window: admission bursts are bounded by
    // budget = capacity × window, so a short window keeps the admitted
    // stream smooth and in-system queues shallow.
    cfg.proxy.monitor_window_ms = 100;
    // Admit slightly below the Theorem-1 rate: at exactly ρ=1 an M/D/1
    // queue grows without bound, so production deployments keep headroom.
    cfg.proxy.headroom = 0.5;
    cfg.idle_pool = 0;
    cfg
}

fn build_set() -> WorkflowSet {
    let cfg = small_config();
    let pool = build_pool(&cfg, None);
    let counts = vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)];
    WorkflowSet::build(cfg, counts, Arc::new(EchoLogic), pool)
}

fn main() {
    println!("=== single set under 3x overload ===");
    let set = build_set();
    std::thread::sleep(Duration::from_millis(100));
    let capacity = set.proxy.capacity_rps(AppId(1));
    println!("entrance capacity: {capacity:.0} req/s (K/T_X)");

    // Offer 3x capacity for 2 seconds, collecting results *concurrently*
    // (clients observe completion while the system serves — measuring at
    // each request's own completion time).
    let offered_interval = Duration::from_secs_f64(1.0 / (capacity * 3.0));
    let (tx, rx) = std::sync::mpsc::channel::<(RequestHandle, u128)>();
    let poller = std::thread::spawn(move || {
        let mut outstanding: Vec<(RequestHandle, u128)> = Vec::new();
        let mut lat = Vec::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        loop {
            while let Ok(x) = rx.try_recv() {
                outstanding.push(x);
            }
            outstanding.retain(|(handle, submitted)| {
                if handle.try_result().is_some() {
                    lat.push((now_ns() - submitted) as f64 / 1e6);
                    false
                } else {
                    true
                }
            });
            // Channel closed and everything drained (or timeout).
            let closed = matches!(
                rx.try_recv(),
                Err(std::sync::mpsc::TryRecvError::Disconnected)
            );
            if (closed && outstanding.is_empty())
                || std::time::Instant::now() > deadline
            {
                return lat;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    });

    let (mut accepted, mut rejected) = (0u32, 0u32);
    let mut last_hint = Duration::ZERO;
    let t0 = std::time::Instant::now();
    while t0.elapsed() < Duration::from_secs(2) {
        match set.submit(AppId(1), Payload::Bytes(vec![0; 128])) {
            Ok(handle) => {
                accepted += 1;
                tx.send((handle, now_ns())).unwrap();
            }
            Err(SubmitError::Overloaded { retry_after }) => {
                rejected += 1;
                last_hint = retry_after;
            }
            Err(SubmitError::NoCapacity) => rejected += 1,
        }
        std::thread::sleep(offered_interval);
    }
    drop(tx);
    println!(
        "offered {:.0} req/s for 2s: accepted {accepted} ({:.0}/s), fast-rejected \
         {rejected} (last retry_after hint: {last_hint:?})",
        capacity * 3.0,
        accepted as f64 / 2.0
    );
    let mut lat = poller.join().unwrap();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if !lat.is_empty() {
        println!(
            "admitted-request latency stayed flat: p50 {:.0} ms, p99 {:.0} ms \
             (pipeline is {} ms of compute)",
            lat[lat.len() / 2],
            lat[(lat.len() * 99 / 100).min(lat.len() - 1)],
            4 * 5
        );
    }
    set.shutdown();

    println!("\n=== two sets: rejected clients retry the other set (§3.2) ===");
    let multi = MultiSet::new(vec![build_set(), build_set()], 99);
    std::thread::sleep(Duration::from_millis(100));
    let mut placed = [0u32; 2];
    let mut lost = 0u32;
    let t0 = std::time::Instant::now();
    while t0.elapsed() < Duration::from_secs(2) {
        match multi.submit(AppId(1), Payload::Bytes(vec![0; 128])) {
            Ok(handle) => placed[handle.set()] += 1,
            Err(_) => lost += 1,
        }
        std::thread::sleep(offered_interval);
    }
    println!(
        "3x single-set load across 2 sets: set0 {} | set1 {} | rejected-everywhere {}",
        placed[0], placed[1], lost
    );
    println!("cross-set load balancing absorbs the overload the single set had to reject");
    for s in multi.sets {
        s.shutdown();
    }
}
