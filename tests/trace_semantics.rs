//! Semantics of the distributed-tracing subsystem, end to end:
//!
//! - a deployment without a `trace` config block registers no `trace_*`
//!   counters and serves requests exactly as before (the off path is
//!   byte-identical — no recorder even exists);
//! - at `sample_rate` 1.0 a completed request's stitched trace
//!   reconstructs the exact stage path with monotonic spans, a
//!   queue/exec/transit breakdown, and a critical path that covers the
//!   whole request;
//! - the flight recorder overwrites oldest-first under overflow and the
//!   newest events survive;
//! - the `always_sample_slow_ms` tail rule force-keeps slow requests a
//!   0.0 sample rate would drop;
//! - cancelled / failed / deadline-expired requests carry their typed
//!   terminal verdict in the kept trace.

use onepiece::client::{Gateway, Priority, RequestHandle, RequestTracker, WaitOutcome};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind, TraceSettings};
use onepiece::metrics::Registry;
use onepiece::trace::{EventKind, Tracer, Verdict};
use onepiece::transport::{AppId, Payload};
use onepiece::util::{ManualClock, NodeId, Uid};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

/// Fast four-stage i2v pipeline on simulated executors.
fn sim_config() -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 1.0 };
        s.exec_ms = 1.0;
    }
    cfg.idle_pool = 0;
    cfg
}

fn build(cfg: &ClusterConfig) -> WorkflowSet {
    let pool = build_pool(cfg, None);
    WorkflowSet::build(cfg.clone(), vec![vec![1, 1, 1, 1]], Arc::new(EchoLogic), pool)
}

/// The terminal event is recorded by the worker right *after* the result
/// reaches the DB (which is what wakes `wait`), so a freshly completed
/// request's trace can trail its result by a scheduling quantum.
fn wait_trace(handle: &RequestHandle, timeout: Duration) -> Option<onepiece::trace::Trace> {
    let deadline = std::time::Instant::now() + timeout;
    loop {
        if let Some(t) = handle.trace() {
            return Some(t);
        }
        if std::time::Instant::now() >= deadline {
            return None;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn untraced_set_registers_no_trace_counters_and_serves() {
    let set = build(&sim_config());
    std::thread::sleep(Duration::from_millis(80));
    assert!(set.tracer().is_none(), "no `trace` block → no tracer");
    assert!(set.trace_hook().is_none());

    let handle = set
        .submit(AppId(1), Payload::Bytes(b"untraced".to_vec()))
        .expect("must admit");
    assert!(matches!(
        handle.wait(Duration::from_secs(10)),
        WaitOutcome::Done(_)
    ));
    assert!(handle.trace().is_none(), "no tracer → no trace");

    // The `trace_*` counters are registered only inside `Tracer::new`;
    // an untraced deployment's registry must never show them.
    for (name, _) in set.metrics().counters_snapshot() {
        assert!(
            !name.starts_with("trace_"),
            "untraced set leaked counter {name:?}"
        );
    }
    assert!(
        !set.metrics().render_prometheus().contains("trace_"),
        "untraced set leaked trace metrics into the exposition"
    );
    set.shutdown();
}

#[test]
fn sampled_request_reconstructs_stage_path_with_monotonic_spans() {
    let mut cfg = sim_config();
    cfg.trace = Some(TraceSettings {
        sample_rate: 1.0,
        buffer_events: 4096,
        always_sample_slow_ms: 0,
    });
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let handle = set
        .submit(AppId(1), Payload::Bytes(b"traced request".to_vec()))
        .expect("must admit");
    assert!(matches!(
        handle.wait(Duration::from_secs(10)),
        WaitOutcome::Done(_)
    ));
    let trace = wait_trace(&handle, Duration::from_secs(5))
        .expect("sample_rate 1.0 keeps every completed trace");

    assert_eq!(trace.uid, handle.uid());
    assert_eq!(trace.verdict, Some(Verdict::Done));
    assert!(trace.total_ns > 0);

    // Exact stage path through the four-stage i2v pipeline.
    assert_eq!(trace.stage_path(), vec![0, 1, 2, 3]);

    // Spans are monotonic: stitching orders by the set clock.
    assert!(
        trace.events.windows(2).all(|w| w[0].t_ns <= w[1].t_ns),
        "events must be time-ordered"
    );

    // The full hop structure survived: admission, per-stage scheduler
    // and execution spans, ring pushes, and final delivery.
    let has = |k: &str| trace.events.iter().any(|e| e.kind.label() == k);
    for kind in ["admitted", "enqueued", "dequeued", "exec_begin", "exec_end", "ring_push", "delivered", "terminal"] {
        assert!(has(kind), "trace must contain a {kind} event: {:?}", trace.events);
    }
    for stage in 0..4u32 {
        assert!(
            trace
                .events
                .iter()
                .any(|e| e.stage == Some(stage) && matches!(e.kind, EventKind::ExecBegin)),
            "stage {stage} must have an exec span"
        );
    }

    // Breakdown: each visited stage has a positive exec span (simulated
    // 1 ms executors) and the critical path accounts for the whole
    // request.
    let breakdown = trace.breakdown();
    assert_eq!(breakdown.len(), 4);
    for b in &breakdown {
        assert!(b.exec_ns > 0, "stage {} exec span missing", b.stage);
    }
    let cp = trace.critical_path();
    let sum: u64 = cp.iter().map(|(_, ns)| ns).sum();
    assert_eq!(sum, trace.total_ns, "critical path covers the request: {cp:?}");

    // Recording left its bookkeeping in the registry.
    assert!(set.metrics().counter("trace_events_total").get() > 0);
    assert!(set.metrics().counter("trace_traces_kept_total").get() >= 1);
    set.shutdown();
}

#[test]
fn overflow_keeps_newest_and_counts_overwrites() {
    let clock = Arc::new(ManualClock::new());
    let metrics = Registry::new();
    let tracer = Tracer::new(
        &TraceSettings {
            sample_rate: 1.0,
            buffer_events: 16, // the recorder's minimum capacity
            always_sample_slow_ms: 0,
        },
        clock.clone(),
        0,
        &metrics,
    );
    let hook = tracer.hook(1);

    // 50 requests × 2 events each through a 16-slot ring: only the
    // newest 16 events (the last 8 requests) survive the laps.
    for i in 0..50u128 {
        hook.record(Uid(i), None, EventKind::Admitted);
        clock.advance(1_000);
        hook.record(Uid(i), None, EventKind::Terminal { verdict: Verdict::Done });
        clock.advance(1_000);
    }
    tracer.drain();

    assert!(tracer.trace_of(Uid(0)).is_none(), "oldest events overwritten");
    assert!(tracer.trace_of(Uid(41)).is_none(), "still outside the ring");
    for i in 42..50u128 {
        let t = tracer.trace_of(Uid(i)).expect("newest requests survive");
        assert_eq!(t.events.len(), 2, "both events of request {i} kept");
        assert_eq!(t.verdict, Some(Verdict::Done));
        assert_eq!(t.total_ns, 1_000);
    }
    assert_eq!(metrics.counter("trace_events_total").get(), 100);
    assert_eq!(
        metrics.counter("trace_events_overwritten_total").get(),
        84,
        "100 recorded - 16 surviving slots"
    );
    assert_eq!(metrics.counter("trace_traces_kept_total").get(), 8);
}

#[test]
fn slow_tail_rule_force_keeps_slow_requests() {
    let clock = Arc::new(ManualClock::new());
    let metrics = Registry::new();
    let tracer = Tracer::new(
        &TraceSettings {
            sample_rate: 0.0, // head sampling drops everything…
            buffer_events: 256,
            always_sample_slow_ms: 5, // …but ≥ 5 ms is always kept
        },
        clock.clone(),
        0,
        &metrics,
    );
    let hook = tracer.hook(1);

    let run = |uid: u128, dur_ns: u64| {
        hook.record(Uid(uid), None, EventKind::Admitted);
        clock.advance(dur_ns);
        hook.record(Uid(uid), None, EventKind::Terminal { verdict: Verdict::Done });
    };
    run(1, 1_000_000); // 1 ms: sampled out
    run(2, 9_000_000); // 9 ms: force-kept by the tail rule

    assert!(tracer.trace_of(Uid(1)).is_none(), "fast request dropped");
    let slow = tracer.trace_of(Uid(2)).expect("slow request force-kept");
    assert_eq!(slow.total_ns, 9_000_000);
    assert_eq!(slow.verdict, Some(Verdict::Done));
    assert_eq!(metrics.counter("trace_traces_kept_total").get(), 1);
    assert_eq!(metrics.counter("trace_traces_sampled_out_total").get(), 1);
}

#[test]
fn cancelled_and_failed_requests_carry_terminal_verdicts() {
    // End-to-end cancellation: a request cancelled mid-pipeline (slow
    // diffusion keeps it in flight) finalizes with Verdict::Cancelled.
    let mut cfg = sim_config();
    cfg.apps[0].stages[2].exec = ExecModel::Simulated { ms: 300.0 };
    cfg.apps[0].stages[2].exec_ms = 300.0;
    cfg.trace = Some(TraceSettings {
        sample_rate: 1.0,
        buffer_events: 4096,
        always_sample_slow_ms: 0,
    });
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let handle = set
        .submit(AppId(1), Payload::Bytes(vec![3; 16]))
        .expect("must admit");
    std::thread::sleep(Duration::from_millis(30)); // reach diffusion
    assert!(handle.cancel());
    let trace = wait_trace(&handle, Duration::from_secs(5))
        .expect("cancelled request still finalizes a trace");
    assert_eq!(trace.verdict, Some(Verdict::Cancelled));
    set.shutdown();

    // Failed + deadline-expired verdicts via the tracker (the component
    // that owns those transitions), against a manual clock.
    let clock = Arc::new(ManualClock::new());
    clock.set(1);
    let metrics = Registry::new();
    let tracer = Tracer::new(&TraceSettings::default(), clock.clone(), 0, &metrics);
    let tracker = RequestTracker::new(clock.clone(), metrics.clone());
    tracker.set_trace(tracer.hook(7));

    let failed = Uid::fresh(NodeId(1));
    tracker.register(failed, Priority::Standard, None);
    assert!(tracker.mark_failed(failed));
    let t = tracer.trace_of(failed).expect("failed request finalizes");
    assert_eq!(t.verdict, Some(Verdict::Failed));

    let late = Uid::fresh(NodeId(2));
    tracker.register(late, Priority::Standard, Some(Duration::from_millis(10)));
    clock.advance(11_000_000);
    let _ = tracker.probe(late); // first post-expiry probe records the verdict
    let t = tracer.trace_of(late).expect("expired request finalizes");
    assert_eq!(t.verdict, Some(Verdict::DeadlineExceeded));
}
