//! Payload-path battery for the zero-copy large-payload plane
//! (DESIGN.md §2): the rendezvous path (staged slab + descriptor frame +
//! one one-sided READ) must be observationally identical to the eager
//! path for every payload size — same bytes delivered, same per-sender
//! FIFO order, same wrap behaviour — while paying **one** post-encode
//! copy instead of two and keeping `payload_regions_live` leak-free.

use onepiece::metrics::Registry;
use onepiece::rdma::Fabric;
use onepiece::ringbuf::RingConfig;
use onepiece::transport::{
    AppId, MessageHeader, Payload, RdmaEndpoint, RdmaSender, RingMetrics, StageId,
    WorkflowMessage,
};
use onepiece::util::{NodeId, Rng, Uid};

/// Deterministic message with `len` pseudo-random payload bytes.
fn bytes_msg(uid: u64, len: usize, seed: u64) -> WorkflowMessage {
    let mut rng = Rng::new(seed);
    let data: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
    WorkflowMessage {
        header: MessageHeader {
            uid: Uid(uid as u128),
            ts_ns: uid,
            app: AppId(1),
            stage: StageId(0),
            origin: NodeId(3),
        },
        payload: Payload::Bytes(data),
    }
}

/// An endpoint + instrumented sender pair on a fresh fabric.
fn plane(cfg: RingConfig, threshold: usize) -> (RdmaEndpoint, RdmaSender, RingMetrics) {
    let fabric = Fabric::ideal();
    let reg = Registry::new();
    let m = RingMetrics::from_registry(&reg);
    let mut ep = RdmaEndpoint::new(&fabric, cfg);
    ep.set_metrics(m.clone());
    let mut tx = ep.sender();
    tx.set_metrics(m.clone());
    tx.set_rendezvous_threshold(threshold);
    (ep, tx, m)
}

/// A ring large enough to carry 16 MB messages *eagerly* (the default
/// 1 MB cap cannot; the rendezvous plane exists so production rings
/// never have to grow like this).
fn big_ring() -> RingConfig {
    RingConfig {
        nslots: 64,
        cap_bytes: 32 << 20,
        ..RingConfig::default()
    }
}

/// The core equivalence property: for sizes from 1 KB to 16 MB
/// straddling the cutover, the rendezvous plane delivers byte-identical
/// messages to the eager plane — the two paths differ only in copies
/// and verbs, never in observable bytes.
#[test]
fn eager_and_rendezvous_byte_identical_1k_to_16m() {
    let sizes = [
        1 << 10,  // 1 KB   — eager on both planes
        16 << 10, // 16 KB  — below the 64 KB cutover
        64 << 10, // 64 KB  — exactly at the cutover
        1 << 20,  // 1 MB
        4 << 20,  // 4 MB
        16 << 20, // 16 MB  — far beyond any ring cap
    ];
    let threshold = 64 << 10;
    let (mut eager_ep, mut eager_tx, _em) = plane(big_ring(), 0);
    let (mut rdv_ep, mut rdv_tx, rm) = plane(big_ring(), threshold);

    for (i, &len) in sizes.iter().enumerate() {
        let msg = bytes_msg(i as u64, len, 0xC0FFEE + i as u64);
        assert!(eager_tx.send(&msg), "eager send of {len} B");
        assert!(rdv_tx.send(&msg), "rendezvous send of {len} B");
        let via_eager = eager_ep.recv().expect("eager delivery");
        let via_rdv = rdv_ep.recv().expect("rendezvous delivery");
        assert_eq!(via_eager, msg, "{len} B corrupted on the eager plane");
        assert_eq!(via_rdv, msg, "{len} B corrupted on the rendezvous plane");
        assert_eq!(via_eager, via_rdv);
    }
    // Everything at/above the cutover went through the staged plane.
    assert_eq!(rm.rendezvous_reads.get(), 4);
    assert_eq!(eager_ep.corrupted_count(), 0);
    assert_eq!(rdv_ep.corrupted_count(), 0);
    // No staged slab leaks once the consumer released them.
    rdv_tx.sweep_staged();
    assert_eq!(rm.payload_regions_live.get(), 0);
}

/// The acceptance shape: a 16 MB delivery through a *default* ring
/// (1 MB cap — the payload could never travel inline) costs exactly one
/// staging copy and one one-sided read.
#[test]
fn sixteen_mb_one_copy_one_read_through_default_ring() {
    let (mut ep, mut tx, m) = plane(RingConfig::default(), 64 << 10);
    let msg = bytes_msg(1, 16 << 20, 42);
    let enc_len = msg.encode().len() as u64;

    assert!(tx.send(&msg), "descriptor fits the default ring");
    assert_eq!(m.payload_bytes_copied.get(), enc_len, "one staging copy");
    assert_eq!(ep.recv().unwrap(), msg);
    assert_eq!(m.rendezvous_reads.get(), 1, "one one-sided READ");
    assert_eq!(
        m.payload_bytes_copied.get(),
        enc_len,
        "the READ lands with zero host copies"
    );
    tx.sweep_staged();
    assert_eq!(m.payload_regions_live.get(), 0);
}

/// Messages below the cutover stay on the untouched eager path (two
/// copies, no staged slab); at/above go rendezvous (one copy, one read).
#[test]
fn threshold_boundary_is_exact() {
    let threshold = 8 << 10;
    let (mut ep, mut tx, m) = plane(RingConfig::default(), threshold);

    // Pick payload sizes so the *encoded* sizes straddle the threshold.
    let mut below = bytes_msg(1, threshold, 7);
    let below_enc = loop {
        let e = below.encode();
        if e.len() < threshold {
            break e;
        }
        let Payload::Bytes(b) = &mut below.payload else { unreachable!() };
        b.truncate(b.len() - 64);
    };
    let mut above = bytes_msg(2, threshold, 8);
    let above_enc = loop {
        let e = above.encode();
        if e.len() >= threshold {
            break e;
        }
        let Payload::Bytes(b) = &mut above.payload else { unreachable!() };
        b.extend_from_slice(&[9u8; 64]);
    };

    assert!(tx.send_encoded(&below_enc));
    assert_eq!(m.payload_regions_live.get(), 0, "below: nothing staged");
    assert!(tx.send_encoded(&above_enc));
    assert_eq!(m.payload_regions_live.get(), 1, "at/above: staged");

    assert_eq!(ep.recv().unwrap(), below);
    assert_eq!(ep.recv().unwrap(), above);
    assert_eq!(m.rendezvous_reads.get(), 1);
    assert_eq!(
        m.payload_bytes_copied.get(),
        2 * below_enc.len() as u64 + above_enc.len() as u64,
        "eager pays 2 copies, rendezvous pays 1"
    );
    tx.sweep_staged();
    assert_eq!(m.payload_regions_live.get(), 0);
}

/// `send_batch` parity: a mixed eager/descriptor batch through one
/// coalesced push round delivers the same messages in the same order as
/// the equivalent sequence of single sends on a twin plane.
#[test]
fn mixed_batch_matches_sequential_sends() {
    let threshold = 4 << 10;
    let cfg = RingConfig {
        nslots: 64,
        cap_bytes: 64 << 10,
        ..RingConfig::default()
    };
    let (mut batch_ep, mut batch_tx, bm) = plane(cfg, threshold);
    let (mut seq_ep, mut seq_tx, _sm) = plane(cfg, threshold);

    // Mixed sizes: small (eager), large (descriptor), alternating so the
    // batch interleaves kinds.
    let msgs: Vec<WorkflowMessage> = (0..8)
        .map(|i| {
            let len = if i % 2 == 0 { 256 } else { 8 << 10 };
            bytes_msg(i as u64, len, 100 + i as u64)
        })
        .collect();
    let encoded: Vec<Vec<u8>> = msgs.iter().map(|m| m.encode()).collect();
    let frames: Vec<&[u8]> = encoded.iter().map(Vec::as_slice).collect();

    assert_eq!(batch_tx.send_batch(&frames), msgs.len());
    assert_eq!(bm.pushes.get(), 1, "mixed batch is one lock acquisition");
    for e in &encoded {
        assert!(seq_tx.send_encoded(e));
    }

    let mut via_batch = Vec::new();
    batch_ep.recv_many(64, &mut via_batch);
    let mut via_seq = Vec::new();
    while let Some(m) = seq_ep.recv() {
        via_seq.push(m);
    }
    assert_eq!(via_batch, msgs, "batch plane FIFO + bytes");
    assert_eq!(via_seq, msgs, "sequential plane FIFO + bytes");

    batch_tx.sweep_staged();
    seq_tx.sweep_staged();
    assert_eq!(bm.payload_regions_live.get(), 0);
}

/// Wrap-boundary parity: a small ring forces descriptor frames to land
/// at every phase of the buffer region across many laps — the §6.1
/// wrap rule must treat a 40-byte descriptor frame exactly like an
/// eager frame of the same size.
#[test]
fn descriptor_frames_wrap_like_eager_frames() {
    let cfg = RingConfig {
        nslots: 8,
        // Small enough that a handful of frames laps the buffer region:
        // mixed eager payloads (24..96 B) and 40 B descriptors hit the
        // wrap at shifting offsets across ~12 laps.
        cap_bytes: 512,
        ..RingConfig::default()
    };
    let threshold = 512;
    let (mut ep, mut tx, m) = plane(cfg, threshold);

    let mut sent = Vec::new();
    for round in 0..64u64 {
        let len = if round % 3 == 0 {
            2 << 10 // rendezvous: only its descriptor enters the ring
        } else {
            24 + (round as usize % 72) // eager, varying frame length
        };
        let msg = bytes_msg(round, len, 1000 + round);
        assert!(tx.send(&msg), "round {round}");
        sent.push(msg);
        // Drain every few rounds so the ring wraps instead of filling.
        if round % 4 == 3 {
            while let Some(got) = ep.recv() {
                let want = sent.remove(0);
                assert_eq!(got, want, "wrap corrupted a frame");
            }
        }
    }
    while let Some(got) = ep.recv() {
        let want = sent.remove(0);
        assert_eq!(got, want);
    }
    assert!(sent.is_empty(), "all messages delivered");
    assert_eq!(ep.corrupted_count(), 0);
    tx.sweep_staged();
    assert_eq!(m.payload_regions_live.get(), 0);
}

/// Randomized property sweep: arbitrary sizes around the cutover,
/// randomly batched or single-sent, must always deliver byte-identical
/// messages in per-sender FIFO order with a leak-free stager.
#[test]
fn randomized_size_sweep_property() {
    let threshold = 4 << 10;
    for seed in 0..8u64 {
        let cfg = RingConfig {
            nslots: 128,
            cap_bytes: 1 << 20,
            ..RingConfig::default()
        };
        let (mut ep, mut tx, m) = plane(cfg, threshold);
        let mut rng = Rng::new(0xBEEF + seed);
        let mut sent: Vec<WorkflowMessage> = Vec::new();
        let mut uid = 0u64;

        for _round in 0..20 {
            // 1..=4 messages, sizes log-uniform in [64 B, 32 KB].
            let n = 1 + rng.below(4) as usize;
            let batch: Vec<WorkflowMessage> = (0..n)
                .map(|_| {
                    let len = 64usize << rng.below(10);
                    uid += 1;
                    bytes_msg(uid, len, seed * 10_000 + uid)
                })
                .collect();
            if rng.below(2) == 0 {
                let encoded: Vec<Vec<u8>> = batch.iter().map(|m| m.encode()).collect();
                let frames: Vec<&[u8]> = encoded.iter().map(Vec::as_slice).collect();
                assert_eq!(tx.send_batch(&frames), n, "seed {seed}");
            } else {
                for msg in &batch {
                    assert!(tx.send(msg), "seed {seed}");
                }
            }
            sent.extend(batch);
            // Opportunistic drain keeps the ring from filling.
            while let Some(got) = ep.recv() {
                let want = sent.remove(0);
                assert_eq!(got, want, "seed {seed}: bytes or order diverged");
            }
        }
        while let Some(got) = ep.recv() {
            let want = sent.remove(0);
            assert_eq!(got, want, "seed {seed}");
        }
        assert!(sent.is_empty(), "seed {seed}: messages lost");
        assert_eq!(ep.corrupted_count(), 0, "seed {seed}");
        tx.sweep_staged();
        assert_eq!(m.payload_regions_live.get(), 0, "seed {seed}: slab leak");
    }
}

/// Oversize handling flips at the cutover: with rendezvous off, a
/// message larger than the ring can never be delivered (permanent drop);
/// switching the threshold on makes the very same message deliverable
/// because only its 40-byte descriptor enters the ring.
#[test]
fn rendezvous_rescues_messages_too_large_for_the_ring() {
    let cfg = RingConfig {
        nslots: 8,
        cap_bytes: 4 << 10,
        ..RingConfig::default()
    };
    let (mut ep, mut tx, m) = plane(cfg, 0);
    let msg = bytes_msg(1, 16 << 10, 5); // 4× the buffer region
    let enc = msg.encode();
    assert!(!tx.accepts(enc.len()), "eager-only: permanently oversized");
    assert!(!tx.send(&msg));
    assert_eq!(tx.dropped_count(), 1);
    assert!(ep.recv().is_none());

    tx.set_rendezvous_threshold(4 << 10);
    assert!(tx.accepts(enc.len()), "rendezvous: always deliverable");
    assert!(tx.send(&msg));
    assert_eq!(ep.recv().unwrap(), msg);
    assert_eq!(m.rendezvous_reads.get(), 1);
    tx.sweep_staged();
    assert_eq!(m.payload_regions_live.get(), 0);
}
