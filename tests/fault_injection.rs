//! Fabric fault-injection integration tests (DESIGN.md §7): with the
//! `faults` config block on, seeded verb loss, completion delays, and
//! directed partitions are absorbed by the verb-retry layer and the
//! Case 1-8 / checkpoint-replay machinery — every admitted request
//! reaches a *typed terminal* state (`Done` with the exact original
//! payload, or `Failed`), never a hang and never a corrupt delivery.
//!
//! The off-by-default contract is asserted too: a build without a
//! `faults` block allocates no fault state and registers no fault
//! counter, so its `counters_snapshot` is row-identical to the seed's.
//!
//! Gate ordering, retry exhaustion, and partition heal are unit-tested
//! in `rdma::fabric`; these tests drive the full wset loop under
//! injected faults.

use onepiece::client::{Gateway, RetryPolicy, SubmitOptions, WaitOutcome};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind, FaultSettings};
use onepiece::transport::{AppId, Payload, WorkflowMessage};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

/// Fast simulated pipeline with the failure detector armed (the
/// composed-chaos test kills instances) and an idle pool to repair from.
fn base_config() -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 1.0 };
        s.exec_ms = 1.0;
    }
    cfg.nm.heartbeat_ms = 10;
    cfg.nm.instance_timeout_ms = 150;
    cfg.idle_pool = 2;
    cfg
}

fn build(cfg: &ClusterConfig) -> WorkflowSet {
    let pool = build_pool(cfg, None);
    WorkflowSet::build(cfg.clone(), vec![vec![1, 1, 1, 1]], Arc::new(EchoLogic), pool)
}

const FAULT_ROWS: [&str; 5] = [
    "verbs_lost_total",
    "verbs_delayed_total",
    "region_flaps_total",
    "partitioned_ops_total",
    "verb_retries_total",
];

#[test]
fn no_faults_block_means_no_fault_state_and_no_new_counters() {
    let cfg = base_config();
    assert!(cfg.faults.is_none(), "faults must be off by default");
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    for i in 0..4u8 {
        let h = set
            .submit(AppId(1), Payload::Bytes(vec![i; 16]))
            .expect("must admit");
        assert!(matches!(h.wait(Duration::from_secs(10)), WaitOutcome::Done(_)));
    }
    assert!(set.fault_stats().is_none(), "no fault state without a faults block");
    set.sync_fault_counters(); // must be a no-op, not a registration
    let metrics = set.metrics().clone();
    set.shutdown();
    for (k, _) in metrics.counters_snapshot() {
        assert!(
            !FAULT_ROWS.contains(&k.as_str()) && !k.starts_with("requests_shed."),
            "unfaulted build must not register fault row {k}"
        );
    }
}

#[test]
fn verb_loss_resolves_through_retries_without_corruption() {
    let mut cfg = base_config();
    cfg.faults = Some(FaultSettings {
        verb_loss_prob: 0.05,
        ..Default::default()
    });
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let opts = SubmitOptions::default()
        .with_retry(RetryPolicy::attempts(3, Duration::ZERO));
    let mut done = 0;
    let mut failed = 0;
    for i in 0..16u8 {
        let payload = vec![i; 64];
        let Ok(h) = set.submit_with(AppId(1), Payload::Bytes(payload.clone()), opts)
        else {
            continue; // admission under faults may shed; that is a typed outcome
        };
        match h.wait(Duration::from_secs(15)) {
            WaitOutcome::Done(bytes) => {
                let msg = WorkflowMessage::decode(&bytes).unwrap();
                assert_eq!(
                    msg.payload,
                    Payload::Bytes(payload),
                    "a delivered result must carry the exact original payload"
                );
                done += 1;
            }
            WaitOutcome::Failed => failed += 1,
            other => panic!("request {i} must reach a terminal state, got {other:?}"),
        }
    }
    assert!(done >= 1, "work must complete through the lossy fabric");
    assert!(done + failed >= 1);

    set.sync_fault_counters();
    let stats = set.fault_stats().expect("faults block must allocate fault state");
    assert!(stats.verbs_lost >= 1, "5% loss must drop verbs in this run");
    assert!(stats.verb_retries >= 1, "lost verbs must be retried");
    let m = set.metrics();
    assert_eq!(
        m.counter("verbs_lost_total").get(),
        stats.verbs_lost,
        "mirrored counter must match the fabric's cumulative stats"
    );
    assert_eq!(m.counter("verb_retries_total").get(), stats.verb_retries);
    set.shutdown();
}

#[test]
fn composed_chaos_every_request_terminates_with_zero_corruption() {
    // Verb loss + timed instance kills + a directed partition that heals
    // mid-run: the full §7 battery at once. Every admitted request must
    // reach a typed terminal, delivered payloads must be byte-exact, and
    // the recovery counters must show each mechanism actually fired.
    let mut cfg = base_config();
    cfg.faults = Some(FaultSettings {
        verb_loss_prob: 0.02,
        ..Default::default()
    });
    cfg.chaos.kill_every_ms = 200;
    cfg.chaos.seed = 11;
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let opts = SubmitOptions::default()
        .with_retry(RetryPolicy::attempts(4, Duration::ZERO));
    let mut handles = Vec::new();
    let mut done = 0;
    let mut failed = 0;
    for i in 0..30u8 {
        if i == 10 {
            // Cut a node-pair partition one third in...
            set.fabric.start_partition(4, 1);
        }
        if i == 20 {
            // ...and heal it two thirds in; the backlog must drain.
            set.fabric.heal_partition();
        }
        let payload = vec![i; 64];
        if let Ok(h) =
            set.submit_with(AppId(1), Payload::Bytes(payload.clone()), opts)
        {
            handles.push((h, payload));
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    set.fabric.heal_partition(); // idempotent; guards an early-exhausted loop
    for (h, payload) in &handles {
        match h.wait(Duration::from_secs(20)) {
            WaitOutcome::Done(bytes) => {
                let msg = WorkflowMessage::decode(&bytes).unwrap();
                assert_eq!(msg.payload, Payload::Bytes(payload.clone()));
                done += 1;
            }
            WaitOutcome::Failed => failed += 1,
            other => panic!("request must reach a terminal state, got {other:?}"),
        }
    }
    assert_eq!(done + failed, handles.len(), "no request may hang");
    assert!(done >= 1, "work must keep completing under composed chaos");

    set.sync_fault_counters();
    let stats = set.fault_stats().expect("fault state");
    assert!(stats.verbs_lost >= 1, "loss injection must have fired");
    assert!(
        stats.partitioned_ops >= 1,
        "the partition window must have rejected verbs on the victim links"
    );
    assert!(
        set.metrics().counter("chaos_kills").get() >= 1,
        "the chaos driver must have killed at least one instance"
    );
    set.shutdown();
}
