//! E7: the §6.1 liveness argument, case by case.
//!
//! Each test reproduces one of the paper's Case1–Case8 interleavings
//! using the stepped `ProducerSession` API (Lock/GH/WB/WL/UH/Unlock as
//! separate calls) and a `ManualClock` to trigger the lock-timeout steal
//! deterministically. The invariant checked everywhere: the consumer is
//! never blocked, never desynchronized, and always reads valid data
//! again after the failure — with corruption confined to the collided
//! entry (checksum-detected), exactly Theorem 2's guarantee.
//!
//! The final tests are the DESIGN.md §6 ablation (double ring recovers
//! where a single ring deadlocks) and a randomized fault-sweep.

use onepiece::rdma::Fabric;
use onepiece::ringbuf::{
    create_ring, DieAt, PopError, PushError, RingConfig, RingConsumer, RingProducer,
    SingleRingConsumer, SingleRingProducer, SingleRingPushError,
};
use onepiece::util::{ManualClock, Rng};
use std::sync::Arc;

const TIMEOUT_NS: u64 = 1_000;

struct Harness {
    fabric: Fabric,
    clock: ManualClock,
    cfg: RingConfig,
    consumer: RingConsumer,
}

impl Harness {
    fn new() -> Self {
        let cfg = RingConfig {
            nslots: 16,
            cap_bytes: 4096,
            lock_timeout_ns: TIMEOUT_NS,
            max_lock_spins: 64,
        };
        let fabric = Fabric::ideal();
        let (id, region) = create_ring(&fabric, cfg);
        let clock = ManualClock::new();
        clock.set(1);
        let consumer = RingConsumer::new(region, cfg);
        let _ = id;
        Self { fabric, clock, cfg, consumer }
    }

    fn producer(&self, pid: u64) -> RingProducer {
        let qp = self.fabric.connect(onepiece::rdma::RegionId(0)).unwrap();
        RingProducer::new(qp, self.cfg, Arc::new(self.clock.clone()), pid)
    }

    /// Advance past the lock timeout (the paper's TL event).
    fn tl(&self) {
        self.clock.advance(TIMEOUT_NS + 1);
    }
}

/// Case 1: X lost immediately after Lock; Y steals and completes.
/// Z reads Y's valid data.
#[test]
fn case1_lost_after_lock() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    let _x_session = x.begin().unwrap(); // X dies holding the lock
    h.tl();
    let out = y.push(b"from-Y", None).unwrap();
    assert!(out.stole_lock, "Y must have stolen the timed-out lock");
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"from-Y");
    assert!(h.consumer.pop().is_none());
}

/// Case 2: X delayed after GH; Y steals and completes; X then overwrites
/// Y's frame and fails WL on the busy bit. Same sizes => Z reads X's
/// complete overwrite (valid); different sizes => checksum discard, and
/// the ring keeps working.
#[test]
fn case2_delayed_overwrite_same_size() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    let mut xs = x.begin().unwrap();
    xs.gh().unwrap();
    h.tl();
    y.push(b"YYYYYY", None).unwrap(); // steals, completes

    xs.reserve(6).unwrap();
    xs.wb(b"XXXXXX").unwrap(); // overwrites Y's frame (same placement)
    assert_eq!(xs.wl(), Err(PushError::LostRace));

    // Same frame size: X's overwrite is a complete, self-consistent
    // frame, so Z reads X's data — matching the paper: "if the data sizes
    // from X and Y match, Z reads valid data".
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"XXXXXX");
    // Ring continues to work.
    y.push(b"after", None).unwrap();
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"after");
}

#[test]
fn case2_delayed_overwrite_different_size() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    let mut xs = x.begin().unwrap();
    xs.gh().unwrap();
    h.tl();
    y.push(&[b'Y'; 40], None).unwrap();

    xs.reserve(3).unwrap();
    xs.wb(b"XXX").unwrap(); // overwrites the front of Y's 40-byte frame
    assert_eq!(xs.wl(), Err(PushError::LostRace));

    // X's *shorter* frame is a complete, self-consistent frame embedded
    // at the front of Y's slot, so Z reads X's data (our framing is
    // strictly stronger than the paper's "otherwise skip": corruption is
    // only visible when the overwrite is partial — see Case 6). What
    // matters for liveness: the cursor advances by Y's slot length and
    // the ring keeps working.
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"XXX");
    // Cursor advanced correctly: next push is readable.
    y.push(b"clean", None).unwrap();
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"clean");
}

/// Case 3: X's WB lands between Y's WB and Y's WL; X's WL fails.
#[test]
fn case3_wb_interleaved_before_wl() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    let mut xs = x.begin().unwrap();
    xs.gh().unwrap();
    h.tl();
    let mut ys = y.begin().unwrap();
    ys.gh().unwrap();
    ys.reserve(8).unwrap();
    ys.wb(b"YYYYYYYY").unwrap();
    xs.reserve(8).unwrap();
    xs.wb(b"XXXXXXXX").unwrap(); // late overwrite
    ys.wl().unwrap();
    ys.uh().unwrap();
    ys.unlock().unwrap();
    assert_eq!(xs.wl(), Err(PushError::LostRace));

    // Same size: X's complete frame reads back valid (its own checksum).
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"XXXXXXXX");
    y.push(b"next", None).unwrap();
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"next");
}

/// Case 4: X's WL lands first; Y's WL fails; X updates the header and Z
/// reads X's data.
#[test]
fn case4_x_finalizes_first() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    let mut xs = x.begin().unwrap();
    xs.gh().unwrap();
    h.tl();
    let mut ys = y.begin().unwrap();
    ys.gh().unwrap();
    ys.reserve(8).unwrap();
    ys.wb(b"YYYYYYYY").unwrap();
    xs.reserve(8).unwrap();
    xs.wb(b"XXXXXXXX").unwrap();
    xs.wl().unwrap(); // X wins the slot
    assert_eq!(ys.wl(), Err(PushError::LostRace));
    xs.uh().unwrap();
    xs.unlock().unwrap(); // fails silently: Y holds the stolen lock — ok

    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"XXXXXXXX");
    // Lock was left held by the aborted Y... Y released on its failed WL.
    x.push(b"continues", None).unwrap();
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"continues");
}

/// Case 5: X writes first, Y overwrites and finalizes; Z reads Y's data.
#[test]
fn case5_y_overwrites_and_finalizes() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    let mut xs = x.begin().unwrap();
    xs.gh().unwrap();
    h.tl();
    let mut ys = y.begin().unwrap();
    ys.gh().unwrap();
    xs.reserve(8).unwrap();
    xs.wb(b"XXXXXXXX").unwrap();
    ys.reserve(8).unwrap();
    ys.wb(b"YYYYYYYY").unwrap(); // Y overwrites X
    ys.wl().unwrap();
    assert_eq!(xs.wl(), Err(PushError::LostRace));
    ys.uh().unwrap();
    ys.unlock().unwrap();

    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"YYYYYYYY");
}

/// Case 6: X's WL wins but Y's bytes are in the buffer. Same size means
/// the frame is Y's complete valid frame; different sizes corrupt.
#[test]
fn case6_size_from_x_data_from_y() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    let mut xs = x.begin().unwrap();
    xs.gh().unwrap();
    h.tl();
    let mut ys = y.begin().unwrap();
    ys.gh().unwrap();
    xs.reserve(4).unwrap();
    xs.wb(b"XXXX").unwrap();
    ys.reserve(32).unwrap();
    ys.wb(&[b'Y'; 32]).unwrap(); // Y's larger frame overwrites X's
    xs.wl().unwrap(); // slot records X's (smaller) length
    assert_eq!(ys.wl(), Err(PushError::LostRace));
    xs.uh().unwrap();

    // Slot length = X's frame (16B); buffer holds Y's 40-byte frame
    // prefix: the embedded payload_len (32) no longer fits X's frame
    // size => corrupted, skipped via size metadata.
    match h.consumer.pop().unwrap() {
        Err(PopError::Corrupted { .. }) => {}
        other => panic!("expected corruption, got {other:?}"),
    }
    // Recovery: the byte cursor follows the size region, so subsequent
    // messages read fine.
    x.push(b"recovered", None).unwrap();
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"recovered");
}

/// Case 7: X dies *after* WL (size written, header not). Y detects the
/// busy slot during GH, advances the header on X's behalf, and appends
/// its own entry. Z reads both X's and Y's data.
#[test]
fn case7_lost_after_wl_header_recovery() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    assert_eq!(
        x.push(b"X-committed", Some(DieAt::AfterWl)),
        Err(PushError::Died(DieAt::AfterWl))
    );
    h.tl();
    let out = y.push(b"Y-following", None).unwrap();
    assert!(out.stole_lock);
    assert_eq!(out.vslot, 1, "Y must land after X's recovered entry");

    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"X-committed");
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"Y-following");
    assert!(h.consumer.pop().is_none());
}

/// Case 8: X completes everything except Unlock. Z reads X's data; the
/// next producer steals the stale lock after TL and proceeds.
#[test]
fn case8_lost_before_unlock() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    assert_eq!(
        x.push(b"X-full", Some(DieAt::AfterUh)),
        Err(PushError::Died(DieAt::AfterUh))
    );
    // X's entry is fully committed: Z reads it immediately.
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"X-full");

    h.tl();
    let out = y.push(b"Y-next", None).unwrap();
    assert!(out.stole_lock);
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"Y-next");
}

/// Die-after-GH behaves like Case 1 (nothing was written).
#[test]
fn lost_after_gh() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);
    assert!(x.push(b"x", Some(DieAt::AfterGh)).is_err());
    h.tl();
    y.push(b"y", None).unwrap();
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"y");
}

/// Die-after-WB: frame bytes written, size not. The slot stays non-busy,
/// so Z sees nothing; the stealer writes over it and the ring moves on.
#[test]
fn lost_after_wb() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);
    assert!(x.push(b"halfway", Some(DieAt::AfterWb)).is_err());
    assert!(h.consumer.pop().is_none(), "uncommitted frame is invisible");
    h.tl();
    y.push(b"fresh", None).unwrap();
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"fresh");
}

/// Mid-batch death, variant A: the producer dies after the coalesced
/// WB but before any WL. No size word was published, so the batch is
/// invisible; a stealer takes the lock and the ring moves on over the
/// orphaned bytes.
#[test]
fn push_many_lost_after_wb_is_invisible() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    let payloads: Vec<&[u8]> = vec![b"aaaa", b"bbbbbbbb", b"cc"];
    let mut xs = x.begin().unwrap();
    xs.gh().unwrap();
    assert_eq!(xs.reserve_many(&payloads).unwrap(), 3);
    xs.wb_many(&payloads).unwrap();
    drop(xs); // X dies: frames written, nothing published

    assert!(h.consumer.pop().is_none(), "unpublished batch is invisible");
    h.tl();
    let out = y.push(b"fresh", None).unwrap();
    assert!(out.stole_lock);
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"fresh");
    assert!(h.consumer.pop().is_none());
}

/// Mid-batch death, variant B: the producer dies after the k-th WL
/// (here 2 of 3 slots published, header never advanced). The next
/// producer's GH runs Case-7 recovery over *both* committed slots, the
/// consumer reads them, and every slot in the ring is eventually freed.
#[test]
fn push_many_lost_after_kth_wl_case7_frees_every_slot() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    let payloads: Vec<&[u8]> = vec![b"first-of-batch", b"second-of-batch", b"third"];
    let mut xs = x.begin().unwrap();
    xs.gh().unwrap();
    assert_eq!(xs.reserve_many(&payloads).unwrap(), 3);
    xs.wb_many(&payloads).unwrap();
    xs.wl_at(0).unwrap();
    xs.wl_at(1).unwrap();
    drop(xs); // X dies between the 2nd and 3rd WL (before UH/unlock)

    h.tl();
    let out = y.push(b"after-recovery", None).unwrap();
    assert!(out.stole_lock);
    assert_eq!(out.vslot, 2, "Y lands after X's two recovered entries");

    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"first-of-batch");
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"second-of-batch");
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"after-recovery");
    assert!(h.consumer.pop().is_none());

    // Every slot is free again: fill the whole slot ring and drain it.
    for i in 0..h.cfg.nslots {
        y.push(&[i as u8; 8], None).unwrap();
    }
    for i in 0..h.cfg.nslots {
        assert_eq!(h.consumer.pop().unwrap().unwrap(), vec![i as u8; 8]);
    }
    assert!(h.consumer.pop().is_none());
}

/// The cached-header fast path engages after a successful push (fewer
/// verbs, same bytes) and a stale cache is rejected by the validation
/// read, not trusted.
#[test]
fn cached_header_fast_path_spends_fewer_verbs() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let cold = x.push(b"cold", None).unwrap();
    assert!(!cold.cache_hit, "first push has no cache");
    let warm = x.push(b"warm", None).unwrap();
    assert!(warm.cache_hit, "tail unchanged: fast path");
    assert!(
        warm.verbs < cold.verbs,
        "fast path must save verbs ({} vs {})",
        warm.verbs,
        cold.verbs
    );
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"cold");
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"warm");

    // Another producer moves the tail: the validation read must reject
    // the stale cache (slow path) and still place the frame correctly.
    let y = h.producer(2);
    y.push(b"interloper", None).unwrap();
    let out = x.push(b"after-move", None).unwrap();
    assert!(!out.cache_hit, "stale tail rejected by the validation read");
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"interloper");
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"after-move");
}

/// A cached-header producer racing a lock stealer: the stealer takes
/// the producer's target slot, the WL CAS detects it (LostRace), and
/// the retry falls back to the full GH scan.
#[test]
fn cached_header_producer_races_lock_stealer_and_falls_back() {
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    x.push(b"warm-up", None).unwrap(); // warms X's header cache
    let mut xs = x.begin().unwrap();
    xs.gh().unwrap();
    assert!(xs.used_cache(), "second push takes the fast path");
    xs.reserve(4).unwrap();
    xs.wb(b"XXXX").unwrap();

    // X stalls past the timeout; Y steals and takes the same slot.
    h.tl();
    let out = y.push(b"YYYY", None).unwrap();
    assert!(out.stole_lock);

    assert_eq!(xs.wl(), Err(PushError::LostRace), "stale fast path detected at WL");
    drop(xs);

    // The failed WL invalidated the cache: the retry runs the full GH
    // scan and lands after Y.
    let out = x.push(b"retry", None).unwrap();
    assert!(!out.cache_hit, "fallback to the full GH after LostRace");
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"warm-up");
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"YYYY");
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"retry");
    assert!(h.consumer.pop().is_none());
}

/// `push_many` places frames exactly where the same sequence of single
/// pushes would — including across the wrap boundary (the per-frame
/// wrap rule) — verified by running twin rings in lockstep.
#[test]
fn push_many_wrap_matches_sequential_pushes() {
    let cfg = RingConfig {
        nslots: 16,
        cap_bytes: 256,
        lock_timeout_ns: TIMEOUT_NS,
        max_lock_spins: 64,
    };
    let mk = |pid: u64| {
        let fabric = Fabric::ideal();
        let (id, region) = create_ring(&fabric, cfg);
        let clock = ManualClock::new();
        clock.set(1);
        let prod = RingProducer::new(
            fabric.connect(id).unwrap(),
            cfg,
            Arc::new(clock),
            pid,
        );
        (prod, RingConsumer::new(region, cfg))
    };
    let (pa, mut ca) = mk(1); // batched ring
    let (pb, mut cb) = mk(1); // sequential ring

    // 48+112+24+64 = 248 bytes of frames per round on a 256-byte ring:
    // every round crosses the wrap boundary at a different phase.
    let sizes = [40usize, 100, 16, 56];
    for round in 0..12u8 {
        let payloads: Vec<Vec<u8>> = sizes
            .iter()
            .map(|&s| vec![round; s])
            .collect();
        let refs: Vec<&[u8]> = payloads.iter().map(Vec::as_slice).collect();
        let out = pa.push_many(&refs, None).unwrap();
        assert_eq!(out.accepted, refs.len(), "round {round}: batch fits");
        for p in &payloads {
            pb.push(p, None).unwrap();
        }
        for p in &payloads {
            assert_eq!(&ca.pop().unwrap().unwrap(), p, "round {round}");
            assert_eq!(&cb.pop().unwrap().unwrap(), p, "round {round}");
        }
        assert_eq!(
            ca.cursor(),
            cb.cursor(),
            "round {round}: identical placement (same vslot + voff advance)"
        );
    }
    assert!(ca.pop().is_none());
    assert!(cb.pop().is_none());
}

/// A one-frame `push_many` leaves the ring region byte-identical to a
/// plain `push` — batching disabled therefore *is* the single-push
/// protocol, not a near miss.
#[test]
fn push_many_of_one_is_byte_identical_to_push() {
    let cfg = RingConfig {
        nslots: 8,
        cap_bytes: 512,
        lock_timeout_ns: TIMEOUT_NS,
        max_lock_spins: 64,
    };
    let clock = ManualClock::new();
    clock.set(7); // same lock timestamps on both rings
    let mk = || {
        let fabric = Fabric::ideal();
        let (id, region) = create_ring(&fabric, cfg);
        let prod = RingProducer::new(
            fabric.connect(id).unwrap(),
            cfg,
            Arc::new(clock.clone()),
            1,
        );
        (prod, region)
    };
    let (pa, ra) = mk();
    let (pb, rb) = mk();
    pa.push(b"identical payload bytes", None).unwrap();
    let out = pb.push_many(&[b"identical payload bytes"], None).unwrap();
    assert_eq!(out.accepted, 1);
    for off in (0..cfg.region_len()).step_by(8) {
        assert_eq!(
            ra.load_u64(off),
            rb.load_u64(off),
            "word at byte {off} differs"
        );
    }
}

/// DESIGN.md §6 ablation: under the same fault (producer dies between
/// write and commit), the single-ring baseline deadlocks permanently
/// while the double ring recovers via timeout + size region.
#[test]
fn ablation_single_ring_deadlocks_double_ring_recovers() {
    // --- single ring: deadlock ---
    let fabric = Fabric::ideal();
    let (sid, sregion) = fabric.register(SingleRingProducer::region_len(4096));
    let sp1 = SingleRingProducer::new(fabric.connect(sid).unwrap(), 4096, 1, 500);
    sp1.push(b"dies-before-commit", true).unwrap();
    let sp2 = SingleRingProducer::new(fabric.connect(sid).unwrap(), 4096, 2, 500);
    assert_eq!(
        sp2.push(b"blocked-forever", false),
        Err(SingleRingPushError::Deadlocked)
    );
    let mut scons = SingleRingConsumer::new(sregion, 4096);
    assert!(scons.pop().is_none(), "consumer starves too");

    // --- double ring: recovers ---
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);
    assert!(x.push(b"dies", Some(DieAt::AfterWl)).is_err());
    h.tl();
    y.push(b"recovered", None).unwrap();
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"dies");
    assert_eq!(h.consumer.pop().unwrap().unwrap(), b"recovered");
}

/// Randomized fault sweep (property-style, no proptest offline): any
/// die-point at any time, interleaved with healthy producers, must never
/// stall the consumer for more than one TL, and every *successfully
/// pushed* message must eventually be read back intact or detected as
/// corrupted — never silently mangled.
#[test]
fn randomized_fault_sweep() {
    let die_points = [
        None,
        Some(DieAt::AfterLock),
        Some(DieAt::AfterGh),
        Some(DieAt::AfterWb),
        Some(DieAt::AfterWl),
        Some(DieAt::AfterUh),
    ];
    for seed in 0..20u64 {
        let mut h = Harness::new();
        let mut rng = Rng::new(seed);
        let mut expected: Vec<Vec<u8>> = Vec::new();
        let mut got: Vec<Vec<u8>> = Vec::new();
        let mut corrupted = 0usize;

        for round in 0..60u32 {
            let pid = 1 + rng.below(4);
            let p = h.producer(pid);
            let die = *rng.choose(&die_points).unwrap();
            let len = 1 + rng.below(64) as usize;
            let payload = vec![(round % 251) as u8; len];
            h.tl(); // every round leaves enough time to steal stale locks
            match p.push(&payload, die) {
                Ok(_) => expected.push(payload),
                Err(PushError::Died(_)) => {} // lost sender
                Err(PushError::Full) => {}    // consumer drains below
                Err(e) => panic!("unexpected {e:?}"),
            }
            // Consumer drains opportunistically (wait-free).
            while let Some(r) = h.consumer.pop() {
                match r {
                    Ok(v) => got.push(v),
                    Err(_) => corrupted += 1,
                }
            }
        }
        while let Some(r) = h.consumer.pop() {
            match r {
                Ok(v) => got.push(v),
                Err(_) => corrupted += 1,
            }
        }
        // Every intact read must be byte-identical to some expected push
        // (prefix order preserved for committed pushes).
        // Note: die-after-WL pushes are *also* delivered (Case 7), so
        // `got` may exceed `expected`; verify content integrity instead.
        for v in &got {
            assert!(
                v.iter().all(|&b| b == v[0]),
                "seed {seed}: silently corrupted message {v:?}"
            );
        }
        assert!(
            got.len() >= expected.len(),
            "seed {seed}: committed pushes lost: got {} < expected {}",
            got.len(),
            expected.len()
        );
        // Corruption is possible but must be rare (single-entry blast
        // radius per §6.1).
        assert!(corrupted <= 12, "seed {seed}: corrupted {corrupted}");
    }
}

// --- Rendezvous descriptor frames under the same failure model ---
//
// A descriptor frame's kind bit rides the WL CAS (`FRAME_DESC` in the
// size word), so it inherits the Case1–Case8 liveness argument wholesale;
// what is new is the *payload* failure surface: the staged slab the
// descriptor points at can be deregistered (producer death), re-staged
// (generation reuse), or overwritten mid-pull (torn read). Every one of
// those must strand the message — never deliver corrupt bytes.

/// The `FRAME_DESC` bit is exactly as crash-consistent as the busy bit:
/// a producer dying after WL (Case 7) leaves a committed descriptor
/// frame whose kind and 40-byte body the recovery path preserves.
#[test]
fn descriptor_kind_survives_case7_recovery() {
    use onepiece::ringbuf::FrameKind;
    let mut h = Harness::new();
    let x = h.producer(1);
    let y = h.producer(2);

    let desc_body = [0xA5u8; 40];
    assert_eq!(
        x.push_frame(&desc_body, FrameKind::Descriptor, Some(DieAt::AfterWl)),
        Err(PushError::Died(DieAt::AfterWl))
    );
    h.tl();
    let out = y.push(b"eager-after", None).unwrap();
    assert!(out.stole_lock, "Y recovers X's committed slot via GH");

    let first = h.consumer.pop_frame().unwrap().unwrap();
    assert_eq!(first.kind, FrameKind::Descriptor, "kind bit recovered");
    assert_eq!(first.payload, desc_body);
    let second = h.consumer.pop_frame().unwrap().unwrap();
    assert_eq!(second.kind, FrameKind::Eager);
    assert_eq!(second.payload, b"eager-after");
}

/// Helper for the slab-failure tests: an endpoint plus a raw producer
/// and stager on the same fabric (the transport sender's internals,
/// exploded so the test can fail each part independently).
fn rendezvous_rig() -> (
    onepiece::transport::RdmaEndpoint,
    RingProducer,
    onepiece::rdma::PayloadStager,
    Fabric,
) {
    let fabric = Fabric::ideal();
    let cfg = RingConfig::default();
    let ep = onepiece::transport::RdmaEndpoint::new(&fabric, cfg);
    let qp = fabric.connect(ep.region_id()).unwrap();
    let producer = RingProducer::new(qp, cfg, Arc::new(onepiece::util::SystemClock), 1);
    let stager = onepiece::rdma::PayloadStager::new(fabric.clone());
    (ep, producer, stager, fabric)
}

fn rendezvous_msg() -> onepiece::transport::WorkflowMessage {
    use onepiece::transport::{AppId, MessageHeader, Payload, StageId, WorkflowMessage};
    WorkflowMessage {
        header: MessageHeader {
            uid: onepiece::util::Uid(77),
            ts_ns: 1,
            app: AppId(1),
            stage: StageId(0),
            origin: onepiece::util::NodeId(2),
        },
        payload: Payload::Bytes(vec![0x5C; 4096]),
    }
}

/// Producer death between the descriptor push and the consumer's pull:
/// the stager's Drop deregisters the slab, so the pull strands the
/// message (the recovery sweep replays it from a checkpoint — see
/// tests/fault_recovery.rs) and the region is actually gone.
#[test]
fn producer_death_after_descriptor_push_strands_and_reclaims_region() {
    use onepiece::ringbuf::FrameKind;
    let (mut ep, producer, mut stager, fabric) = rendezvous_rig();
    let enc = rendezvous_msg().encode();
    let desc = stager.stage(&enc, 1);
    producer
        .push_frame(&desc.encode(), FrameKind::Descriptor, None)
        .unwrap();
    drop(stager); // producer dies: slab deregistered

    assert!(
        fabric.local(desc.region).is_err(),
        "dead producer's staged region must be reclaimed"
    );
    assert!(ep.recv().is_none(), "descriptor strands");
    assert_eq!(ep.corrupted_count(), 1, "counted, not delivered");
}

/// Generation reuse racing a slow consumer: the slab is re-staged before
/// the pull, so the descriptor's generation no longer matches. The stale
/// message strands; the *new* staging still delivers intact.
#[test]
fn stale_generation_on_slab_reuse_is_stranded_never_corrupt() {
    use onepiece::ringbuf::FrameKind;
    use onepiece::rdma::PAYLOAD_RELEASE_OFF;
    let (mut ep, producer, mut stager, fabric) = rendezvous_rig();
    let stale = rendezvous_msg().encode();
    let d1 = stager.stage(&stale, 1);
    producer
        .push_frame(&d1.encode(), FrameKind::Descriptor, None)
        .unwrap();

    // The release races ahead of the actual read (a crashed-then-
    // restarted consumer, or a buggy double release): the producer
    // legally reuses the slab for a fresh payload.
    fabric
        .local(d1.region)
        .unwrap()
        .fetch_add_u64(PAYLOAD_RELEASE_OFF, 1);
    let mut fresh = rendezvous_msg();
    fresh.header.uid = onepiece::util::Uid(78);
    let fresh_enc = fresh.encode();
    let d2 = stager.stage(&fresh_enc, 1);
    assert_eq!(d2.region, d1.region, "the slab was reused");
    assert!(d2.generation > d1.generation);
    producer
        .push_frame(&d2.encode(), FrameKind::Descriptor, None)
        .unwrap();

    // d1's pull sees d2's generation: stranded. d2 delivers intact.
    let got = ep.recv().expect("the fresh staging must deliver");
    assert_eq!(got, fresh);
    assert_eq!(ep.corrupted_count(), 1, "stale descriptor stranded");
    assert!(ep.recv().is_none());
}

/// A torn payload (bytes overwritten under an unchanged generation —
/// the mid-READ reuse window) fails the descriptor checksum: stranded,
/// and crucially *not released*, so the producer cannot reclaim a slab
/// a reader might still be traversing.
#[test]
fn torn_payload_fails_checksum_and_is_not_released() {
    use onepiece::ringbuf::FrameKind;
    use onepiece::rdma::{PAYLOAD_HDR_BYTES, PAYLOAD_RELEASE_OFF};
    let (mut ep, producer, mut stager, fabric) = rendezvous_rig();
    let enc = rendezvous_msg().encode();
    let desc = stager.stage(&enc, 1);
    producer
        .push_frame(&desc.encode(), FrameKind::Descriptor, None)
        .unwrap();

    // Scribble over the staged payload without touching the generation
    // word — the worst case the checksum exists for.
    let slab = fabric.local(desc.region).unwrap();
    slab.write_bytes(PAYLOAD_HDR_BYTES + 64, &[0xFF; 128]);

    assert!(ep.recv().is_none(), "torn payload must strand");
    assert_eq!(ep.corrupted_count(), 1);
    assert_eq!(
        slab.load_u64(PAYLOAD_RELEASE_OFF),
        0,
        "a failed validation must not release the slab"
    );
    assert_eq!(stager.live(), 1, "still staged: reclaim stays blocked");
}

/// Concurrent stress with live threads (no injected deaths): all messages
/// delivered intact under real contention.
#[test]
fn concurrent_stress_no_faults() {
    let cfg = RingConfig {
        nslots: 128,
        cap_bytes: 1 << 16,
        // Dwarf worst-case scheduling stalls: stealing from a live-but-
        // descheduled holder triggers the (detected) corruption path.
        lock_timeout_ns: 5_000_000_000,
        max_lock_spins: 1 << 22,
    };
    let fabric = Fabric::ideal();
    let (id, region) = create_ring(&fabric, cfg);
    let mut consumer = RingConsumer::new(region, cfg);
    let clock = Arc::new(onepiece::util::SystemClock);

    let nprod = 4;
    let per = 200;
    let handles: Vec<_> = (0..nprod)
        .map(|p| {
            let qp = fabric.connect(id).unwrap();
            let clock = clock.clone();
            std::thread::spawn(move || {
                let prod = RingProducer::new(qp, cfg, clock, p + 1);
                let mut sent = 0;
                while sent < per {
                    let payload = vec![p as u8; 8 + (sent % 50)];
                    match prod.push(&payload, None) {
                        Ok(_) => sent += 1,
                        Err(PushError::Full) | Err(PushError::LostRace) => {
                            std::thread::yield_now();
                        }
                        Err(e) => panic!("{e:?}"),
                    }
                }
            })
        })
        .collect();

    let mut got = 0;
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while got < nprod as usize * per && std::time::Instant::now() < deadline {
        match consumer.pop() {
            Some(Ok(_)) => got += 1,
            Some(Err(e)) => panic!("corruption without faults: {e:?}"),
            None => std::thread::yield_now(),
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(got, nprod as usize * per);
}
