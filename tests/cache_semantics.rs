//! Artifact-cache semantics battery: cached results must be
//! byte-identical to the uncached pipeline, racing fills publish exactly
//! once, eviction respects capacity, dropped requests never poison the
//! cache, and the config salt invalidates.

use onepiece::cache::{ArtifactCache, WORKFLOW_STAGE};
use onepiece::client::{Gateway, SubmitOptions, WaitOutcome};
use onepiece::config::{CacheSettings, ClusterConfig, ExecModel, FabricKind};
use onepiece::metrics::Registry;
use onepiece::rdma::Fabric;
use onepiece::runtime::StageExecutor;
use onepiece::transport::{AppId, Payload, WorkflowMessage};
use onepiece::util::{Clock, SystemClock};
use onepiece::workflow::{AppLogic, EchoLogic};
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn sim_config(stage_ms: f64, cached: bool) -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: stage_ms };
        s.exec_ms = stage_ms;
    }
    cfg.idle_pool = 0;
    if cached {
        cfg.cache = Some(CacheSettings::default());
    }
    cfg
}

fn build(cfg: &ClusterConfig, logic: Arc<dyn AppLogic>) -> WorkflowSet {
    let pool = build_pool(cfg, None);
    WorkflowSet::build(cfg.clone(), vec![vec![1, 1, 1, 1]], logic, pool)
}

fn mk_cache(settings: &CacheSettings) -> (ArtifactCache, Registry) {
    let reg = Registry::new();
    let clock: Arc<dyn Clock> = Arc::new(SystemClock);
    (ArtifactCache::new(Fabric::ideal(), clock, settings, &reg), reg)
}

/// Pass-through logic that counts stage executions — the thing a cache
/// hit must make not happen.
struct CountingEcho(Arc<AtomicU64>);

impl AppLogic for CountingEcho {
    fn execute(
        &self,
        _stage: &str,
        exec: &StageExecutor,
        msg: &WorkflowMessage,
    ) -> anyhow::Result<Payload> {
        self.0.fetch_add(1, Ordering::SeqCst);
        exec.run(&[])?;
        Ok(msg.payload.clone())
    }
}

/// Acceptance criterion: for the same prompts, a cache-enabled set must
/// produce byte-identical payloads to an uncached set — on misses *and*
/// on hits.
#[test]
fn cached_results_are_byte_identical_to_uncached() {
    let uncached = build(&sim_config(1.0, false), Arc::new(EchoLogic));
    let cached = build(&sim_config(1.0, true), Arc::new(EchoLogic));
    std::thread::sleep(Duration::from_millis(80));

    let prompts: Vec<Payload> = (0..6u8)
        .map(|i| Payload::Bytes(vec![i % 3; 32])) // each prompt twice
        .collect();
    for prompt in &prompts {
        let mut results = Vec::new();
        for set in [&uncached, &cached] {
            let h = set.submit(AppId(1), prompt.clone()).expect("must admit");
            let WaitOutcome::Done(bytes) = h.wait(Duration::from_secs(10)) else {
                panic!("pipeline must complete")
            };
            let msg = WorkflowMessage::decode(&bytes).unwrap();
            assert_eq!(msg.header.uid, h.uid(), "result carries its own uid");
            results.push(msg.payload);
        }
        assert_eq!(results[0], results[1], "cached == uncached for {prompt:?}");
        assert_eq!(results[0], *prompt, "echo returns the prompt itself");
    }
    // The repeats actually exercised the cache.
    let hits: u64 = cached
        .metrics()
        .counters_snapshot()
        .into_iter()
        .filter(|(k, _)| k.starts_with("cache_hits."))
        .map(|(_, v)| v)
        .sum();
    assert!(hits > 0, "repeat prompts must hit");
    uncached.shutdown();
    cached.shutdown();
}

/// Racing fills: N threads fill the same key concurrently; exactly one
/// wins and every subsequent lookup returns the winner's bytes.
#[test]
fn racing_fills_publish_exactly_once() {
    let (cache, reg) = mk_cache(&CacheSettings::default());
    let cache = Arc::new(cache);
    let key = cache.key_for(AppId(1), "vae", &Payload::Bytes(vec![1, 2, 3]));
    let wins: Vec<bool> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8u8)
            .map(|i| {
                let cache = cache.clone();
                s.spawn(move || {
                    let value: Arc<[u8]> = vec![i; 128].into();
                    cache.fill(key, &value)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(
        wins.iter().filter(|&&w| w).count(),
        1,
        "first-writer-wins: exactly one racing fill may publish"
    );
    assert_eq!(reg.counter("cache_fills_total").get(), 1);
    // The published value is one of the candidates, stable across reads.
    let v1 = cache.lookup("vae", key).expect("filled");
    let v2 = cache.lookup("vae", key).expect("still filled");
    assert_eq!(v1, v2);
    assert_eq!(v1.len(), 128);
    assert!(v1.iter().all(|&b| b == v1[0]), "no torn write");
}

/// Two concurrent identical submissions: single-flight (plus the stage
/// tier) collapses the stage work to one execution per stage.
#[test]
fn concurrent_identical_requests_execute_once_per_stage() {
    let executions = Arc::new(AtomicU64::new(0));
    // 150 ms stages so the two requests genuinely overlap in the
    // pipeline. All stages Individual: in Collaboration mode every
    // worker executes by design, which would skew the count.
    let mut cfg = sim_config(150.0, true);
    for s in cfg.apps[0].stages.iter_mut() {
        s.mode = onepiece::config::SchedMode::Individual;
    }
    let set = build(&cfg, Arc::new(CountingEcho(executions.clone())));
    std::thread::sleep(Duration::from_millis(80));

    let prompt = Payload::Bytes(b"expensive prompt".to_vec());
    let h1 = set.submit(AppId(1), prompt.clone()).expect("must admit");
    let h2 = set.submit(AppId(1), prompt).expect("must admit");
    for h in [h1, h2] {
        assert!(
            matches!(h.wait(Duration::from_secs(20)), WaitOutcome::Done(_)),
            "both identical requests must complete"
        );
    }
    let stages = 4;
    assert_eq!(
        executions.load(Ordering::SeqCst),
        stages,
        "two identical in-flight requests must execute each stage once"
    );
    set.shutdown();
}

/// Capacity pressure: inserting more than fits evicts in LRU order and
/// counts it; the resident set stays bounded.
#[test]
fn eviction_under_capacity_pressure() {
    let settings = CacheSettings {
        hot_capacity_bytes: 512,
        warm_capacity_bytes: 1_024,
        ..CacheSettings::default()
    };
    let (cache, reg) = mk_cache(&settings);
    let keys: Vec<_> = (0..16u8)
        .map(|i| cache.key_for(AppId(1), "s", &Payload::Bytes(vec![i])))
        .collect();
    for key in &keys {
        let value: Arc<[u8]> = vec![7u8; 256].into();
        assert!(cache.fill(*key, &value));
    }
    assert!(
        reg.counter("cache_evictions_total").get() > 0,
        "16 × 256 B into a 1 KiB warm tier must evict"
    );
    let (hot, warm) = cache.tier_bytes();
    assert!(hot <= 512, "hot tier over capacity: {hot}");
    assert!(warm <= 1_024, "warm tier over capacity: {warm}");
    // LRU: the newest key survived, the oldest did not.
    assert!(cache.lookup("s", keys[15]).is_some());
    assert!(cache.lookup("s", keys[0]).is_none());
}

/// A deadline-dropped request must never seed the cache: the next
/// identical submission misses, runs fresh, and completes correctly.
#[test]
fn dropped_request_never_poisons_the_cache() {
    let mut cfg = sim_config(1.0, true);
    // Slow diffusion so the deadline lapses mid-pipeline.
    cfg.apps[0].stages[2].exec = ExecModel::Simulated { ms: 300.0 };
    cfg.apps[0].stages[2].exec_ms = 300.0;
    let set = build(&cfg, Arc::new(EchoLogic));
    std::thread::sleep(Duration::from_millis(80));

    let prompt = Payload::Bytes(b"dropped then retried".to_vec());
    let opts = SubmitOptions::default().with_deadline(Duration::from_millis(100));
    let h = set
        .submit_with(AppId(1), prompt.clone(), opts)
        .expect("must admit");
    assert_eq!(
        h.wait(Duration::from_secs(10)),
        WaitOutcome::DeadlineExceeded,
        "the probe request must be dropped mid-pipeline"
    );
    assert_eq!(
        set.metrics().counter("cache_hits.__workflow__").get(),
        0,
        "a dropped request must not have seeded the workflow tier"
    );
    // Fresh identical submission: full pipeline run, correct bytes.
    let h2 = set.submit(AppId(1), prompt.clone()).expect("must admit");
    let WaitOutcome::Done(bytes) = h2.wait(Duration::from_secs(10)) else {
        panic!("retry of a dropped request must complete")
    };
    let msg = WorkflowMessage::decode(&bytes).unwrap();
    assert_eq!(msg.payload, prompt);
    set.shutdown();
}

/// The config salt participates in key derivation: bumping it (model /
/// config rollout) invalidates everything cached under the old salt.
#[test]
fn salt_change_invalidates_cached_entries() {
    let (old, _) = mk_cache(&CacheSettings {
        salt: "model-v1".into(),
        ..CacheSettings::default()
    });
    let (new, _) = mk_cache(&CacheSettings {
        salt: "model-v2".into(),
        ..CacheSettings::default()
    });
    let prompt = Payload::Bytes(b"same prompt".to_vec());
    let k_old = old.key_for(AppId(1), WORKFLOW_STAGE, &prompt);
    let k_new = new.key_for(AppId(1), WORKFLOW_STAGE, &prompt);
    assert_ne!(k_old, k_new, "salt must change the derived key");

    let value: Arc<[u8]> = b"v1 output".to_vec().into();
    assert!(old.fill(k_old, &value));
    // The new deployment derives k_new for the same prompt — the v1
    // entry is unreachable from it.
    assert!(new.lookup("s", k_new).is_none());
    // And stage / app also separate key spaces.
    assert_ne!(
        old.key_for(AppId(1), "vae", &prompt),
        old.key_for(AppId(1), "diffusion", &prompt)
    );
    assert_ne!(
        old.key_for(AppId(1), WORKFLOW_STAGE, &prompt),
        old.key_for(AppId(2), WORKFLOW_STAGE, &prompt)
    );
}
