//! Fixture tests for the in-crate lint pass (`onepiece lint`) and the
//! runtime lock-order witness.
//!
//! Each rule gets a positive hit, plus the suppression paths it must
//! honor (`// lint: allow(...)` and the checked-in baseline). The last
//! test is the self-check the CI lint job relies on: the shipped tree
//! must be clean under its shipped baseline.

use onepiece::lint::{baseline, lint_sources, lint_tree, load_baseline};
use std::collections::HashSet;
use std::path::Path;

fn src(path: &str, body: &str) -> Vec<(String, String)> {
    vec![(path.to_string(), body.to_string())]
}

fn lint_one(path: &str, body: &str) -> onepiece::lint::LintOutcome {
    lint_sources(&src(path, body), &HashSet::new())
}

// ---------------------------------------------------------------- L1

#[test]
fn l1_flags_unwrap_in_data_plane() {
    let out = lint_one(
        "ringbuf/fake.rs",
        "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
    );
    assert_eq!(out.violations.len(), 1, "{}", out.summary());
    assert_eq!(out.violations[0].rule, "l1");
    assert_eq!(out.violations[0].line, 2);
}

#[test]
fn l1_flags_panic_and_expect() {
    let out = lint_one(
        "rdma/fake.rs",
        "fn f(x: Option<u32>) {\n    let _ = x.expect(\"gone\");\n    panic!(\"boom\");\n}\n",
    );
    let rules: Vec<&str> = out.violations.iter().map(|v| v.rule).collect();
    assert_eq!(rules, ["l1", "l1"], "{}", out.summary());
}

#[test]
fn l1_poison_propagation_is_exempt() {
    let out = lint_one(
        "workflow/fake.rs",
        "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n",
    );
    assert!(out.violations.is_empty(), "{}", out.summary());
}

#[test]
fn l1_test_modules_are_exempt() {
    let out = lint_one(
        "db/fake.rs",
        "#[cfg(test)]\nmod tests {\n    fn g() {\n        None::<u32>.unwrap();\n    }\n}\n",
    );
    assert!(out.violations.is_empty(), "{}", out.summary());
}

#[test]
fn l1_ignores_non_data_plane_modules() {
    let out = lint_one(
        "util/fake.rs",
        "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
    );
    assert!(out.violations.is_empty(), "{}", out.summary());
}

#[test]
fn l1_allow_comment_suppresses() {
    let out = lint_one(
        "cache/fake.rs",
        "fn f(x: Option<u32>) -> u32 {\n    x.unwrap() // lint: allow(l1)\n}\n",
    );
    assert!(out.violations.is_empty(), "{}", out.summary());
    assert_eq!(out.suppressed, 1);
}

#[test]
fn l1_allow_on_preceding_comment_line_attaches_to_next_line() {
    let out = lint_one(
        "cache/fake.rs",
        "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(l1)\n    x.unwrap()\n}\n",
    );
    assert!(out.violations.is_empty(), "{}", out.summary());
    assert_eq!(out.suppressed, 1);
}

// ---------------------------------------------------------------- L2

const L2_BAD: &str = "use std::sync::{Condvar, Mutex};\n\
struct S {\n    m: Mutex<u32>,\n    cv: Condvar,\n}\n\
impl S {\n    fn f(&self) {\n        let g = self.m.lock().unwrap();\n        let _g = self.cv.wait(g).unwrap();\n    }\n}\n";

#[test]
fn l2_flags_unbounded_condvar_wait() {
    let out = lint_one("workflow/fake.rs", L2_BAD);
    assert_eq!(out.violations.len(), 1, "{}", out.summary());
    assert_eq!(out.violations[0].rule, "l2");
}

#[test]
fn l2_wait_timeout_is_clean() {
    let body = L2_BAD.replace(".wait(g)", ".wait_timeout(g, d)");
    let out = lint_one("workflow/fake.rs", &body);
    assert!(out.violations.is_empty(), "{}", out.summary());
}

#[test]
fn l2_applies_outside_data_plane_too() {
    let out = lint_one("nm/fake.rs", L2_BAD);
    assert_eq!(out.violations.len(), 1, "{}", out.summary());
}

// ---------------------------------------------------------------- L3

const L3_INVERTED: &str = "struct S {\n\
    a: Mutex<u32>, // lint: lock-rank(outer, 50)\n\
    b: Mutex<u32>, // lint: lock-rank(inner, 40)\n\
}\n\
impl S {\n    fn f(&self) {\n        let g1 = self.a.lock().unwrap();\n        let g2 = self.b.lock().unwrap();\n        drop(g2);\n        drop(g1);\n    }\n}\n";

#[test]
fn l3_flags_rank_inversion() {
    let out = lint_one("workflow/fake.rs", L3_INVERTED);
    assert_eq!(out.violations.len(), 1, "{}", out.summary());
    assert_eq!(out.violations[0].rule, "l3");
    assert!(out.violations[0].message.contains("strictly ascend"));
}

#[test]
fn l3_ascending_order_is_clean() {
    // Same function, acquisition order matching the ranks.
    let body = L3_INVERTED
        .replace("lock-rank(outer, 50)", "lock-rank(outer, 40)")
        .replace("lock-rank(inner, 40)", "lock-rank(inner, 50)");
    let out = lint_one("workflow/fake.rs", &body);
    assert!(out.violations.is_empty(), "{}", out.summary());
}

#[test]
fn l3_early_drop_releases_the_guard() {
    // outer is dropped before inner is taken: no nesting, no inversion.
    let body = "struct S {\n\
    a: Mutex<u32>, // lint: lock-rank(outer, 50)\n\
    b: Mutex<u32>, // lint: lock-rank(inner, 40)\n\
}\n\
impl S {\n    fn f(&self) {\n        let g1 = self.a.lock().unwrap();\n        drop(g1);\n        let g2 = self.b.lock().unwrap();\n        drop(g2);\n    }\n}\n";
    let out = lint_one("workflow/fake.rs", body);
    assert!(out.violations.is_empty(), "{}", out.summary());
}

// ---------------------------------------------------------------- L4

#[test]
fn l4_flags_unaccounted_verb_call_site() {
    let out = lint_one(
        "transport/fake.rs",
        "impl X {\n    fn send(&self) {\n        let _ = self.qp.post_write_words(0, &[1]);\n    }\n}\n",
    );
    assert_eq!(out.violations.len(), 1, "{}", out.summary());
    assert_eq!(out.violations[0].rule, "l4");
}

#[test]
fn l4_accounted_call_site_is_clean() {
    let out = lint_one(
        "transport/fake.rs",
        "impl X {\n    fn send(&self, m: &mut M) {\n        let _ = self.qp.post_write_words(0, &[1]);\n        m.verbs += 1;\n    }\n}\n",
    );
    assert!(out.violations.is_empty(), "{}", out.summary());
}

// ---------------------------------------------------------------- L5

#[test]
fn l5_flags_wall_clock_in_key_derivation() {
    let out = lint_one(
        "cache/key.rs",
        "fn salt() -> u64 {\n    let _t = std::time::Instant::now();\n    0\n}\n",
    );
    assert_eq!(out.violations.len(), 1, "{}", out.summary());
    assert_eq!(out.violations[0].rule, "l5");
}

#[test]
fn l5_other_cache_files_may_read_clocks() {
    let out = lint_one(
        "cache/tier_fake.rs",
        "fn age() -> std::time::Instant {\n    std::time::Instant::now()\n}\n",
    );
    assert!(out.violations.is_empty(), "{}", out.summary());
}

// ---------------------------------------------------------- baseline

#[test]
fn baseline_filters_by_fingerprint_not_line_number() {
    let body = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    let first = lint_one("ringbuf/fake.rs", body);
    assert_eq!(first.violations.len(), 1);
    let accepted = baseline::render(&first.violations);
    let set = baseline::parse(&accepted).unwrap();

    // Same violation, shifted two lines down: still baselined.
    let shifted = format!("// pad\n// pad\n{body}");
    let out = lint_sources(&src("ringbuf/fake.rs", &shifted), &set);
    assert!(out.violations.is_empty(), "{}", out.summary());
    assert_eq!(out.baselined, 1);

    // A *different* violation in the same file is not swallowed.
    let other = "fn g(y: Option<u64>) -> u64 {\n    y.unwrap()\n}\n";
    let out = lint_sources(&src("ringbuf/fake.rs", other), &set);
    assert_eq!(out.violations.len(), 1, "{}", out.summary());
}

#[test]
fn baseline_accepts_empty_entries_file() {
    let set = baseline::parse("{\"entries\":[]}").unwrap();
    assert!(set.is_empty());
}

// ----------------------------------------------------- self-check

/// The contract the CI lint job greps for: the shipped tree is clean
/// under the shipped (empty) baseline.
#[test]
fn shipped_tree_is_clean() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let set = load_baseline(&manifest.join("LINT_BASELINE.json")).unwrap();
    let out = lint_tree(&manifest.join("rust/src"), &set).unwrap();
    assert!(
        out.violations.is_empty(),
        "shipped tree must lint clean: {}\n{}",
        out.summary(),
        out.violations
            .iter()
            .map(|v| format!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

// ------------------------------------------------- runtime witness

/// The witness hooks are compiled under `debug_assertions` (always on
/// for `cargo test`) or the `lockwitness` feature.
#[cfg(any(debug_assertions, feature = "lockwitness"))]
mod witness {
    use onepiece::lint::runtime::WitnessMutex;
    use std::sync::{Arc, Barrier};

    fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
        payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default()
    }

    #[test]
    fn rank_inversion_panics_with_held_stack() {
        let a = Arc::new(WitnessMutex::new("wit_outer", 50, 0u32));
        let b = Arc::new(WitnessMutex::new("wit_inner", 40, 0u32));
        let h = std::thread::spawn(move || {
            let _ga = a.lock().unwrap();
            let _gb = b.lock().unwrap(); // rank 40 under rank 50: panics
        });
        let err = h.join().expect_err("inversion must panic");
        let msg = panic_message(err);
        assert!(msg.contains("ranks must strictly ascend"), "{msg}");
        assert!(msg.contains("wit_outer"), "{msg}");
    }

    #[test]
    fn abba_cycle_is_detected_and_reported() {
        // Unranked witnesses skip the rank check, so a real ABBA cycle
        // can form and must be caught by the wait-for-graph DFS. The
        // detecting thread panics; its guard drop unblocks the peer.
        let a = Arc::new(WitnessMutex::new_unranked("cyc_a", 0u32));
        let b = Arc::new(WitnessMutex::new_unranked("cyc_b", 0u32));
        let gate = Arc::new(Barrier::new(2));

        let (a1, b1, g1) = (a.clone(), b.clone(), gate.clone());
        let t1 = std::thread::spawn(move || {
            let _ga = a1.lock().unwrap();
            g1.wait();
            // Blocks until t2's witness panic releases `cyc_b` (the
            // lock arrives poisoned then — either result is fine).
            let _gb = b1.lock();
        });
        let t2 = std::thread::spawn(move || {
            let _gb = b.lock().unwrap();
            gate.wait();
            // Give t1 time to register its wait-for edge on `cyc_b`.
            std::thread::sleep(std::time::Duration::from_millis(100));
            let _ga = a.lock();
        });

        let results = [t1.join(), t2.join()];
        let errs: Vec<String> = results
            .into_iter()
            .filter_map(|r| r.err().map(panic_message))
            .collect();
        assert_eq!(errs.len(), 1, "exactly one thread detects the cycle: {errs:?}");
        assert!(errs[0].contains("deadlock cycle detected"), "{}", errs[0]);
        assert!(errs[0].contains("cyc_a") && errs[0].contains("cyc_b"), "{}", errs[0]);
    }
}
