//! Worker-instance fault-tolerance integration tests: with crash
//! injection killing instances mid-pipeline, every admitted request
//! reaches a terminal state — `Done` after checkpoint replay onto a
//! promoted replacement, or a `Failed` tombstone once the submit
//! `RetryPolicy`'s recovery budget is exhausted — and none hang.
//!
//! Detector edge cases (flapping heartbeats, donor-stage promotion) are
//! unit-tested in `nm::manager`; first-writer-wins publication is
//! unit-tested in `db::store`. These tests drive the full wset loop:
//! housekeeper detection → NM repair → checkpoint replay → client
//! handle.

use onepiece::client::{
    Gateway, RequestStatus, RetryPolicy, SubmitOptions, WaitOutcome,
};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind};
use onepiece::nm::StageKey;
use onepiece::transport::{AppId, Payload};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

/// A pipeline with the failure detector on (150 ms heartbeat silence,
/// housekeeper sweeping every ~50 ms) and a slow diffusion stage so
/// requests are reliably in flight there when tests crash it.
fn fault_config(stage_ms: [f64; 4]) -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for (s, &m) in cfg.apps[0].stages.iter_mut().zip(&stage_ms) {
        s.exec = ExecModel::Simulated { ms: m };
        s.exec_ms = m;
    }
    cfg.nm.heartbeat_ms = 10;
    cfg.nm.instance_timeout_ms = 150;
    cfg.idle_pool = 1;
    cfg
}

fn build(cfg: &ClusterConfig) -> WorkflowSet {
    let pool = build_pool(cfg, None);
    WorkflowSet::build(cfg.clone(), vec![vec![1, 1, 1, 1]], Arc::new(EchoLogic), pool)
}

fn diffusion() -> StageKey {
    StageKey { app: AppId(1), stage: 2 }
}

#[test]
fn killed_mid_pipeline_instance_every_request_terminates() {
    let cfg = fault_config([1.0, 1.0, 60.0, 1.0]);
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    // Recovery budget: 3 total attempts = original + 2 replays.
    let opts = SubmitOptions::default()
        .with_retry(RetryPolicy::attempts(3, Duration::ZERO));
    let handles: Vec<_> = (0..6)
        .map(|i| {
            set.submit_with(AppId(1), Payload::Bytes(vec![i as u8; 16]), opts)
                .expect("must admit")
        })
        .collect();
    // Let the stream reach diffusion (60 ms/req on one instance: a
    // backlog forms there), then kill that instance.
    std::thread::sleep(Duration::from_millis(40));
    let victim = set
        .inject_crash_at_stage(diffusion())
        .expect("diffusion must have an instance to kill");

    let mut done = 0;
    let mut failed = 0;
    for h in &handles {
        match h.wait(Duration::from_secs(15)) {
            WaitOutcome::Done(_) => done += 1,
            WaitOutcome::Failed => failed += 1,
            other => panic!(
                "request {:?} must reach a terminal state, got {other:?} \
                 (victim was {victim:?})",
                h.uid()
            ),
        }
    }
    assert_eq!(done + failed, 6, "no request may hang");
    assert!(done >= 1, "replay onto the promoted replacement must complete work");

    let m = set.metrics();
    assert!(m.counter("instances_failed").get() >= 1, "detector must fire");
    assert!(
        m.counter("instances_replaced").get() >= 1,
        "idle-pool promotion must repair the stage"
    );
    assert!(
        m.counter("requests_recovered").get() >= 1,
        "stranded requests must be replayed"
    );
    assert!(
        m.histogram("recovery_latency_ns").snapshot().count >= 1,
        "recovery latency must be recorded"
    );
    set.shutdown();
}

#[test]
fn crash_racing_completion_publishes_exactly_one_terminal_entry() {
    // The request *completes* (result stored) just before its final-
    // stage instance dies. The recovery sweep must notice the terminal
    // entry and not replay — the client sees exactly one outcome.
    let mut cfg = fault_config([1.0, 1.0, 1.0, 1.0]);
    cfg.db.replicas = 1; // single replica: any duplicate would be visible
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let opts = SubmitOptions::default()
        .with_retry(RetryPolicy::attempts(3, Duration::ZERO));
    let handle = set
        .submit_with(AppId(1), Payload::Bytes(vec![9; 16]), opts)
        .expect("must admit");
    // Wait for the result to land in the DB *without* consuming it.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while set.dbs[0].peek(handle.uid()).is_none() {
        assert!(std::time::Instant::now() < deadline, "pipeline must complete");
        std::thread::sleep(Duration::from_millis(5));
    }
    // Now the final-stage instance "dies" with the tracker still
    // holding the request's location there.
    set.inject_crash_at_stage(StageKey { app: AppId(1), stage: 3 })
        .expect("final stage instance");
    // Let detection + the recovery sweep run.
    std::thread::sleep(Duration::from_millis(400));
    let m = set.metrics();
    assert!(m.counter("instances_failed").get() >= 1, "detector must fire");
    assert_eq!(
        m.counter("requests_recovered").get(),
        0,
        "a completed request must not be replayed"
    );
    assert_eq!(m.counter("requests_failed").get(), 0);
    // The one terminal entry is the result.
    let WaitOutcome::Done(bytes) = handle.wait(Duration::from_secs(5)) else {
        panic!("completed request must read back Done")
    };
    assert!(!bytes.is_empty());
    assert!(
        set.db_client.fetch_entry(handle.uid()).is_none(),
        "exactly one terminal entry: nothing left after the handle consumed it"
    );
    set.shutdown();
}

#[test]
fn final_stage_crash_replays_from_last_checkpoint_and_completes() {
    // The dead instance is the request's *final* stage: the replay must
    // re-enter at stage 3 (from the stage-3 checkpoint written by the
    // stage-2 deliver), not restart the pipeline.
    let cfg = fault_config([1.0, 1.0, 1.0, 200.0]);
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let opts = SubmitOptions::default()
        .with_retry(RetryPolicy::attempts(3, Duration::ZERO));
    let handle = set
        .submit_with(AppId(1), Payload::Bytes(vec![5; 16]), opts)
        .expect("must admit");
    // Let it reach the (slow) final stage, then kill it.
    std::thread::sleep(Duration::from_millis(50));
    set.inject_crash_at_stage(StageKey { app: AppId(1), stage: 3 })
        .expect("final stage instance");

    let WaitOutcome::Done(bytes) = handle.wait(Duration::from_secs(15)) else {
        panic!("replayed final stage must still produce the result")
    };
    let msg = onepiece::transport::WorkflowMessage::decode(&bytes).unwrap();
    assert_eq!(msg.payload, Payload::Bytes(vec![5; 16]));
    let m = set.metrics();
    assert!(m.counter("requests_recovered").get() >= 1, "final stage replayed");
    assert!(m.counter("instances_replaced").get() >= 1);
    set.shutdown();
}

#[test]
fn exhausted_retry_budget_publishes_failed_tombstone() {
    // Default RetryPolicy (1 attempt) = no recovery budget: a crash
    // fails the request fast — terminal `Failed`, not a hang — even
    // though the stage itself is repaired for future traffic.
    let cfg = fault_config([1.0, 1.0, 300.0, 1.0]);
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let handle = set
        .submit(AppId(1), Payload::Bytes(vec![3; 16]))
        .expect("must admit");
    std::thread::sleep(Duration::from_millis(40)); // in flight at diffusion
    set.inject_crash_at_stage(diffusion()).expect("diffusion instance");

    assert_eq!(handle.wait(Duration::from_secs(10)), WaitOutcome::Failed);
    assert_eq!(handle.status(), RequestStatus::Failed, "Failed is sticky");
    let m = set.metrics();
    assert_eq!(m.counter("requests_recovered").get(), 0, "no budget, no replay");
    assert!(m.counter("requests_failed").get() >= 1);
    assert!(
        m.counter("instances_replaced").get() >= 1,
        "the stage is still repaired for future traffic"
    );
    set.shutdown();
}

#[test]
fn rendezvous_plane_crash_terminates_requests_and_reclaims_regions() {
    // Same crash drill as `killed_mid_pipeline_instance_every_request_
    // terminates`, but with the rendezvous cutover forced low so every
    // inter-stage delivery travels as a staged slab + descriptor frame.
    // A descriptor stranded in the dead ring (or pointing at the dead
    // producer's deregistered slab) must never surface as a corrupt
    // result: checkpoint replay wins, and once the set drains, every
    // staged region is reclaimed — `payload_regions_live` back to 0.
    let mut cfg = fault_config([1.0, 1.0, 60.0, 1.0]);
    cfg.rdma.rendezvous_threshold_bytes = 256;
    let set = build(&cfg);
    let metrics = set.metrics().clone();
    std::thread::sleep(Duration::from_millis(80));

    let opts = SubmitOptions::default()
        .with_retry(RetryPolicy::attempts(3, Duration::ZERO));
    let payload = vec![0xAB; 8 << 10]; // 8 KB: far above the cutover
    let handles: Vec<_> = (0..6)
        .map(|_| {
            set.submit_with(AppId(1), Payload::Bytes(payload.clone()), opts)
                .expect("must admit")
        })
        .collect();
    std::thread::sleep(Duration::from_millis(40));
    set.inject_crash_at_stage(diffusion())
        .expect("diffusion must have an instance to kill");

    let mut done = 0;
    let mut failed = 0;
    for h in &handles {
        match h.wait(Duration::from_secs(15)) {
            WaitOutcome::Done(bytes) => {
                // A delivered result must carry the original payload —
                // a stale-generation or torn pull may strand a request,
                // never corrupt one.
                let msg = onepiece::transport::WorkflowMessage::decode(&bytes).unwrap();
                assert_eq!(msg.payload, Payload::Bytes(payload.clone()));
                done += 1;
            }
            WaitOutcome::Failed => failed += 1,
            other => panic!("request must reach a terminal state, got {other:?}"),
        }
    }
    assert_eq!(done + failed, 6, "no request may hang");
    assert!(done >= 1, "replay must complete work over the rendezvous plane");
    assert!(
        metrics.counter("rendezvous_reads_total").get() >= 1,
        "deliveries above the cutover must use the descriptor plane"
    );
    assert!(metrics.counter("instances_failed").get() >= 1);
    assert!(metrics.counter("requests_recovered").get() >= 1);

    set.shutdown();
    // Shutdown joins every instance (crashed ones included): all sender
    // stagers drop, deregistering their slabs. Anything else is a leak.
    assert_eq!(
        metrics.gauge("payload_regions_live").get(),
        0,
        "staged payload regions must all be reclaimed after shutdown"
    );
}

#[test]
fn chaos_config_block_drives_housekeeper_kills() {
    // chaos.kill_every_ms turns the housekeeper into the crash
    // injector: instances die on a timer and the same sweep repairs
    // them — admitted traffic keeps reaching terminal states.
    let mut cfg = fault_config([1.0, 1.0, 5.0, 1.0]);
    cfg.chaos.kill_every_ms = 200;
    cfg.chaos.seed = 11;
    cfg.idle_pool = 2;
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let opts = SubmitOptions::default()
        .with_retry(RetryPolicy::attempts(4, Duration::ZERO));
    let mut outcomes = (0usize, 0usize); // (done, failed)
    for i in 0..20 {
        if let Ok(h) = set.submit_with(AppId(1), Payload::Bytes(vec![i as u8; 8]), opts)
        {
            match h.wait(Duration::from_secs(15)) {
                WaitOutcome::Done(_) => outcomes.0 += 1,
                WaitOutcome::Failed => outcomes.1 += 1,
                other => panic!("request {i} must terminate, got {other:?}"),
            }
        }
        std::thread::sleep(Duration::from_millis(30));
    }
    assert!(outcomes.0 >= 1, "work must keep completing under chaos");
    assert!(
        set.metrics().counter("chaos_kills").get() >= 1,
        "the chaos driver must have killed at least one instance"
    );
    set.shutdown();
}
