//! Request-lifecycle integration tests for the unified client gateway:
//! cancellation and deadline expiry reach their typed terminal states
//! **without leaking in-flight stage work** — dropped requests publish a
//! tombstone instead of a result, tracker entries are released, and a
//! late cancel against a completed request is a no-op.

use onepiece::client::{Gateway, RequestStatus, SubmitOptions, WaitOutcome};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind};
use onepiece::transport::{AppId, Payload};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

/// A pipeline whose diffusion stage is slow enough (300 ms) that a
/// request is reliably *in flight* there when tests cancel it or let its
/// deadline lapse.
fn slow_diffusion_config() -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    let ms = [1.0, 1.0, 300.0, 1.0];
    for (s, &m) in cfg.apps[0].stages.iter_mut().zip(&ms) {
        s.exec = ExecModel::Simulated { ms: m };
        s.exec_ms = m;
    }
    cfg.idle_pool = 0;
    // Short TTL so the housekeeper's tracker sweep (which releases the
    // entries of dropped requests — the data plane keeps them so late
    // copies still drop) runs inside the tests' wait windows.
    cfg.db.ttl_ms = 1_000;
    cfg
}

fn build(cfg: &ClusterConfig) -> WorkflowSet {
    let pool = build_pool(cfg, None);
    WorkflowSet::build(cfg.clone(), vec![vec![1, 1, 1, 1]], Arc::new(EchoLogic), pool)
}

fn total_sla_dropped(set: &WorkflowSet) -> u64 {
    set.instance_stats().iter().map(|(_, s, _)| s.sla_dropped).sum()
}

#[test]
fn deadline_expiry_mid_pipeline_produces_tombstone() {
    let set = build(&slow_diffusion_config());
    std::thread::sleep(Duration::from_millis(80));

    // 100 ms deadline against a 300 ms diffusion stage: the deadline
    // lapses while the request is queued at / executing in diffusion.
    let opts = SubmitOptions::default().with_deadline(Duration::from_millis(100));
    let handle = set
        .submit_with(AppId(1), Payload::Bytes(vec![1; 16]), opts)
        .expect("must admit");

    assert_eq!(
        handle.wait(Duration::from_secs(5)),
        WaitOutcome::DeadlineExceeded,
        "deadline must surface as the typed terminal state"
    );
    assert_eq!(handle.status(), RequestStatus::DeadlineExceeded);

    // No in-flight work leaks: the data plane dropped the request (a
    // tombstone, not a result, reached the DB) and released its tracker
    // entry.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while (total_sla_dropped(&set) == 0 || !set.tracker().is_empty())
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(total_sla_dropped(&set) >= 1, "stage work must be dropped");
    assert!(set.tracker().is_empty(), "tracker entry must be released");
    assert!(
        set.db_client.fetch(handle.uid()).is_none(),
        "no result may be published past the deadline"
    );
    assert_eq!(set.metrics().counter("deadline_missed").get(), 1);
    set.shutdown();
}

#[test]
fn cancellation_mid_pipeline_drops_in_flight_work() {
    let set = build(&slow_diffusion_config());
    std::thread::sleep(Duration::from_millis(80));

    let handle = set
        .submit(AppId(1), Payload::Bytes(vec![2; 16]))
        .expect("must admit");
    // Let the request reach the diffusion stage, then cancel mid-flight.
    std::thread::sleep(Duration::from_millis(60));
    assert!(handle.cancel(), "cancel must take effect on an in-flight request");
    assert_eq!(handle.status(), RequestStatus::Cancelled);
    assert_eq!(handle.wait(Duration::from_secs(5)), WaitOutcome::Cancelled);
    assert!(!handle.cancel(), "second cancel is a no-op");

    // The diffusion worker finishes its (wasted) execution and must then
    // drop the output instead of delivering it downstream.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while (total_sla_dropped(&set) == 0 || !set.tracker().is_empty())
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(total_sla_dropped(&set) >= 1, "in-flight stage work must be dropped");
    assert!(set.tracker().is_empty(), "tracker entry must be released");
    assert!(
        set.db_client.fetch(handle.uid()).is_none(),
        "a cancelled request must never publish a result"
    );
    assert_eq!(set.metrics().counter("requests_cancelled").get(), 1);
    set.shutdown();
}

#[test]
fn cancel_after_completion_is_a_noop() {
    let mut cfg = slow_diffusion_config();
    // Fast pipeline for this one: completion wins the race by design.
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 1.0 };
        s.exec_ms = 1.0;
    }
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let handle = set
        .submit(AppId(1), Payload::Bytes(vec![3; 16]))
        .expect("must admit");
    let WaitOutcome::Done(bytes) = handle.wait(Duration::from_secs(10)) else {
        panic!("fast pipeline must complete")
    };
    assert!(!bytes.is_empty());
    assert!(!handle.cancel(), "cancel after Done must not take effect");
    assert_eq!(handle.status(), RequestStatus::Done, "Done is sticky");
    assert_eq!(set.metrics().counter("requests_cancelled").get(), 0);
    set.shutdown();
}

#[test]
fn deadline_met_completes_normally() {
    let mut cfg = slow_diffusion_config();
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 1.0 };
        s.exec_ms = 1.0;
    }
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let opts = SubmitOptions::interactive().with_deadline(Duration::from_secs(10));
    let handle = set
        .submit_with(AppId(1), Payload::Bytes(vec![4; 16]), opts)
        .expect("must admit");
    assert!(matches!(handle.wait(Duration::from_secs(10)), WaitOutcome::Done(_)));
    assert_eq!(set.metrics().counter("deadline_missed").get(), 0);
    assert_eq!(set.metrics().counter("accepted.interactive").get(), 1);
    set.shutdown();
}
