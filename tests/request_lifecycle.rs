//! Request-lifecycle integration tests for the unified client gateway:
//! cancellation and deadline expiry reach their typed terminal states
//! **without leaking in-flight stage work** — dropped requests publish a
//! tombstone instead of a result, tracker entries are released, and a
//! late cancel against a completed request is a no-op.

use onepiece::client::{Gateway, RequestStatus, SubmitOptions, WaitOutcome};
use onepiece::config::{BatchSettings, ClusterConfig, ExecModel, FabricKind, SchedMode};
use onepiece::transport::{AppId, Payload};
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

/// A pipeline whose diffusion stage is slow enough (300 ms) that a
/// request is reliably *in flight* there when tests cancel it or let its
/// deadline lapse.
fn slow_diffusion_config() -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    let ms = [1.0, 1.0, 300.0, 1.0];
    for (s, &m) in cfg.apps[0].stages.iter_mut().zip(&ms) {
        s.exec = ExecModel::Simulated { ms: m };
        s.exec_ms = m;
    }
    cfg.idle_pool = 0;
    // Short TTL so the housekeeper's tracker sweep (which releases the
    // entries of dropped requests — the data plane keeps them so late
    // copies still drop) runs inside the tests' wait windows.
    cfg.db.ttl_ms = 1_000;
    cfg
}

fn build(cfg: &ClusterConfig) -> WorkflowSet {
    let pool = build_pool(cfg, None);
    WorkflowSet::build(cfg.clone(), vec![vec![1, 1, 1, 1]], Arc::new(EchoLogic), pool)
}

fn total_sla_dropped(set: &WorkflowSet) -> u64 {
    set.instance_stats().iter().map(|(_, s, _)| s.sla_dropped).sum()
}

#[test]
fn deadline_expiry_mid_pipeline_produces_tombstone() {
    let set = build(&slow_diffusion_config());
    std::thread::sleep(Duration::from_millis(80));

    // 100 ms deadline against a 300 ms diffusion stage: the deadline
    // lapses while the request is queued at / executing in diffusion.
    let opts = SubmitOptions::default().with_deadline(Duration::from_millis(100));
    let handle = set
        .submit_with(AppId(1), Payload::Bytes(vec![1; 16]), opts)
        .expect("must admit");

    assert_eq!(
        handle.wait(Duration::from_secs(5)),
        WaitOutcome::DeadlineExceeded,
        "deadline must surface as the typed terminal state"
    );
    assert_eq!(handle.status(), RequestStatus::DeadlineExceeded);

    // No in-flight work leaks: the data plane dropped the request (a
    // tombstone, not a result, reached the DB) and released its tracker
    // entry.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while (total_sla_dropped(&set) == 0 || !set.tracker().is_empty())
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(total_sla_dropped(&set) >= 1, "stage work must be dropped");
    assert!(set.tracker().is_empty(), "tracker entry must be released");
    assert!(
        set.db_client.fetch(handle.uid()).is_none(),
        "no result may be published past the deadline"
    );
    assert_eq!(set.metrics().counter("deadline_missed").get(), 1);
    set.shutdown();
}

#[test]
fn cancellation_mid_pipeline_drops_in_flight_work() {
    let set = build(&slow_diffusion_config());
    std::thread::sleep(Duration::from_millis(80));

    let handle = set
        .submit(AppId(1), Payload::Bytes(vec![2; 16]))
        .expect("must admit");
    // Let the request reach the diffusion stage, then cancel mid-flight.
    std::thread::sleep(Duration::from_millis(60));
    assert!(handle.cancel(), "cancel must take effect on an in-flight request");
    assert_eq!(handle.status(), RequestStatus::Cancelled);
    assert_eq!(handle.wait(Duration::from_secs(5)), WaitOutcome::Cancelled);
    assert!(!handle.cancel(), "second cancel is a no-op");

    // The diffusion worker finishes its (wasted) execution and must then
    // drop the output instead of delivering it downstream.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while (total_sla_dropped(&set) == 0 || !set.tracker().is_empty())
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(total_sla_dropped(&set) >= 1, "in-flight stage work must be dropped");
    assert!(set.tracker().is_empty(), "tracker entry must be released");
    assert!(
        set.db_client.fetch(handle.uid()).is_none(),
        "a cancelled request must never publish a result"
    );
    assert_eq!(set.metrics().counter("requests_cancelled").get(), 1);
    set.shutdown();
}

/// Batch-vs-lifecycle interaction: three Batch-class requests coalesce
/// into one micro-batch; mid-flight, one member is cancelled and another
/// hits its deadline. The surviving member must complete, each dropped
/// member must publish its own terminal tombstone exactly once, and a
/// recovery sweep over the (crashed) serving instance must not resubmit
/// any of them — they are all terminal.
#[test]
fn batch_member_cancel_and_deadline_do_not_poison_the_batch() {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    let ms = [1.0, 1.0, 200.0, 1.0];
    for (s, &m) in cfg.apps[0].stages.iter_mut().zip(&ms) {
        s.exec = ExecModel::Simulated { ms: m };
        s.exec_ms = m;
    }
    // Diffusion runs IM so it can batch; a generous window (100 ms) lets
    // the three submissions coalesce at every stage.
    cfg.apps[0].stages[2].mode = SchedMode::Individual;
    cfg.batch = Some(BatchSettings {
        max_batch: 4,
        max_wait_us: 100_000,
        adaptive: false,
        interactive_bypass: true,
        max_starvation_ms: 0,
    });
    // Failure detector + checkpoints on, so the recovery-sweep half of
    // the scenario is live (sweep every ~100 ms, evict after 400 ms).
    cfg.nm.heartbeat_ms = 20;
    cfg.nm.instance_timeout_ms = 400;
    cfg.idle_pool = 0;
    cfg.db.ttl_ms = 60_000;
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    // Three Batch-class members submitted back-to-back: they ride the
    // same micro-batch through the 200 ms diffusion stage. B carries a
    // deadline that lapses while that batch is in flight.
    let a = set
        .submit_with(AppId(1), Payload::Bytes(vec![1; 16]), SubmitOptions::batch())
        .expect("must admit");
    let b = set
        .submit_with(
            AppId(1),
            Payload::Bytes(vec![2; 16]),
            SubmitOptions::batch().with_deadline(Duration::from_millis(450)),
        )
        .expect("must admit");
    let c = set
        .submit_with(AppId(1), Payload::Bytes(vec![3; 16]), SubmitOptions::batch())
        .expect("must admit");

    // Cancel A once the batch is past the entrance stages.
    std::thread::sleep(Duration::from_millis(250));
    assert!(a.cancel(), "cancel must land on the in-flight member");

    // The surviving member completes despite its batchmates dying.
    assert!(
        matches!(c.wait(Duration::from_secs(10)), WaitOutcome::Done(_)),
        "remaining member must complete"
    );
    assert_eq!(a.wait(Duration::from_secs(10)), WaitOutcome::Cancelled);
    assert_eq!(b.wait(Duration::from_secs(10)), WaitOutcome::DeadlineExceeded);
    assert_eq!(a.status(), RequestStatus::Cancelled);
    assert_eq!(b.status(), RequestStatus::DeadlineExceeded);
    assert_eq!(c.status(), RequestStatus::Done);

    let m = set.metrics();
    assert!(m.counter("batches_executed").get() >= 1, "a batch must have formed");
    assert_eq!(m.counter("requests_cancelled").get(), 1);
    assert_eq!(m.counter("deadline_missed").get(), 1);
    assert_eq!(m.counter("requests_failed").get(), 0, "nobody may escalate to Failed");
    // First-writer-wins held: each terminal entry was written once per
    // replica at most (re-publishes from late pipeline stages are
    // suppressed, not duplicated — `dup_suppressed` counts them).
    assert!(
        set.db_client.fetch(c.uid()).is_none(),
        "C's result was consumed by wait() and must not reappear"
    );

    // Recovery must not resubmit completed/terminal batch members: kill
    // the diffusion instance *after* the batch resolved; the sweep
    // evicts it but finds nothing recoverable at its ring.
    let recovered_before = m.counter("requests_recovered").get();
    let victim = set.inject_crash_at_stage(onepiece::nm::StageKey {
        app: AppId(1),
        stage: 2,
    });
    assert!(victim.is_some(), "diffusion instance must exist to crash");
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while m.counter("instances_failed").get() == 0
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(m.counter("instances_failed").get() >= 1, "detector must evict the crash");
    // A couple more sweeps, then: no replay may have fired.
    std::thread::sleep(Duration::from_millis(300));
    assert_eq!(
        m.counter("requests_recovered").get(),
        recovered_before,
        "recovery replay must not resubmit completed/terminal batch members"
    );
    assert_eq!(m.counter("requests_failed").get(), 0);
    set.shutdown();
}

#[test]
fn cancel_after_completion_is_a_noop() {
    let mut cfg = slow_diffusion_config();
    // Fast pipeline for this one: completion wins the race by design.
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 1.0 };
        s.exec_ms = 1.0;
    }
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let handle = set
        .submit(AppId(1), Payload::Bytes(vec![3; 16]))
        .expect("must admit");
    let WaitOutcome::Done(bytes) = handle.wait(Duration::from_secs(10)) else {
        panic!("fast pipeline must complete")
    };
    assert!(!bytes.is_empty());
    assert!(!handle.cancel(), "cancel after Done must not take effect");
    assert_eq!(handle.status(), RequestStatus::Done, "Done is sticky");
    assert_eq!(set.metrics().counter("requests_cancelled").get(), 0);
    set.shutdown();
}

#[test]
fn deadline_met_completes_normally() {
    let mut cfg = slow_diffusion_config();
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 1.0 };
        s.exec_ms = 1.0;
    }
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let opts = SubmitOptions::interactive().with_deadline(Duration::from_secs(10));
    let handle = set
        .submit_with(AppId(1), Payload::Bytes(vec![4; 16]), opts)
        .expect("must admit");
    assert!(matches!(handle.wait(Duration::from_secs(10)), WaitOutcome::Done(_)));
    assert_eq!(set.metrics().counter("deadline_missed").get(), 0);
    assert_eq!(set.metrics().counter("accepted.interactive").get(), 1);
    set.shutdown();
}
