//! Cross-module integration tests: full Workflow Set request lifecycle
//! through the unified `Gateway`/`RequestHandle` API, fault-tolerance
//! matrix rows from DESIGN.md §7 (message loss with no retransmission,
//! DB replica failure, NM failover), and multi-set behaviour.

use onepiece::client::{Gateway, WaitOutcome};
use onepiece::config::{ClusterConfig, ExecModel, FabricKind};
use onepiece::nm::StageKey;
use onepiece::rdma::{Fabric, FabricConfig};
use onepiece::transport::{AppId, Payload, WorkflowMessage};
use onepiece::util::NodeId;
use onepiece::workflow::EchoLogic;
use onepiece::wset::{build_pool, MultiSet, WorkflowSet};
use std::sync::Arc;
use std::time::Duration;

fn fast_config() -> ClusterConfig {
    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = FabricKind::Ideal;
    for s in cfg.apps[0].stages.iter_mut() {
        s.exec = ExecModel::Simulated { ms: 1.0 };
        s.exec_ms = 1.0;
    }
    cfg.idle_pool = 1;
    cfg
}

fn build(cfg: &ClusterConfig) -> WorkflowSet {
    let pool = build_pool(cfg, None);
    let counts = vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)];
    WorkflowSet::build(cfg.clone(), counts, Arc::new(EchoLogic), pool)
}

#[test]
fn request_lifecycle_uid_threading() {
    let cfg = fast_config();
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let handle = set
        .submit(AppId(1), Payload::Bytes(vec![42; 32]))
        .expect("must accept");
    let WaitOutcome::Done(bytes) = handle.wait(Duration::from_secs(10)) else {
        panic!("result expected")
    };
    let msg = WorkflowMessage::decode(&bytes).unwrap();
    // The UID assigned at the proxy survives the whole lifecycle (§3.2),
    // the stage advanced past the last stage index, the proxy origin and
    // timestamp are preserved.
    assert_eq!(msg.header.uid, handle.uid());
    assert_eq!(msg.header.stage.0, 4);
    assert_eq!(msg.header.origin, set.proxy.node());
    assert!(msg.header.ts_ns > 0);
    // The handle's observation purged one replica; the remaining replicas
    // still hold copies (they expire by TTL — §3.4). Drain them directly.
    for _ in 1..set.dbs.len() {
        let _ = set.db_client.fetch(handle.uid());
    }
    assert!(set.db_client.fetch(handle.uid()).is_none());
    set.shutdown();
}

#[test]
fn pipelined_batch_all_complete() {
    let cfg = fast_config();
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let mut handles = Vec::new();
    for i in 0..30u8 {
        if let Ok(h) = set.submit(AppId(1), Payload::Bytes(vec![i])) {
            handles.push((i, h));
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(handles.len() >= 25, "most requests admitted, got {}", handles.len());
    for (i, h) in &handles {
        let WaitOutcome::Done(bytes) = h.wait(Duration::from_secs(15)) else {
            panic!("request {i} must complete")
        };
        let msg = WorkflowMessage::decode(&bytes).unwrap();
        assert_eq!(msg.payload, Payload::Bytes(vec![*i]), "payload integrity");
    }
    set.shutdown();
}

#[test]
fn message_loss_is_not_retransmitted() {
    // §9: lost inter-stage messages are dropped, the request simply never
    // completes; the system itself keeps serving.
    let cfg = fast_config();
    let pool = build_pool(&cfg, None);
    let counts = vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)];
    let set = WorkflowSet::build(cfg, counts, Arc::new(EchoLogic), pool);
    std::thread::sleep(Duration::from_millis(80));

    // Inject 30% write loss into the fabric mid-run.
    set.fabric.set_config(FabricConfig {
        latency: None,
        write_drop_prob: 0.3,
        ..Default::default()
    });
    let mut handles = Vec::new();
    for i in 0..20u8 {
        if let Ok(h) = set.submit(AppId(1), Payload::Bytes(vec![i])) {
            handles.push(h);
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let completed = handles
        .iter()
        .filter(|h| matches!(h.wait(Duration::from_secs(3)), WaitOutcome::Done(_)))
        .count();
    // Some complete, some are lost; with 4 RDMA hops at 30% drop the
    // expected completion rate is (0.7)^4 ≈ 24% — allow a broad band but
    // require losses to occur (lost requests surface as TimedOut).
    assert!(completed < handles.len(), "losses must occur");

    // Heal the fabric: the system recovers with no residue.
    set.fabric.set_config(FabricConfig { latency: None, ..Default::default() });
    let handle = set
        .submit(AppId(1), Payload::Bytes(vec![99]))
        .expect("post-loss submission must admit");
    assert!(
        matches!(handle.wait(Duration::from_secs(10)), WaitOutcome::Done(_)),
        "post-loss requests must flow normally"
    );
    set.shutdown();
}

#[test]
fn db_replica_failure_served_by_backup() {
    let cfg = fast_config();
    let set = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));

    let handle = set.submit(AppId(1), Payload::Bytes(vec![7])).expect("admit");
    // Wait until the result is stored on all replicas (RD writes all).
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while set.dbs[1].peek(handle.uid()).is_none()
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    // Kill replica 0; the handle's read path falls through to replica 1.
    set.db_client.set_alive(0, false);
    assert!(
        matches!(handle.wait(Duration::from_secs(5)), WaitOutcome::Done(_)),
        "backup replica must serve the result"
    );
    set.shutdown();
}

#[test]
fn nm_primary_failover() {
    let cfg = fast_config();
    let set = build(&cfg);
    let primary = set.nm_cluster.primary().expect("initial primary");
    set.nm_cluster.set_alive(primary, false);
    // Heartbeats stop; another replica detects and re-elects.
    std::thread::sleep(Duration::from_millis(10));
    let status = set.nm_cluster.status();
    let backup = status.iter().find(|r| r.alive).unwrap().node;
    let new_primary = set.nm_cluster.elect(backup).expect("failover election");
    assert_ne!(new_primary, primary);
    assert_eq!(set.nm_cluster.primary(), Some(new_primary));
    set.shutdown();
}

#[test]
fn multiset_isolates_set_failure() {
    // A set whose entrance stage is unassigned (simulating regional
    // failure) rejects; the multi-set gateway places everything on the
    // healthy set.
    let cfg = fast_config();
    let dead = {
        let pool = build_pool(&cfg, None);
        WorkflowSet::build(cfg.clone(), vec![vec![0, 0, 0, 0]], Arc::new(EchoLogic), pool)
    };
    let healthy = build(&cfg);
    std::thread::sleep(Duration::from_millis(80));
    let multi = MultiSet::new(vec![dead, healthy], 3);

    let mut handles = Vec::new();
    for i in 0..10u8 {
        let handle = multi
            .submit(AppId(1), Payload::Bytes(vec![i]))
            .expect("healthy set must absorb");
        assert_eq!(handle.set(), 1);
        handles.push(handle);
    }
    for handle in handles {
        assert!(matches!(
            handle.wait(Duration::from_secs(10)),
            WaitOutcome::Done(_)
        ));
    }
}

#[test]
fn idle_pool_instance_absorbs_hot_stage() {
    // End-to-end §8.2: saturate diffusion, rebalance, observe the idle
    // instance join and process traffic.
    let mut cfg = fast_config();
    cfg.apps[0].stages[2].exec = ExecModel::Simulated { ms: 20.0 };
    cfg.apps[0].stages[2].exec_ms = 20.0;
    cfg.nm.util_window_ms = 200;
    let pool = build_pool(&cfg, None);
    // Deliberately under-provision diffusion.
    let set = WorkflowSet::build(cfg, vec![vec![1, 1, 1, 1]], Arc::new(EchoLogic), pool);
    std::thread::sleep(Duration::from_millis(80));
    let diffusion = StageKey { app: AppId(1), stage: 2 };
    assert_eq!(set.nm.stage_instances(diffusion).len(), 1);

    // Saturate.
    let mut handles = Vec::new();
    for i in 0..40u8 {
        if let Ok(h) = set.submit(AppId(1), Payload::Bytes(vec![i])) {
            handles.push(h);
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    std::thread::sleep(Duration::from_millis(300)); // utilization builds
    let action = set.rebalance().expect("hot diffusion must trigger scale-up");
    assert_eq!(action.to, diffusion);
    assert_eq!(set.nm.stage_instances(diffusion).len(), 2);

    // Everything still completes after the topology change.
    let done = handles
        .iter()
        .filter(|h| matches!(h.wait(Duration::from_secs(20)), WaitOutcome::Done(_)))
        .count();
    assert!(done >= handles.len() * 8 / 10, "done={done}/{}", handles.len());
    set.shutdown();
}

#[test]
fn instance_death_is_isolated() {
    // §1 "Fault Isolation": killing one instance of a stage loses only
    // the requests routed to it; the sibling instance keeps the workflow
    // serving, and after the NM drops the dead instance from the routing
    // table, completion returns to 100%.
    let cfg = fast_config();
    let pool = build_pool(&cfg, None);
    // Two instances at every stage.
    let set = WorkflowSet::build(
        cfg.clone(),
        vec![vec![2, 2, 2, 2]],
        Arc::new(EchoLogic),
        pool.clone(),
    );
    std::thread::sleep(Duration::from_millis(80));

    // Kill one diffusion instance by reassigning it to the idle pool
    // (the NM-level equivalent of a node death: it leaves the routing
    // table; in-flight ring contents are lost per §9).
    let diffusion = StageKey { app: AppId(1), stage: 2 };
    let victims = set.nm.stage_instances(diffusion);
    set.nm.assign(victims[0], None);
    std::thread::sleep(Duration::from_millis(60)); // routing propagates

    let mut handles = Vec::new();
    for i in 0..20u8 {
        if let Ok(h) = set.submit(AppId(1), Payload::Bytes(vec![i])) {
            handles.push(h);
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let done = handles
        .iter()
        .filter(|h| matches!(h.wait(Duration::from_secs(10)), WaitOutcome::Done(_)))
        .count();
    assert_eq!(
        done,
        handles.len(),
        "remaining instance must serve all post-failure requests"
    );
    assert_eq!(set.nm.stage_instances(diffusion).len(), 1);
    set.shutdown();
}

#[test]
fn fabric_traffic_accounted() {
    let fabric = Fabric::ideal();
    let (ops0, bytes0) = fabric.traffic();
    assert_eq!((ops0, bytes0), (0, 0));
    let (id, _r) = fabric.register(1024);
    let qp = fabric.connect(id).unwrap();
    qp.post_write(0, &[0u8; 512]).unwrap();
    let (ops, bytes) = fabric.traffic();
    assert_eq!(ops, 1);
    assert_eq!(bytes, 512);
    let _ = NodeId(0);
}
