//! E13 safety: randomized Paxos schedules — concurrent proposers,
//! message loss, retries — can never decide two different values in one
//! instance. This is the property the NM election relies on (§8.1: "at
//! most one leader is elected at any given time").

use onepiece::paxos::{propose, Acceptor, AcceptorHandle, Ballot, PrepareReply, ProposeError};
use onepiece::util::{NodeId, Rng};
use std::sync::{Arc, Mutex};

/// Acceptor handle that drops messages with probability `p` (decided by
/// a shared deterministic RNG).
struct Lossy {
    inner: Arc<Mutex<Acceptor>>,
    rng: Arc<Mutex<Rng>>,
    p: f64,
}

impl Lossy {
    fn drop_now(&self) -> bool {
        self.rng.lock().unwrap().f64() < self.p
    }
}

impl AcceptorHandle for Lossy {
    fn prepare(&self, b: Ballot) -> Option<PrepareReply> {
        if self.drop_now() {
            return None;
        }
        Some(self.inner.lock().unwrap().prepare(b))
    }

    fn accept(&self, b: Ballot, v: u64) -> Option<Result<(), Ballot>> {
        if self.drop_now() {
            return None;
        }
        Some(self.inner.lock().unwrap().accept(b, v))
    }
}

#[test]
fn randomized_schedules_never_decide_twice() {
    for seed in 0..50u64 {
        let rng = Arc::new(Mutex::new(Rng::new(seed)));
        let acceptors: Vec<Arc<Mutex<Acceptor>>> =
            (0..5).map(|_| Arc::new(Mutex::new(Acceptor::new()))).collect();
        let loss = (seed % 4) as f64 * 0.1; // 0%..30% loss

        let mut decided: Option<u64> = None;
        // 3 proposers, interleaved retries with escalating ballots.
        let mut ballots: Vec<Ballot> =
            (0..3).map(|p| Ballot::new(1, NodeId(p))).collect();
        for round in 0..40u64 {
            let p = (round % 3) as usize;
            let handles: Vec<Lossy> = acceptors
                .iter()
                .map(|a| Lossy { inner: a.clone(), rng: rng.clone(), p: loss })
                .collect();
            match propose(&handles, ballots[p], 100 + p as u64) {
                Ok(v) => {
                    if let Some(prev) = decided {
                        assert_eq!(
                            prev, v,
                            "seed {seed}: two different values decided!"
                        );
                    }
                    decided = Some(v);
                }
                Err(ProposeError::Preempted { suggested }) => {
                    ballots[p] = suggested.next_for(NodeId(p as u32));
                }
                Err(_) => {
                    ballots[p] = ballots[p].next_for(NodeId(p as u32));
                }
            }
        }
        // With ≤30% loss and 40 rounds, some value must be decided.
        assert!(decided.is_some(), "seed {seed}: no decision reached");
    }
}

#[test]
fn decided_value_is_stable_across_later_ballots() {
    let acceptors: Vec<Arc<Mutex<Acceptor>>> =
        (0..3).map(|_| Arc::new(Mutex::new(Acceptor::new()))).collect();
    let first = propose(&acceptors, Ballot::new(1, NodeId(0)), 7).unwrap();
    for round in 2..20 {
        let v = propose(&acceptors, Ballot::new(round, NodeId(1)), 999).unwrap();
        assert_eq!(v, first, "a decided value can never change");
    }
}

#[test]
fn partitioned_minority_cannot_decide() {
    let acceptors: Vec<Arc<Mutex<Acceptor>>> =
        (0..5).map(|_| Arc::new(Mutex::new(Acceptor::new()))).collect();
    // Proposer only reaches 2 of 5.
    struct Partition {
        inner: Arc<Mutex<Acceptor>>,
        reachable: bool,
    }
    impl AcceptorHandle for Partition {
        fn prepare(&self, b: Ballot) -> Option<PrepareReply> {
            self.reachable.then(|| self.inner.lock().unwrap().prepare(b))
        }
        fn accept(&self, b: Ballot, v: u64) -> Option<Result<(), Ballot>> {
            self.reachable.then(|| self.inner.lock().unwrap().accept(b, v))
        }
    }
    let handles: Vec<Partition> = acceptors
        .iter()
        .enumerate()
        .map(|(i, a)| Partition { inner: a.clone(), reachable: i < 2 })
        .collect();
    assert!(propose(&handles, Ballot::new(1, NodeId(0)), 1).is_err());
    // The majority side can still decide its own value.
    let handles: Vec<Partition> = acceptors
        .iter()
        .enumerate()
        .map(|(i, a)| Partition { inner: a.clone(), reachable: i >= 2 })
        .collect();
    assert_eq!(propose(&handles, Ballot::new(2, NodeId(1)), 2), Ok(2));
}
