//! E13 safety: randomized Paxos schedules — concurrent proposers,
//! message loss, retries — can never decide two different values in one
//! instance. This is the property the NM election relies on (§8.1: "at
//! most one leader is elected at any given time").

use onepiece::paxos::{propose, Acceptor, AcceptorHandle, Ballot, PrepareReply, ProposeError};
use onepiece::rdma::{Fabric, FabricConfig, FaultPlan, QueuePair};
use onepiece::util::{NodeId, Rng};
use std::sync::{Arc, Mutex};

/// Acceptor handle that drops messages with probability `p` (decided by
/// a shared deterministic RNG).
struct Lossy {
    inner: Arc<Mutex<Acceptor>>,
    rng: Arc<Mutex<Rng>>,
    p: f64,
}

impl Lossy {
    fn drop_now(&self) -> bool {
        self.rng.lock().unwrap().f64() < self.p
    }
}

impl AcceptorHandle for Lossy {
    fn prepare(&self, b: Ballot) -> Option<PrepareReply> {
        if self.drop_now() {
            return None;
        }
        Some(self.inner.lock().unwrap().prepare(b))
    }

    fn accept(&self, b: Ballot, v: u64) -> Option<Result<(), Ballot>> {
        if self.drop_now() {
            return None;
        }
        Some(self.inner.lock().unwrap().accept(b, v))
    }
}

#[test]
fn randomized_schedules_never_decide_twice() {
    for seed in 0..50u64 {
        let rng = Arc::new(Mutex::new(Rng::new(seed)));
        let acceptors: Vec<Arc<Mutex<Acceptor>>> =
            (0..5).map(|_| Arc::new(Mutex::new(Acceptor::new()))).collect();
        let loss = (seed % 4) as f64 * 0.1; // 0%..30% loss

        let mut decided: Option<u64> = None;
        // 3 proposers, interleaved retries with escalating ballots.
        let mut ballots: Vec<Ballot> =
            (0..3).map(|p| Ballot::new(1, NodeId(p))).collect();
        for round in 0..40u64 {
            let p = (round % 3) as usize;
            let handles: Vec<Lossy> = acceptors
                .iter()
                .map(|a| Lossy { inner: a.clone(), rng: rng.clone(), p: loss })
                .collect();
            match propose(&handles, ballots[p], 100 + p as u64) {
                Ok(v) => {
                    if let Some(prev) = decided {
                        assert_eq!(
                            prev, v,
                            "seed {seed}: two different values decided!"
                        );
                    }
                    decided = Some(v);
                }
                Err(ProposeError::Preempted { suggested }) => {
                    ballots[p] = suggested.next_for(NodeId(p as u32));
                }
                Err(_) => {
                    ballots[p] = ballots[p].next_for(NodeId(p as u32));
                }
            }
        }
        // With ≤30% loss and 40 rounds, some value must be decided.
        assert!(decided.is_some(), "seed {seed}: no decision reached");
    }
}

#[test]
fn decided_value_is_stable_across_later_ballots() {
    let acceptors: Vec<Arc<Mutex<Acceptor>>> =
        (0..3).map(|_| Arc::new(Mutex::new(Acceptor::new()))).collect();
    let first = propose(&acceptors, Ballot::new(1, NodeId(0)), 7).unwrap();
    for round in 2..20 {
        let v = propose(&acceptors, Ballot::new(round, NodeId(1)), 999).unwrap();
        assert_eq!(v, first, "a decided value can never change");
    }
}

#[test]
fn partitioned_minority_cannot_decide() {
    let acceptors: Vec<Arc<Mutex<Acceptor>>> =
        (0..5).map(|_| Arc::new(Mutex::new(Acceptor::new()))).collect();
    // Proposer only reaches 2 of 5.
    struct Partition {
        inner: Arc<Mutex<Acceptor>>,
        reachable: bool,
    }
    impl AcceptorHandle for Partition {
        fn prepare(&self, b: Ballot) -> Option<PrepareReply> {
            self.reachable.then(|| self.inner.lock().unwrap().prepare(b))
        }
        fn accept(&self, b: Ballot, v: u64) -> Option<Result<(), Ballot>> {
            self.reachable.then(|| self.inner.lock().unwrap().accept(b, v))
        }
    }
    let handles: Vec<Partition> = acceptors
        .iter()
        .enumerate()
        .map(|(i, a)| Partition { inner: a.clone(), reachable: i < 2 })
        .collect();
    assert!(propose(&handles, Ballot::new(1, NodeId(0)), 1).is_err());
    // The majority side can still decide its own value.
    let handles: Vec<Partition> = acceptors
        .iter()
        .enumerate()
        .map(|(i, a)| Partition { inner: a.clone(), reachable: i >= 2 })
        .collect();
    assert_eq!(propose(&handles, Ballot::new(2, NodeId(1)), 2), Ok(2));
}

/// Acceptor handle whose messages traverse a fault-injected fabric
/// link: each exchange posts one gated verb against the acceptor's
/// region, so seeded verb loss and directed partitions from the
/// [`FaultPlan`] become Paxos message drops.
struct FaultyLink<'a> {
    inner: Arc<Mutex<Acceptor>>,
    qp: &'a QueuePair,
}

impl AcceptorHandle for FaultyLink<'_> {
    fn prepare(&self, b: Ballot) -> Option<PrepareReply> {
        self.qp.post_write_u64(0, 1).ok()?;
        Some(self.inner.lock().unwrap().prepare(b))
    }

    fn accept(&self, b: Ballot, v: u64) -> Option<Result<(), Ballot>> {
        self.qp.post_write_u64(0, 1).ok()?;
        Some(self.inner.lock().unwrap().accept(b, v))
    }
}

#[test]
fn elections_under_injected_loss_and_healed_partition_stay_safe_and_live() {
    // NM elections over a lossy, partition-prone fabric: each term is
    // one Paxos instance whose messages cross FaultPlan-gated links.
    // Safety: within a term, every successful proposal returns the same
    // leader (at most one leader per term). Liveness: a majority of
    // acceptor links stays reachable (the partition cuts region id 1
    // only), so every term converges — including the partitioned terms
    // and the ones after the heal.
    let fabric = Fabric::new(FabricConfig {
        latency: None,
        faults: Some(FaultPlan {
            verb_loss_prob: 0.15,
            ..Default::default()
        }),
        ..Default::default()
    });
    let qps: Vec<QueuePair> = (0..5)
        .map(|_| {
            let (id, _) = fabric.register(64);
            fabric.connect(id).expect("fresh region connects")
        })
        .collect();

    for term in 1..=6u64 {
        // Terms 3-4 run under a directed partition (acceptor region 1
        // unreachable); term 5 heals it.
        if term == 3 {
            fabric.start_partition(4, 1);
        }
        if term == 5 {
            fabric.heal_partition();
        }
        let acceptors: Vec<Arc<Mutex<Acceptor>>> =
            (0..5).map(|_| Arc::new(Mutex::new(Acceptor::new()))).collect();
        let handles: Vec<FaultyLink> = acceptors
            .iter()
            .zip(&qps)
            .map(|(a, qp)| FaultyLink { inner: a.clone(), qp })
            .collect();
        let mut leader: Option<u64> = None;
        let mut ballots: Vec<Ballot> =
            (0..3u32).map(|p| Ballot::new(term, NodeId(p))).collect();
        for round in 0..90u64 {
            let p = (round % 3) as usize;
            match propose(&handles, ballots[p], 100 + p as u64) {
                Ok(v) => {
                    if let Some(prev) = leader {
                        assert_eq!(prev, v, "term {term}: two leaders elected!");
                    }
                    leader = Some(v);
                }
                Err(ProposeError::Preempted { suggested }) => {
                    ballots[p] = suggested.next_for(NodeId(p as u32));
                }
                Err(_) => {
                    ballots[p] = ballots[p].next_for(NodeId(p as u32));
                }
            }
        }
        assert!(
            leader.is_some(),
            "term {term}: a majority stays reachable, so the election must converge"
        );
    }
    let stats = fabric.fault_stats().expect("faults block allocates fault state");
    assert!(stats.verbs_lost >= 1, "loss injection must have fired");
    assert!(
        stats.partitioned_ops >= 1,
        "the partitioned terms must have rejected verbs on the victim link"
    );
}
