//! Property tests for the pipelining math (no proptest offline — seeded
//! randomized sweeps with explicit failure seeds).
//!
//! Invariants from §5 / Theorem 1:
//!  P1  rate matching: Theorem-1 sizing gives output interval == T_X/K;
//!  P2  no in-pipeline queueing: completion(r) == admit(r) + Σ T_i;
//!  P3  monotonicity: more instances never increase the output interval;
//!  P4  chain conservation: every stage plan sustains ≥ the chain rate;
//!  P5  GPU accounting: total == Σ instances·gpus_per_instance.

use onepiece::pipeline::{instances_needed, plan_chain, trace_schedule, StageReq, TraceStage};
use onepiece::util::Rng;

fn random_two_stage(rng: &mut Rng) -> (usize, f64, f64) {
    let k = 1 + rng.below(6) as usize;
    let tx = 0.5 + rng.f64() * 4.0;
    let ty = tx * (1.0 + rng.f64() * 6.0); // T_Y > T_X per the theorem
    (k, tx, ty)
}

#[test]
fn p1_p2_rate_matching_and_no_queueing() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed);
        let (k, tx, ty) = random_two_stage(&mut rng);
        let m = instances_needed(k, tx, ty);
        let stages = vec![
            TraceStage { name: "X".into(), exec_s: tx, instances: 1, workers: k },
            TraceStage { name: "Y".into(), exec_s: ty, instances: m, workers: 1 },
        ];
        let admit = tx / k as f64;
        let n = (m * 5).max(20);
        let t = trace_schedule(&stages, n, admit);
        assert!(
            (t.output_interval_s - admit).abs() < 1e-6,
            "seed {seed}: interval {} != {admit}",
            t.output_interval_s
        );
        // P2: completion(r) = r*admit + tx + ty exactly (no waiting).
        for (r, &c) in t.completions.iter().enumerate() {
            let expect = r as f64 * admit + tx + ty;
            assert!(
                (c - expect).abs() < 1e-6,
                "seed {seed}: req {r} queued ({c} vs {expect})"
            );
        }
    }
}

#[test]
fn p3_more_instances_never_slower() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed * 7 + 1);
        let (k, tx, ty) = random_two_stage(&mut rng);
        let m = instances_needed(k, tx, ty);
        let admit = tx / k as f64;
        let interval = |mm: usize| {
            let stages = vec![
                TraceStage { name: "X".into(), exec_s: tx, instances: 1, workers: k },
                TraceStage { name: "Y".into(), exec_s: ty, instances: mm, workers: 1 },
            ];
            trace_schedule(&stages, (mm * 5).max(20), admit).output_interval_s
        };
        let at_m = interval(m);
        let at_m_plus = interval(m + 1 + rng.below(3) as usize);
        assert!(
            at_m_plus <= at_m + 1e-9,
            "seed {seed}: extra instances slowed the pipeline"
        );
    }
}

#[test]
fn p4_p5_chain_conservation_and_gpu_accounting() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed * 13 + 5);
        let nstages = 2 + rng.below(5) as usize;
        let stages: Vec<StageReq> = (0..nstages)
            .map(|i| StageReq {
                name: format!("s{i}"),
                exec_s: 0.2 + rng.f64() * 8.0,
                gpus_per_instance: 1 + rng.below(4) as usize,
                workers: 1 + rng.below(3) as usize,
            })
            .collect();
        let entrance = 1 + rng.below(3) as usize;
        let plan = plan_chain(&stages, entrance);
        // P4: every stage sustains at least the chain output rate.
        for sp in &plan.stages {
            assert!(
                sp.rate >= plan.output_rate - 1e-9,
                "seed {seed}: stage {} under-provisioned",
                sp.name
            );
        }
        // The entrance is the bottleneck by construction.
        assert!(
            (plan.output_rate - plan.stages[0].rate).abs() < 1e-9,
            "seed {seed}: chain rate must equal entrance rate"
        );
        // P5: GPU accounting.
        let total: usize = plan
            .stages
            .iter()
            .zip(&stages)
            .map(|(p, s)| p.instances * s.gpus_per_instance)
            .sum();
        assert_eq!(total, plan.total_gpus, "seed {seed}");
        // Latency = sum of stage times.
        let lat: f64 = stages.iter().map(|s| s.exec_s).sum();
        assert!((plan.request_latency_s - lat).abs() < 1e-9);
    }
}

#[test]
fn theorem1_boundary_exact_multiples() {
    // When T_Y is an exact multiple of T_X, M-1 must fail and M succeed —
    // the ceiling is tight with no slack.
    for ratio in 2..=6usize {
        let tx = 3.0;
        let ty = tx * ratio as f64;
        let m = instances_needed(1, tx, ty);
        assert_eq!(m, ratio);
        let under = vec![
            TraceStage { name: "X".into(), exec_s: tx, instances: 1, workers: 1 },
            TraceStage { name: "Y".into(), exec_s: ty, instances: m - 1, workers: 1 },
        ];
        let t = trace_schedule(&under, 30, tx);
        assert!(t.output_interval_s > tx + 1e-9, "ratio {ratio} should degrade");
    }
}
