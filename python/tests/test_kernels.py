"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes; every test asserts allclose against ref.py.
This is the core correctness signal for the compute layer — the AOT
artifacts lower exactly these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, fused_mlp, layernorm, modulate
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

_SETTINGS = dict(max_examples=12, deadline=None)


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape).astype(
        jnp.float32
    )


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
@settings(**_SETTINGS)
@given(
    h=st.sampled_from([1, 2, 4]),
    sq=st.sampled_from([8, 16, 64, 128, 256]),
    sk=st.sampled_from([8, 32, 128, 256]),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(h, sq, sk, d, seed):
    q = _rand(seed, (h, sq, d))
    k = _rand(seed + 1, (h, sk, d))
    v = _rand(seed + 2, (h, sk, d))
    out = attention(q, k, v)
    expect = ref.attention_ref(q, k, v)
    assert out.shape == (h, sq, d)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)


@settings(**_SETTINGS)
@given(
    bq=st.sampled_from([16, 32, 64, 128]),
    bk=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_attention_block_size_invariant(bq, bk, seed):
    """Online-softmax result must not depend on the tiling."""
    q = _rand(seed, (2, 128, 32))
    k = _rand(seed + 1, (2, 128, 32))
    v = _rand(seed + 2, (2, 128, 32))
    tiled = attention(q, k, v, block_q=bq, block_k=bk)
    base = attention(q, k, v, block_q=128, block_k=128)
    np.testing.assert_allclose(tiled, base, rtol=1e-5, atol=1e-6)


def test_attention_large_logits_stable():
    """Online softmax must survive large-magnitude logits (no inf/nan)."""
    q = _rand(7, (1, 64, 32), scale=30.0)
    k = _rand(8, (1, 64, 32), scale=30.0)
    v = _rand(9, (1, 64, 32))
    out = attention(q, k, v)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v), rtol=1e-3,
                               atol=1e-4)


def test_attention_uniform_when_keys_equal():
    """Identical keys => output is the mean of values, independent of q."""
    q = _rand(1, (1, 16, 8))
    k = jnp.ones((1, 32, 8), jnp.float32)
    v = _rand(2, (1, 32, 8))
    out = attention(q, k, v)
    expect = jnp.broadcast_to(v.mean(axis=1, keepdims=True), out.shape)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fused_mlp
# ---------------------------------------------------------------------------
@settings(**_SETTINGS)
@given(
    s=st.sampled_from([8, 32, 64, 128, 256]),
    d=st.sampled_from([16, 64, 128]),
    f=st.sampled_from([32, 128, 512]),
    seed=st.integers(0, 2**16),
)
def test_fused_mlp_matches_ref(s, d, f, seed):
    x = _rand(seed, (s, d))
    w1 = _rand(seed + 1, (d, f), 0.1)
    b1 = _rand(seed + 2, (f,), 0.1)
    w2 = _rand(seed + 3, (f, d), 0.1)
    b2 = _rand(seed + 4, (d,), 0.1)
    out = fused_mlp(x, w1, b1, w2, b2)
    expect = ref.fused_mlp_ref(x, w1, b1, w2, b2)
    assert out.shape == (s, d)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)


@settings(**_SETTINGS)
@given(bs=st.sampled_from([16, 32, 64, 128, 256]), seed=st.integers(0, 2**16))
def test_fused_mlp_block_invariant(bs, seed):
    x = _rand(seed, (256, 64))
    w1 = _rand(seed + 1, (64, 128), 0.1)
    b1 = _rand(seed + 2, (128,), 0.1)
    w2 = _rand(seed + 3, (128, 64), 0.1)
    b2 = _rand(seed + 4, (64,), 0.1)
    np.testing.assert_allclose(
        fused_mlp(x, w1, b1, w2, b2, block_s=bs),
        fused_mlp(x, w1, b1, w2, b2, block_s=256),
        rtol=1e-6, atol=1e-7,
    )


def test_fused_mlp_zero_weights_give_bias():
    x = _rand(0, (16, 8))
    w1 = jnp.zeros((8, 4), jnp.float32)
    b1 = jnp.zeros((4,), jnp.float32)
    w2 = jnp.zeros((4, 8), jnp.float32)
    b2 = jnp.full((8,), 3.0, jnp.float32)
    out = fused_mlp(x, w1, b1, w2, b2)
    np.testing.assert_allclose(out, jnp.broadcast_to(b2, (16, 8)), atol=1e-7)


# ---------------------------------------------------------------------------
# modulate
# ---------------------------------------------------------------------------
@settings(**_SETTINGS)
@given(
    s=st.sampled_from([8, 64, 256]),
    d=st.sampled_from([16, 128]),
    seed=st.integers(0, 2**16),
)
def test_modulate_matches_ref(s, d, seed):
    x = _rand(seed, (s, d))
    shift = _rand(seed + 1, (d,))
    scale = _rand(seed + 2, (d,))
    gate = _rand(seed + 3, (d,))
    res = _rand(seed + 4, (s, d))
    out = modulate(x, shift, scale, gate, res)
    expect = ref.modulate_ref(x, shift, scale, gate, res)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)


def test_modulate_zero_gate_is_identity():
    """adaLN-Zero init: gate=0 => block output == residual."""
    x = _rand(1, (32, 16))
    res = _rand(2, (32, 16))
    zero = jnp.zeros((16,), jnp.float32)
    out = modulate(x, _rand(3, (16,)), _rand(4, (16,)), zero, res)
    np.testing.assert_allclose(out, res, atol=1e-7)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------
@settings(**_SETTINGS)
@given(
    s=st.sampled_from([8, 64, 256]),
    d=st.sampled_from([16, 128]),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
    seed=st.integers(0, 2**16),
)
def test_layernorm_matches_ref(s, d, scale, seed):
    x = _rand(seed, (s, d), scale)
    out = layernorm(x)
    expect = ref.layernorm_ref(x)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)


def test_layernorm_output_standardized():
    x = _rand(3, (32, 64), 7.0) + 5.0
    out = np.asarray(layernorm(x))
    np.testing.assert_allclose(out.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(out.var(axis=-1), 1.0, rtol=1e-3)


@settings(**_SETTINGS)
@given(bs=st.sampled_from([32, 64, 128, 256]), seed=st.integers(0, 2**16))
def test_layernorm_block_invariant(bs, seed):
    x = _rand(seed, (256, 32))
    np.testing.assert_allclose(
        layernorm(x, block_s=bs), layernorm(x, block_s=256), rtol=1e-6, atol=1e-7
    )


def test_layernorm_constant_row_is_zero():
    x = jnp.full((8, 16), 3.5, jnp.float32)
    out = layernorm(x)
    np.testing.assert_allclose(out, jnp.zeros_like(x), atol=1e-3)


# ---------------------------------------------------------------------------
# gelu epilogue parity (kernel-internal gelu vs ref)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scale", [0.1, 1.0, 10.0])
def test_gelu_parity(scale):
    from compile.kernels.fused_mlp import _gelu

    x = _rand(11, (64,), scale)
    np.testing.assert_allclose(_gelu(x), ref.gelu_ref(x), rtol=1e-6, atol=1e-7)
