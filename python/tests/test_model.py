"""L2 stage-model contracts: shapes, determinism, conditioning, dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


def _tokens(seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (model.SEQ_TEXT,), 0, model.VOCAB
    ).astype(jnp.int32)


def _image(seed=1):
    return jax.random.uniform(
        jax.random.PRNGKey(seed), (model.IMG_HW, model.IMG_HW, model.IMG_C)
    ).astype(jnp.float32)


def _latent(seed=2):
    return jax.random.normal(
        jax.random.PRNGKey(seed), (model.VID_TOKENS, model.D_LATENT)
    ).astype(jnp.float32)


@pytest.mark.parametrize("name", list(model.STAGES))
def test_stage_shapes(name):
    fn, arg_specs, out_shape = model.STAGES[name]
    args = []
    for i, (_, dtype, shape) in enumerate(arg_specs):
        if dtype == jnp.int32:
            args.append(_tokens(i))
        else:
            args.append(
                jax.random.normal(jax.random.PRNGKey(i), shape).astype(
                    jnp.float32
                )
            )
    out = fn(*args)
    assert out.shape == out_shape
    assert out.dtype == jnp.float32
    assert np.isfinite(np.asarray(out)).all()


def test_params_deterministic():
    """Same seed => identical weights => identical artifacts across builds."""
    model.build_params.cache_clear()
    a = model.build_params()["di.out"]
    model.build_params.cache_clear()
    b = model.build_params()["di.out"]
    np.testing.assert_array_equal(a, b)


def test_text_encoder_token_sensitivity():
    a = model.text_encoder(_tokens(0))
    b = model.text_encoder(_tokens(99))
    assert not np.allclose(a, b)


def test_vae_roundtrip_shape_chain():
    """encode -> tile to video tokens -> decode composes shape-wise."""
    img_lat = model.vae_encode(_image())
    assert img_lat.shape == (model.IMG_TOKENS, model.D_LATENT)
    video_lat = jnp.tile(img_lat, (model.FRAMES, 1))
    video = model.vae_decode(video_lat)
    assert video.shape == (model.FRAMES, model.IMG_HW, model.IMG_HW,
                           model.IMG_C)


def test_diffusion_step_conditioning_matters():
    x = _latent()
    t = jnp.array([500.0], jnp.float32)
    dt = jnp.array([1.0 / 8], jnp.float32)
    ctx_a = model.text_encoder(_tokens(0))
    ctx_b = model.text_encoder(_tokens(7))
    lat = model.vae_encode(_image())
    out_a = model.diffusion_step(x, t, dt, ctx_a, lat)
    out_b = model.diffusion_step(x, t, dt, ctx_b, lat)
    assert not np.allclose(out_a, out_b)


def test_diffusion_step_zero_dt_is_identity():
    x = _latent()
    out = model.diffusion_step(
        x,
        jnp.array([100.0], jnp.float32),
        jnp.array([0.0], jnp.float32),
        model.text_encoder(_tokens()),
        model.vae_encode(_image()),
    )
    np.testing.assert_allclose(out, x, atol=1e-6)


def test_diffusion_multi_step_stays_finite():
    """8 Euler steps (the rust driver's loop) stay numerically sane."""
    x = _latent()
    ctx = model.text_encoder(_tokens())
    lat = model.vae_encode(_image())
    steps = 8
    dt = jnp.array([1.0 / steps], jnp.float32)
    for i in range(steps):
        t = jnp.array([1000.0 * (1 - i / steps)], jnp.float32)
        x = model.diffusion_step(x, t, dt, ctx, lat)
    assert np.isfinite(np.asarray(x)).all()
    assert float(jnp.abs(x).max()) < 1e3


def test_vae_decode_bounded():
    """Decoder ends in tanh => pixels in [-1, 1]."""
    video = model.vae_decode(_latent())
    assert float(jnp.abs(video).max()) <= 1.0 + 1e-6
