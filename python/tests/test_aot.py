"""AOT path: every stage lowers to parseable HLO text with the right
signature, and the manifest matches model.STAGES."""

import json
import re

import jax
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def lowered():
    return {name: aot.lower_stage(name) for name in model.STAGES}


@pytest.mark.parametrize("name", list(model.STAGES))
def test_hlo_text_has_entry(lowered, name):
    text, _ = lowered[name]
    assert "ENTRY" in text
    assert "HloModule" in text


@pytest.mark.parametrize("name", list(model.STAGES))
def test_no_elided_constants(lowered, name):
    """print_large_constants must be in effect: an elided `constant({...})`
    would silently drop baked weights on the rust side."""
    text, _ = lowered[name]
    assert "{...}" not in text


@pytest.mark.parametrize("name", list(model.STAGES))
def test_no_metadata_attributes(lowered, name):
    """xla_extension 0.5.1's parser rejects jax's newer metadata attrs."""
    text, _ = lowered[name]
    assert "source_end_line" not in text


@pytest.mark.parametrize("name", list(model.STAGES))
def test_hlo_weights_are_constants(lowered, name):
    """Weights are baked: parameter count == model.STAGES arg count."""
    text, entry = lowered[name]
    # The entry computation's parameters — one per activation input.
    entry_block = text.split("ENTRY")[1]
    params = re.findall(r"parameter\(\d+\)", entry_block)
    assert len(params) == len(entry["inputs"])
    # Baked weights show up as constants somewhere in the module.
    assert "constant" in text


@pytest.mark.parametrize("name", list(model.STAGES))
def test_manifest_entry_shapes(lowered, name):
    _, entry = lowered[name]
    _, arg_specs, out_shape = model.STAGES[name]
    assert [tuple(i["shape"]) for i in entry["inputs"]] == [
        s for _, _, s in arg_specs
    ]
    assert tuple(entry["output"]["shape"]) == out_shape


def test_main_writes_artifacts(tmp_path, monkeypatch):
    """End-to-end aot.main over a stage subset."""
    import sys

    monkeypatch.setattr(
        sys, "argv",
        ["aot", "--out", str(tmp_path), "--stages", "vae_encode"],
    )
    aot.main()
    assert (tmp_path / "vae_encode.hlo.txt").exists()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert "vae_encode" in manifest["stages"]
    assert manifest["dims"]["d_latent"] == model.D_LATENT
