"""AOT lowering: JAX stage models -> HLO text artifacts + manifest.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
rust `xla` 0.1.6 crate links) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (`make artifacts`):

    cd python && python -m compile.aot --out ../artifacts

Outputs, per stage in model.STAGES:
    artifacts/<stage>.hlo.txt     — HLO text, weights baked as constants
    artifacts/manifest.json       — input/output shapes+dtypes for rust
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True: rust
    unwraps with to_tuple1).

    Print options matter: `print_large_constants=True` or the baked weights
    are elided as `constant({...})` and the rust-side parser would reject
    (or zero) them; `print_metadata=False` because jax's current metadata
    attributes (`source_end_line` etc.) are unknown to xla_extension
    0.5.1's HLO parser.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    mod = comp.get_hlo_module()
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return mod.to_string(opts)


def lower_stage(name: str) -> tuple[str, dict]:
    """Lower one stage; returns (hlo_text, manifest entry)."""
    fn, arg_specs, out_shape = model.STAGES[name]
    specs = [jax.ShapeDtypeStruct(shape, dtype) for _, dtype, shape in arg_specs]
    lowered = jax.jit(fn).lower(*specs)
    entry = {
        "inputs": [
            {"name": n, "dtype": jnp.dtype(d).name, "shape": list(s)}
            for n, d, s in arg_specs
        ],
        "output": {"dtype": "float32", "shape": list(out_shape)},
        "file": f"{name}.hlo.txt",
    }
    return to_hlo_text(lowered), entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--stages", nargs="*", default=list(model.STAGES),
                    help="subset of stages to lower")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {
        "dims": {
            "vocab": model.VOCAB, "seq_text": model.SEQ_TEXT,
            "d_model": model.D_MODEL, "heads": model.HEADS,
            "d_ff": model.D_FF, "img_hw": model.IMG_HW,
            "img_c": model.IMG_C, "patch": model.PATCH,
            "img_tokens": model.IMG_TOKENS, "d_latent": model.D_LATENT,
            "frames": model.FRAMES, "vid_tokens": model.VID_TOKENS,
            "seed": model.SEED,
        },
        "stages": {},
    }
    for name in args.stages:
        text, entry = lower_stage(name)
        path = os.path.join(args.out, entry["file"])
        with open(path, "w") as f:
            f.write(text)
        manifest["stages"][name] = entry
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
