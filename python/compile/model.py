"""L2 JAX stage models for the OnePiece AIGC workflow (build-time only).

Four stage models mirror the paper's Wan2.1 image-to-video pipeline (§2.4):

  text_encoder    — T5/CLIP stand-in: token embedding + transformer blocks
  vae_encode      — patchify + MLP projection of the input image to latents
  diffusion_step  — one DiT denoising step (self-attn, cross-attn to text +
                    image conditioning, adaLN-Zero time modulation, Euler
                    update) — the hot spot; every matmul-heavy op routes
                    through the L1 Pallas kernels
  vae_decode      — latent video tokens back to pixel frames

Weights are generated from a fixed PRNG seed and *baked into the HLO as
constants* at lowering time, so the rust runtime passes activations only.
Shapes are deliberately small (≈1.1 M params total) — the paper's system
contribution is the coordination layer; these models give each workflow
stage real, asymmetric compute (diffusion ≫ encoders), which is what the
resource experiments need (DESIGN.md §2 substitutions).
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels import attention, fused_mlp, layernorm, modulate

# ---------------------------------------------------------------------------
# Dimensions (single source of truth; mirrored in artifacts/manifest.json).
# ---------------------------------------------------------------------------
VOCAB = 512          # text vocabulary
SEQ_TEXT = 32        # prompt tokens
D_MODEL = 128        # transformer width
HEADS = 4
HEAD_DIM = D_MODEL // HEADS
D_FF = 512           # MLP hidden width
IMG_HW = 32          # input image height/width
IMG_C = 3
PATCH = 4            # VAE patch size
IMG_TOKENS = (IMG_HW // PATCH) ** 2          # 64 image latent tokens
D_LATENT = 16        # latent channel width
FRAMES = 4           # generated video frames
VID_TOKENS = FRAMES * IMG_TOKENS             # 256 video latent tokens
TEXT_BLOCKS = 2      # encoder depth
DIT_BLOCKS = 2       # diffusion transformer depth
SEED = 20260710      # weight PRNG seed (fixed => reproducible artifacts)

_PATCH_DIM = PATCH * PATCH * IMG_C           # 48


# ---------------------------------------------------------------------------
# Parameters. Built once per process; treated as compile-time constants.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=1)
def build_params() -> Dict[str, "np.ndarray"]:
    """Deterministic parameter set for all four stages.

    Built with *numpy* (never jax): (1) numpy closures always lower to
    `stablehlo.constant` — baked into the artifact — whereas committed
    jax.Arrays can be hoisted into entry parameters by later lowerings in
    the same process, which would change the rust-side call signature;
    (2) numpy construction cannot accidentally be staged into a jit trace.
    """
    import numpy as np

    rng = np.random.default_rng(SEED)

    def _init(_key, shape, scale: float = 0.02):
        return (scale * rng.standard_normal(shape)).astype(np.float32)

    jnp = np  # shadow: zeros() below builds numpy arrays
    keys = iter(range(256))
    p: Dict[str, np.ndarray] = {}

    # --- text encoder ---
    p["te.embed"] = _init(next(keys), (VOCAB, D_MODEL), 0.05)
    p["te.pos"] = _init(next(keys), (SEQ_TEXT, D_MODEL), 0.02)
    for b in range(TEXT_BLOCKS):
        pre = f"te.{b}."
        p[pre + "wq"] = _init(next(keys), (D_MODEL, D_MODEL))
        p[pre + "wk"] = _init(next(keys), (D_MODEL, D_MODEL))
        p[pre + "wv"] = _init(next(keys), (D_MODEL, D_MODEL))
        p[pre + "wo"] = _init(next(keys), (D_MODEL, D_MODEL))
        p[pre + "w1"] = _init(next(keys), (D_MODEL, D_FF))
        p[pre + "b1"] = jnp.zeros((D_FF,), jnp.float32)
        p[pre + "w2"] = _init(next(keys), (D_FF, D_MODEL))
        p[pre + "b2"] = jnp.zeros((D_MODEL,), jnp.float32)

    # --- VAE encoder ---
    p["ve.proj1"] = _init(next(keys), (_PATCH_DIM, D_MODEL), 0.05)
    p["ve.b1"] = jnp.zeros((D_MODEL,), jnp.float32)
    p["ve.w1"] = _init(next(keys), (D_MODEL, D_FF))
    p["ve.bb1"] = jnp.zeros((D_FF,), jnp.float32)
    p["ve.w2"] = _init(next(keys), (D_FF, D_MODEL))
    p["ve.bb2"] = jnp.zeros((D_MODEL,), jnp.float32)
    p["ve.proj2"] = _init(next(keys), (D_MODEL, D_LATENT), 0.05)
    p["ve.b2"] = jnp.zeros((D_LATENT,), jnp.float32)

    # --- diffusion (DiT) ---
    p["di.in"] = _init(next(keys), (D_LATENT, D_MODEL), 0.05)
    p["di.pos"] = _init(next(keys), (VID_TOKENS, D_MODEL), 0.02)
    p["di.img_in"] = _init(next(keys), (D_LATENT, D_MODEL), 0.05)
    p["di.t1"] = _init(next(keys), (D_MODEL, D_MODEL))
    p["di.t2"] = _init(next(keys), (D_MODEL, 6 * D_MODEL * DIT_BLOCKS), 0.01)
    for b in range(DIT_BLOCKS):
        pre = f"di.{b}."
        for n in ("wq", "wk", "wv", "wo", "cq", "ck", "cv", "co"):
            p[pre + n] = _init(next(keys), (D_MODEL, D_MODEL))
        p[pre + "w1"] = _init(next(keys), (D_MODEL, D_FF))
        p[pre + "b1"] = jnp.zeros((D_FF,), jnp.float32)
        p[pre + "w2"] = _init(next(keys), (D_FF, D_MODEL))
        p[pre + "b2"] = jnp.zeros((D_MODEL,), jnp.float32)
    p["di.out"] = _init(next(keys), (D_MODEL, D_LATENT), 0.02)

    # --- VAE decoder ---
    p["vd.proj1"] = _init(next(keys), (D_LATENT, D_MODEL), 0.05)
    p["vd.b1"] = jnp.zeros((D_MODEL,), jnp.float32)
    p["vd.w1"] = _init(next(keys), (D_MODEL, D_FF))
    p["vd.bb1"] = jnp.zeros((D_FF,), jnp.float32)
    p["vd.w2"] = _init(next(keys), (D_FF, D_MODEL))
    p["vd.bb2"] = jnp.zeros((D_MODEL,), jnp.float32)
    p["vd.proj2"] = _init(next(keys), (D_MODEL, _PATCH_DIM), 0.05)
    p["vd.b2"] = jnp.zeros((_PATCH_DIM,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Shared blocks.
# ---------------------------------------------------------------------------
def _split_heads(x: jnp.ndarray) -> jnp.ndarray:
    """[S, D_MODEL] -> [HEADS, S, HEAD_DIM]."""
    s = x.shape[0]
    return x.reshape(s, HEADS, HEAD_DIM).transpose(1, 0, 2)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    """[HEADS, S, HEAD_DIM] -> [S, D_MODEL]."""
    h, s, d = x.shape
    return x.transpose(1, 0, 2).reshape(s, h * d)


def _mha(x: jnp.ndarray, kv: jnp.ndarray, p, pre: str, qn="wq", kn="wk",
         vn="wv", on="wo") -> jnp.ndarray:
    """Multi-head attention via the L1 Pallas kernel. x:[Sq,D], kv:[Sk,D]."""
    q = _split_heads(x @ p[pre + qn])
    k = _split_heads(kv @ p[pre + kn])
    v = _split_heads(kv @ p[pre + vn])
    return _merge_heads(attention(q, k, v)) @ p[pre + on]


def _encoder_block(x: jnp.ndarray, p, pre: str) -> jnp.ndarray:
    """Pre-LN transformer block (self-attn + fused MLP)."""
    h = layernorm(x)
    x = x + _mha(h, h, p, pre)
    h = layernorm(x)
    return x + fused_mlp(h, p[pre + "w1"], p[pre + "b1"], p[pre + "w2"],
                         p[pre + "b2"])


def _time_embed(t: jnp.ndarray, p) -> jnp.ndarray:
    """Sinusoidal timestep embedding -> MLP -> adaLN params [6*D*BLOCKS]."""
    half = D_MODEL // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / half)
    ang = t[0] * freqs
    emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])  # [D_MODEL]
    h = jnp.tanh(emb @ p["di.t1"])
    return h @ p["di.t2"]  # [6 * D_MODEL * DIT_BLOCKS]


# ---------------------------------------------------------------------------
# Stage entry points (AOT-lowered by aot.py).
# ---------------------------------------------------------------------------
def text_encoder(tokens: jnp.ndarray) -> jnp.ndarray:
    """T5/CLIP stand-in. tokens:i32[SEQ_TEXT] -> ctx f32[SEQ_TEXT, D_MODEL]."""
    p = build_params()
    x = jnp.take(p["te.embed"], tokens, axis=0) + p["te.pos"]
    for b in range(TEXT_BLOCKS):
        x = _encoder_block(x, p, f"te.{b}.")
    return layernorm(x)


def vae_encode(image: jnp.ndarray) -> jnp.ndarray:
    """Patchify + project. image f32[IMG_HW, IMG_HW, IMG_C] ->
    latent f32[IMG_TOKENS, D_LATENT]."""
    p = build_params()
    g = IMG_HW // PATCH
    patches = (
        image.reshape(g, PATCH, g, PATCH, IMG_C)
        .transpose(0, 2, 1, 3, 4)
        .reshape(IMG_TOKENS, _PATCH_DIM)
    )
    h = jnp.tanh(patches @ p["ve.proj1"] + p["ve.b1"])
    h = h + fused_mlp(layernorm(h), p["ve.w1"], p["ve.bb1"], p["ve.w2"],
                      p["ve.bb2"])
    return h @ p["ve.proj2"] + p["ve.b2"]


def _dit_block(x, ctx, c6, p, pre):
    """DiT block: adaLN-modulated self-attn, cross-attn, fused MLP.

    x:[VID_TOKENS, D], ctx:[SEQ_TEXT+IMG_TOKENS, D], c6: [6, D] adaLN params.
    """
    shift_a, scale_a, gate_a, shift_m, scale_m, gate_m = c6
    h = _mha(layernorm(x), layernorm(x), p, pre)  # self-attention
    x = modulate(h, shift_a, scale_a, gate_a, x)
    x = x + _mha(layernorm(x), ctx, p, pre, "cq", "ck", "cv", "co")  # cross
    h = fused_mlp(layernorm(x), p[pre + "w1"], p[pre + "b1"], p[pre + "w2"],
                  p[pre + "b2"])
    return modulate(h, shift_m, scale_m, gate_m, x)


def diffusion_step(x: jnp.ndarray, t: jnp.ndarray, dt: jnp.ndarray,
                   ctx: jnp.ndarray, img_lat: jnp.ndarray) -> jnp.ndarray:
    """One Euler denoising step of the DiT (the per-request hot loop).

    x:       f32[VID_TOKENS, D_LATENT]   current noisy latent video
    t:       f32[1]                      current timestep (0..1000 scale)
    dt:      f32[1]                      Euler step size
    ctx:     f32[SEQ_TEXT, D_MODEL]      text conditioning (stage 1 output)
    img_lat: f32[IMG_TOKENS, D_LATENT]   image conditioning (stage 2 output)
    Returns f32[VID_TOKENS, D_LATENT]: x - dt * eps_hat.
    """
    p = build_params()
    h = x @ p["di.in"] + p["di.pos"]
    cond = jnp.concatenate([ctx, img_lat @ p["di.img_in"]], axis=0)
    cvec = _time_embed(t, p).reshape(DIT_BLOCKS, 6, D_MODEL)
    for b in range(DIT_BLOCKS):
        h = _dit_block(h, cond, cvec[b], p, f"di.{b}.")
    eps = layernorm(h) @ p["di.out"]
    return x - dt[0] * eps


def vae_decode(x: jnp.ndarray) -> jnp.ndarray:
    """Latent video tokens -> pixel frames.

    x f32[VID_TOKENS, D_LATENT] -> video f32[FRAMES, IMG_HW, IMG_HW, IMG_C].
    """
    p = build_params()
    h = jnp.tanh(x @ p["vd.proj1"] + p["vd.b1"])
    h = h + fused_mlp(layernorm(h), p["vd.w1"], p["vd.bb1"], p["vd.w2"],
                      p["vd.bb2"])
    patches = jnp.tanh(h @ p["vd.proj2"] + p["vd.b2"])
    g = IMG_HW // PATCH
    return (
        patches.reshape(FRAMES, g, g, PATCH, PATCH, IMG_C)
        .transpose(0, 1, 3, 2, 4, 5)
        .reshape(FRAMES, IMG_HW, IMG_HW, IMG_C)
    )


# Stage registry used by aot.py and the shape tests: name -> (fn, arg specs).
STAGES = {
    "text_encoder": (
        text_encoder,
        [("tokens", jnp.int32, (SEQ_TEXT,))],
        (SEQ_TEXT, D_MODEL),
    ),
    "vae_encode": (
        vae_encode,
        [("image", jnp.float32, (IMG_HW, IMG_HW, IMG_C))],
        (IMG_TOKENS, D_LATENT),
    ),
    "diffusion_step": (
        diffusion_step,
        [
            ("x", jnp.float32, (VID_TOKENS, D_LATENT)),
            ("t", jnp.float32, (1,)),
            ("dt", jnp.float32, (1,)),
            ("ctx", jnp.float32, (SEQ_TEXT, D_MODEL)),
            ("img_lat", jnp.float32, (IMG_TOKENS, D_LATENT)),
        ],
        (VID_TOKENS, D_LATENT),
    ),
    "vae_decode": (
        vae_decode,
        [("x", jnp.float32, (VID_TOKENS, D_LATENT))],
        (FRAMES, IMG_HW, IMG_HW, IMG_C),
    ),
}
