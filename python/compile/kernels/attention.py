"""Pallas flash-style multi-head attention kernel (L1 hot-spot).

TPU adaptation of the paper's GPU attention hot-spot (DESIGN.md
§Hardware-Adaptation): instead of CUDA warp tiles / shared memory, we tile
for VMEM with BlockSpec — the grid walks (head, q-block) and each program
streams K/V through an online-softmax accumulator, so the [Sq, Sk] logits
matrix never materializes in HBM. All matmuls are shaped for the MXU
systolic array ([bq, D] @ [D, bk] and [bq, bk] @ [bk, D]).

Run with interpret=True everywhere in this repo: the CPU PJRT plugin cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md). Real-TPU
VMEM/MXU estimates live in DESIGN.md §9.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches the MXU lane width; smaller inputs use a
# single block. Q is tiled; K/V are streamed in chunks of _BLOCK_K inside
# the kernel so the logits tile is at most [_BLOCK_Q, _BLOCK_K] in VMEM.
_BLOCK_Q = 128
_BLOCK_K = 128

_NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, sk: int):
    """One (head, q-block) program: online-softmax over K/V chunks."""
    q = q_ref[0]  # [bq, D]
    bq, d = q.shape
    scale = (1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))).astype(q.dtype)

    num_kb = sk // block_k

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], i * block_k, block_k, axis=0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], i * block_k, block_k, axis=0)
        # [bq, bk] logits tile — MXU matmul, fp32 accumulate.
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        m_cur = jnp.max(s, axis=-1)  # [bq]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])  # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)  # rescale old accumulator
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc

    m0 = jnp.full((bq,), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, d), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def _pick_block(n: int, preferred: int) -> int:
    """Largest divisor of `n` that is <= preferred (block must tile evenly)."""
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    block_q: int = _BLOCK_Q,
    block_k: int = _BLOCK_K,
) -> jnp.ndarray:
    """Flash attention over [H, Sq, D] / [H, Sk, D] / [H, Sk, D] -> [H, Sq, D].

    Matches `ref.attention_ref` to fp32 tolerance. Grid = (H, Sq/bq); each
    program holds one Q tile plus one K/V chunk in VMEM at a time.
    """
    h, sq, d = q.shape
    _, sk, _ = k.shape
    bq = _pick_block(sq, block_q)
    bk = _pick_block(sk, block_k)

    kernel = functools.partial(_attention_kernel, block_k=bk, sk=sk)
    return pl.pallas_call(
        kernel,
        grid=(h, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((1, sk, d), lambda ih, iq: (ih, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda ih, iq: (ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda ih, iq: (ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, d), q.dtype),
        interpret=True,
    )(q, k, v)
