"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these references to float32 tolerance.

Nothing here is ever lowered into an artifact — artifacts always go through
the Pallas implementations so the AOT path exercises the real kernels.
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Multi-head scaled dot-product attention.

    Args:
      q: [H, Sq, D] queries.
      k: [H, Sk, D] keys.
      v: [H, Sk, D] values.
    Returns:
      [H, Sq, D] attention output.
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    weights = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", weights, v)


def gelu_ref(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GELU (matches the Pallas kernel's epilogue)."""
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def fused_mlp_ref(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
) -> jnp.ndarray:
    """Reference for the fused MLP: (x @ w1 + b1) -> GELU -> (@ w2 + b2).

    Args:
      x:  [S, D].
      w1: [D, F]; b1: [F].
      w2: [F, D]; b2: [D].
    Returns:
      [S, D].
    """
    h = gelu_ref(x @ w1 + b1)
    return h @ w2 + b2


def modulate_ref(
    x: jnp.ndarray,
    shift: jnp.ndarray,
    scale: jnp.ndarray,
    gate: jnp.ndarray,
    residual: jnp.ndarray,
) -> jnp.ndarray:
    """Reference for adaLN-Zero modulation with gated residual.

    out = residual + gate * (x * (1 + scale) + shift)

    Args:
      x, residual: [S, D].
      shift, scale, gate: [D] (broadcast over rows).
    """
    return residual + gate[None, :] * (x * (1.0 + scale[None, :]) + shift[None, :])


def layernorm_ref(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Parameter-free LayerNorm over the last axis (adaLN supplies affine)."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps)
