"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""

from .attention import attention
from .fused_mlp import fused_mlp
from .layernorm import layernorm
from .modulation import modulate

__all__ = ["attention", "fused_mlp", "layernorm", "modulate"]
