"""Pallas fused transformer MLP kernel (L1).

Fuses matmul -> bias -> GELU -> matmul -> bias into one kernel so the
[S, F] hidden activation never round-trips HBM (the paper's GPU version
keeps it in shared memory; on TPU it lives in VMEM — DESIGN.md
§Hardware-Adaptation). The grid tiles the token dimension; each program
loads one [bs, D] activation tile plus both weight panels and writes one
[bs, D] output tile.

interpret=True only — see attention.py header.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approx GELU; must match ref.gelu_ref exactly."""
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _fused_mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]  # [bs, D]
    # First matmul + bias + GELU: hidden stays in VMEM/registers.
    h = jax.lax.dot_general(
        x, w1_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) + b1_ref[...][None, :]
    h = _gelu(h.astype(x.dtype))
    # Second matmul + bias — fused epilogue, no HBM round-trip for h.
    o = jax.lax.dot_general(
        h, w2_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) + b2_ref[...][None, :]
    o_ref[...] = o.astype(o_ref.dtype)


def _pick_block(n: int, preferred: int) -> int:
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_s",))
def fused_mlp(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    block_s: int = 128,
) -> jnp.ndarray:
    """Fused (x @ w1 + b1) -> GELU -> (@ w2 + b2) over x:[S, D].

    w1: [D, F], b1: [F], w2: [F, D], b2: [D]. Matches ref.fused_mlp_ref.
    """
    s, d = x.shape
    f = w1.shape[1]
    bs = _pick_block(s, block_s)
    return pl.pallas_call(
        _fused_mlp_kernel,
        grid=(s // bs,),
        in_specs=[
            pl.BlockSpec((bs, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bs, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)
