"""Pallas LayerNorm kernel (L1).

Parameter-free LayerNorm over the last axis (the DiT blocks apply affine
via adaLN, so no gamma/beta here). One pass per row tile: mean and
variance computed in-register over the feature axis, normalized output
written back — the feature row never leaves VMEM between the moment
statistics and the normalization (on GPU this is the classic two-pass vs
fused-one-pass distinction; on TPU the row tile lives in VMEM either way,
so the win is avoiding a second HBM read of x).

interpret=True only — see attention.py header.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, o_ref, *, eps: float):
    x = x_ref[...]  # [bs, D]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mean) * jax.lax.rsqrt(var + eps)


def _pick_block(n: int, preferred: int) -> int:
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("eps", "block_s"))
def layernorm(x: jnp.ndarray, eps: float = 1e-6, block_s: int = 256) -> jnp.ndarray:
    """Parameter-free LayerNorm over the last axis of x:[S, D].

    Matches ref.layernorm_ref to fp32 tolerance.
    """
    s, d = x.shape
    bs = _pick_block(s, block_s)
    kernel = functools.partial(_layernorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(s // bs,),
        in_specs=[pl.BlockSpec((bs, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bs, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), x.dtype),
        interpret=True,
    )(x)
