"""Pallas adaLN-Zero modulation kernel (L1).

DiT-style conditioning: out = residual + gate * (x * (1 + scale) + shift),
with shift/scale/gate broadcast over the token dimension. A pure
elementwise/VPU kernel — it exists so the whole DiT block body (attention,
MLP, modulation) stays in Pallas and lowers into the same HLO module.

interpret=True only — see attention.py header.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _modulate_kernel(x_ref, shift_ref, scale_ref, gate_ref, res_ref, o_ref):
    x = x_ref[...]
    shift = shift_ref[...][None, :]
    scale = scale_ref[...][None, :]
    gate = gate_ref[...][None, :]
    o_ref[...] = res_ref[...] + gate * (x * (1.0 + scale) + shift)


def _pick_block(n: int, preferred: int) -> int:
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_s",))
def modulate(
    x: jnp.ndarray,
    shift: jnp.ndarray,
    scale: jnp.ndarray,
    gate: jnp.ndarray,
    residual: jnp.ndarray,
    block_s: int = 256,
) -> jnp.ndarray:
    """out = residual + gate * (x * (1 + scale) + shift); x/residual [S, D]."""
    s, d = x.shape
    bs = _pick_block(s, block_s)
    return pl.pallas_call(
        _modulate_kernel,
        grid=(s // bs,),
        in_specs=[
            pl.BlockSpec((bs, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((bs, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bs, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), x.dtype),
        interpret=True,
    )(x, shift, scale, gate, residual)
