"""Build-time compile path: JAX stage models + Pallas kernels + AOT lowering.

Nothing in this package is imported at serving time — `make artifacts` runs
it once and the rust coordinator only ever sees `artifacts/*.hlo.txt`.
"""
