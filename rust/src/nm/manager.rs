//! The NodeManager registry + load-aware scheduler (§8.2).
//!
//! Assignment flow (paper steps): instances report GPU utilization →
//! NM averages per stage over a recent window → identifies the busiest
//! stage → if above threshold, assigns an additional instance (idle pool
//! first, then the most-underutilized donor stage) → delivers the new
//! role + routing (next hops) → the instance initializes models and
//! updates its RD.
//!
//! For multi-set federation the NM additionally supports **cross-set
//! elasticity**: [`NodeManager::release_idle`] donates an idle-pool
//! instance out of this set (its GPUs return to the shared regional
//! pool) and [`NodeManager::deregister_instance`] removes a node from
//! the registry entirely; the receiving set registers a fresh instance
//! and lets its own §8.2 pass absorb it. See [`crate::federation`].

use crate::config::{AppConfig, SchedMode};
use crate::rdma::RegionId;
use crate::transport::AppId;
use crate::util::{Clock, NodeId, SystemClock};
use crate::workflow::{Assignment, ControlPlane, NextHop, StageRole};
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

/// (app, stage index) — the unit of scaling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StageKey {
    pub app: AppId,
    pub stage: u32,
}

/// What the NM knows about one instance.
#[derive(Debug, Clone)]
pub struct InstanceInfo {
    pub node: NodeId,
    /// Inbox ring region (None for non-workflow roles).
    pub region: Option<RegionId>,
    /// Current stage role (None = idle pool).
    pub role: Option<StageKey>,
    /// Last reported utilization in [0, 1].
    pub util: f64,
    /// Last reported effective batch-formation window, µs (0 = the
    /// instance is not batching). Exported so §8.2 elastic reallocation
    /// and adaptive batch sizing don't fight: a stage holding a wide
    /// window is coalescing on purpose, not starving for capacity.
    pub batch_window_us: u64,
    /// Liveness: when the instance last reported utilization (the
    /// report doubles as a heartbeat — no extra control message). The
    /// failure detector declares the instance dead once this is older
    /// than `nm.instance_timeout_ms`.
    pub last_seen_ns: u64,
}

/// One instance the failure detector declared dead and evicted
/// ([`NodeManager::detect_failures`]): what the recovery sweep needs to
/// repair routing and replay the requests stranded on it.
#[derive(Debug, Clone)]
pub struct FailedInstance {
    pub node: NodeId,
    /// The stage it was serving (None = died in the idle pool).
    pub role: Option<StageKey>,
    /// Its inbox ring — in-flight requests last sent here are stranded.
    pub region: Option<RegionId>,
    /// Last heartbeat (detector clock, ns).
    pub last_seen_ns: u64,
}

/// A rebalancing decision (for logging / the Fig-10 demo).
#[derive(Debug, Clone, PartialEq)]
pub struct RebalanceAction {
    pub node: NodeId,
    pub from: Option<StageKey>,
    pub to: StageKey,
    /// Utilization of the destination stage that triggered the move.
    pub trigger_util: f64,
}

struct State {
    apps: BTreeMap<AppId, AppConfig>,
    instances: BTreeMap<NodeId, InstanceInfo>,
    /// Assignment version per node (bumped on any change affecting it).
    versions: HashMap<NodeId, u64>,
    /// Stage-sharing aliases: (app_b, stage_idx_b) served by the
    /// instances of (app_a, stage_idx_a) (§8.3).
    aliases: HashMap<StageKey, StageKey>,
    next_version: u64,
}

/// The central NodeManager (primary replica). Cheap handle: wrap in Arc.
pub struct NodeManager {
    state: Mutex<State>, // lint: lock-rank(nm_state, 20)
    clock: Arc<dyn Clock>,
    /// Scale-up utilization threshold (paper default 0.85).
    pub util_threshold: f64,
    /// Donor stages must be below this to give up an instance.
    pub donor_max_util: f64,
}

impl NodeManager {
    pub fn new(apps: Vec<AppConfig>, util_threshold: f64) -> Self {
        Self::with_clock(apps, util_threshold, Arc::new(SystemClock))
    }

    /// Construct with an explicit clock (failure-detector tests drive a
    /// [`crate::util::ManualClock`]).
    pub fn with_clock(
        apps: Vec<AppConfig>,
        util_threshold: f64,
        clock: Arc<dyn Clock>,
    ) -> Self {
        Self {
            state: Mutex::new(State {
                apps: apps.into_iter().map(|a| (AppId(a.id), a)).collect(),
                instances: BTreeMap::new(),
                versions: HashMap::new(),
                aliases: HashMap::new(),
                next_version: 1,
            }),
            clock,
            util_threshold,
            donor_max_util: 0.5,
        }
    }

    /// Register a workflow instance (TaskManager init, §4.2). Starts in
    /// the idle pool until assigned.
    pub fn register_instance(&self, node: NodeId, region: RegionId) {
        let now = self.clock.now_ns();
        let mut s = self.state.lock().unwrap();
        s.instances.insert(
            node,
            InstanceInfo {
                node,
                region: Some(region),
                role: None,
                util: 0.0,
                batch_window_us: 0,
                last_seen_ns: now,
            },
        );
        let v = s.next_version;
        s.next_version += 1;
        s.versions.insert(node, v);
    }

    /// Remove `node` from the registry entirely (node death, or cross-set
    /// donation: the instance's GPUs leave this set). Upstream stages get
    /// their routing versions bumped so they stop delivering to it.
    /// Returns the removed instance's info, if it was registered.
    pub fn deregister_instance(&self, node: NodeId) -> Option<InstanceInfo> {
        let mut s = self.state.lock().unwrap();
        let info = s.instances.remove(&node)?;
        s.versions.remove(&node);
        if let Some(role) = info.role {
            Self::bump_upstream_of(&mut s, role);
        }
        Some(info)
    }

    /// Donate one idle-pool instance (§8.2 pool, federation donate path):
    /// deregisters and returns the lowest-numbered idle node, or `None`
    /// when the pool is empty — a set never donates assigned capacity.
    /// Find-and-remove happens under one lock acquisition so a concurrent
    /// rebalance pass cannot assign the node in between (which would
    /// silently donate serving capacity).
    pub fn release_idle(&self) -> Option<NodeId> {
        let mut s = self.state.lock().unwrap();
        let node = s
            .instances
            .values()
            .find(|i| i.role.is_none())
            .map(|i| i.node)?;
        s.instances.remove(&node);
        s.versions.remove(&node);
        // An idle node has no role, so no upstream routing to bump.
        Some(node)
    }

    /// Assign `node` to a stage (or `None` to park it in the idle pool).
    pub fn assign(&self, node: NodeId, role: Option<StageKey>) {
        let mut s = self.state.lock().unwrap();
        let prev = s.instances.get(&node).and_then(|i| i.role);
        if let Some(info) = s.instances.get_mut(&node) {
            info.role = role;
            info.util = 0.0;
            // The old stage's batch window is meaningless under the new
            // role; a non-batching role never reports again, so a stale
            // value would advertise coalescing forever.
            info.batch_window_us = 0;
        }
        // Bump this node and every node whose routing may have changed
        // (stages that feed the affected stages).
        Self::bump(&mut s, node);
        for touched in [prev, role].into_iter().flatten() {
            Self::bump_upstream_of(&mut s, touched);
        }
        drop(s);
    }

    fn bump(s: &mut State, node: NodeId) {
        let v = s.next_version;
        s.next_version += 1;
        s.versions.insert(node, v);
    }

    /// Bump every instance at stages that deliver *into* `key` (their
    /// next-hop sets changed), across aliases too.
    fn bump_upstream_of(s: &mut State, key: StageKey) {
        // Upstream in the same app.
        let upstream: Vec<NodeId> = s
            .instances
            .values()
            .filter(|i| {
                i.role.map_or(false, |r| {
                    let feeds_direct = r.app == key.app && r.stage + 1 == key.stage;
                    // Aliased: some app's stage s maps to r; its next
                    // stage may alias into key as well — conservatively
                    // bump all aliased-app upstreams.
                    let feeds_alias = s.aliases.iter().any(|(b, a)| {
                        *a == StageKey { app: r.app, stage: r.stage }
                            && b.app == key.app
                            && b.stage + 1 == key.stage
                    });
                    feeds_direct || feeds_alias
                })
            })
            .map(|i| i.node)
            .collect();
        for n in upstream {
            Self::bump(s, n);
        }
    }

    /// Declare that `served_as` (app_b stage) is served by the instances
    /// of `served_by` (app_a stage) — cross-workflow sharing (§8.3).
    pub fn share_stage(&self, served_as: StageKey, served_by: StageKey) {
        let mut s = self.state.lock().unwrap();
        s.aliases.insert(served_as, served_by);
        // Routing changed for upstream of the alias and for the serving
        // instances themselves (they gain a route entry).
        let serving: Vec<NodeId> = s
            .instances
            .values()
            .filter(|i| i.role == Some(served_by))
            .map(|i| i.node)
            .collect();
        for n in serving {
            Self::bump(&mut s, n);
        }
        Self::bump_upstream_of(&mut s, served_as);
    }

    /// Resolve aliasing: which physical stage serves `key`.
    fn physical(s: &State, key: StageKey) -> StageKey {
        s.aliases.get(&key).copied().unwrap_or(key)
    }

    /// Inbox regions of the instances serving (app, stage).
    pub fn stage_regions(&self, app: AppId, stage: u32) -> Vec<RegionId> {
        let s = self.state.lock().unwrap();
        let phys = Self::physical(&s, StageKey { app, stage });
        s.instances
            .values()
            .filter(|i| i.role == Some(phys))
            .filter_map(|i| i.region)
            .collect()
    }

    /// Average utilization of a stage's instances.
    pub fn stage_utilization(&self, key: StageKey) -> f64 {
        let s = self.state.lock().unwrap();
        let phys = Self::physical(&s, key);
        let utils: Vec<f64> = s
            .instances
            .values()
            .filter(|i| i.role == Some(phys))
            .map(|i| i.util)
            .collect();
        if utils.is_empty() {
            0.0
        } else {
            utils.iter().sum::<f64>() / utils.len() as f64
        }
    }

    /// Instances currently idle (the paper's Idle Instance Pool).
    pub fn idle_pool(&self) -> Vec<NodeId> {
        let s = self.state.lock().unwrap();
        s.instances
            .values()
            .filter(|i| i.role.is_none())
            .map(|i| i.node)
            .collect()
    }

    /// Instances assigned to a stage.
    pub fn stage_instances(&self, key: StageKey) -> Vec<NodeId> {
        let s = self.state.lock().unwrap();
        let phys = Self::physical(&s, key);
        s.instances
            .values()
            .filter(|i| i.role == Some(phys))
            .map(|i| i.node)
            .collect()
    }

    /// Snapshot of all instances.
    pub fn instances(&self) -> Vec<InstanceInfo> {
        self.state.lock().unwrap().instances.values().cloned().collect()
    }

    /// The §8.2 rebalancing pass. Returns the action taken, if any.
    pub fn rebalance(&self) -> Option<RebalanceAction> {
        let (busiest, trigger_util, donor) = {
            let s = self.state.lock().unwrap();
            // Average utilization per (physical) stage.
            let mut sums: BTreeMap<StageKey, (f64, usize)> = BTreeMap::new();
            for i in s.instances.values() {
                if let Some(r) = i.role {
                    let e = sums.entry(r).or_insert((0.0, 0));
                    e.0 += i.util;
                    e.1 += 1;
                }
            }
            let mut best: Option<(StageKey, f64)> = None;
            for (k, (sum, n)) in &sums {
                let avg = sum / *n as f64;
                if best.map_or(true, |(_, b)| avg > b) {
                    best = Some((*k, avg));
                }
            }
            let (busiest, util) = best?;
            if util < self.util_threshold {
                return None;
            }
            // Donor: idle pool first, else least-utilized stage with >1
            // instances and low enough utilization.
            let idle = s
                .instances
                .values()
                .find(|i| i.role.is_none())
                .map(|i| i.node);
            let donor = idle.or_else(|| {
                let mut candidates: Vec<(StageKey, f64, usize)> = sums
                    .iter()
                    .filter(|(k, (_, n))| **k != busiest && *n > 1)
                    .map(|(k, (sum, n))| (*k, sum / *n as f64, *n))
                    .filter(|(_, avg, _)| *avg < self.donor_max_util)
                    .collect();
                candidates.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
                candidates.first().and_then(|(k, _, _)| {
                    s.instances
                        .values()
                        .filter(|i| i.role == Some(*k))
                        .min_by(|a, b| a.util.partial_cmp(&b.util).unwrap())
                        .map(|i| i.node)
                })
            })?;
            let from = s.instances.get(&donor).and_then(|i| i.role);
            (busiest, util, (donor, from))
        };
        let (donor_node, from) = donor;
        self.assign(donor_node, Some(busiest));
        Some(RebalanceAction {
            node: donor_node,
            from,
            to: busiest,
            trigger_util,
        })
    }

    /// The failure detector: declare dead — and evict — every instance
    /// whose last heartbeat is older than `timeout_ns`. Eviction mirrors
    /// [`NodeManager::deregister_instance`]: the node leaves the
    /// registry and every upstream stage's assignment version is bumped,
    /// so `ResultDeliver`s drop the dead `NextHop` (and prune its
    /// sender) on their next control poll. Returns the evicted
    /// instances for the recovery sweep (repair + replay).
    pub fn detect_failures(&self, timeout_ns: u64) -> Vec<FailedInstance> {
        let now = self.clock.now_ns();
        let mut s = self.state.lock().unwrap();
        let dead: Vec<NodeId> = s
            .instances
            .values()
            .filter(|i| now.saturating_sub(i.last_seen_ns) > timeout_ns)
            .map(|i| i.node)
            .collect();
        let mut failed = Vec::with_capacity(dead.len());
        for node in dead {
            let Some(info) = s.instances.remove(&node) else { continue };
            s.versions.remove(&node);
            if let Some(role) = info.role {
                Self::bump_upstream_of(&mut s, role);
            }
            failed.push(FailedInstance {
                node: info.node,
                role: info.role,
                region: info.region,
                last_seen_ns: info.last_seen_ns,
            });
        }
        failed
    }

    /// Repair a stage that lost an instance: promote a replacement via
    /// the §8.2 machinery — idle pool first, then the least-utilized
    /// donor stage that can spare one (same donor rule as
    /// [`NodeManager::rebalance`], but unconditional: the stage lost
    /// capacity, no utilization threshold gates the refill). Returns the
    /// action taken, if any donor existed.
    pub fn promote_replacement(&self, to: StageKey) -> Option<RebalanceAction> {
        let (donor, from, trigger_util) = {
            let s = self.state.lock().unwrap();
            let idle = s
                .instances
                .values()
                .find(|i| i.role.is_none())
                .map(|i| i.node);
            let donor = idle.or_else(|| {
                let mut sums: BTreeMap<StageKey, (f64, usize)> = BTreeMap::new();
                for i in s.instances.values() {
                    if let Some(r) = i.role {
                        let e = sums.entry(r).or_insert((0.0, 0));
                        e.0 += i.util;
                        e.1 += 1;
                    }
                }
                let mut candidates: Vec<(StageKey, f64)> = sums
                    .iter()
                    .filter(|(k, (_, n))| **k != to && *n > 1)
                    .map(|(k, (sum, n))| (*k, sum / *n as f64))
                    .filter(|(_, avg)| *avg < self.donor_max_util)
                    .collect();
                candidates.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
                candidates.first().and_then(|(k, _)| {
                    s.instances
                        .values()
                        .filter(|i| i.role == Some(*k))
                        .min_by(|a, b| a.util.partial_cmp(&b.util).unwrap())
                        .map(|i| i.node)
                })
            })?;
            let from = s.instances.get(&donor).and_then(|i| i.role);
            // Not utilization-triggered: record the destination's
            // current average (often 0.0 — everyone there just died).
            let utils: Vec<f64> = s
                .instances
                .values()
                .filter(|i| i.role == Some(Self::physical(&s, to)))
                .map(|i| i.util)
                .collect();
            let trigger = if utils.is_empty() {
                0.0
            } else {
                utils.iter().sum::<f64>() / utils.len() as f64
            };
            (donor, from, trigger)
        };
        self.assign(donor, Some(to));
        Some(RebalanceAction { node: donor, from, to, trigger_util })
    }

    /// Build the full per-app route set for an instance serving `phys`.
    fn routes_for(s: &State, phys: StageKey) -> Vec<(AppId, Vec<NextHop>)> {
        // The physical stage serves its own app plus every alias mapping
        // onto it.
        let mut served: Vec<StageKey> = vec![phys];
        served.extend(s.aliases.iter().filter(|(_, v)| **v == phys).map(|(k, _)| *k));
        let mut routes = Vec::new();
        for sk in served {
            let app_cfg = match s.apps.get(&sk.app) {
                Some(a) => a,
                None => continue,
            };
            let next_stage = sk.stage + 1;
            let hops = if (next_stage as usize) >= app_cfg.stages.len() {
                vec![NextHop::Database]
            } else {
                let next_phys = Self::physical(s, StageKey { app: sk.app, stage: next_stage });
                let regions: Vec<NextHop> = s
                    .instances
                    .values()
                    .filter(|i| i.role == Some(next_phys))
                    .filter_map(|i| i.region)
                    .map(NextHop::Instance)
                    .collect();
                regions
            };
            routes.push((sk.app, hops));
        }
        routes
    }

    fn build_assignment(s: &State, node: NodeId) -> Assignment {
        let version = s.versions.get(&node).copied().unwrap_or(0);
        let info = match s.instances.get(&node) {
            Some(i) => i,
            None => return Assignment { version, role: None },
        };
        let role = info.role.map(|key| {
            let app_cfg = &s.apps[&key.app];
            let stage_cfg = &app_cfg.stages[key.stage as usize];
            StageRole {
                app: key.app,
                stage_index: key.stage,
                stage_name: stage_cfg.name.clone(),
                mode: stage_cfg.mode,
                workers: stage_cfg.workers,
                routes: Self::routes_for(s, key),
                // Micro-batching rides the assignment: the stage's
                // (effective) `batch` block becomes a resolved policy.
                // Individual Mode only — CM broadcasts one request to
                // every rank, so there is nothing to coalesce.
                batch: match stage_cfg.mode {
                    SchedMode::Individual => stage_cfg
                        .batch
                        .as_ref()
                        .map(crate::batch::BatchPolicy::from_settings),
                    SchedMode::Collaboration => None,
                },
            }
        });
        Assignment { version, role }
    }

    /// Stage config lookup (proxy admission needs exec times).
    pub fn app_config(&self, app: AppId) -> Option<AppConfig> {
        self.state.lock().unwrap().apps.get(&app).cloned()
    }

    /// Effective scheduling mode of a stage.
    pub fn stage_mode(&self, key: StageKey) -> Option<SchedMode> {
        let s = self.state.lock().unwrap();
        s.apps
            .get(&key.app)
            .and_then(|a| a.stages.get(key.stage as usize))
            .map(|st| st.mode)
    }
}

impl ControlPlane for NodeManager {
    fn get_assignment(&self, node: NodeId) -> Assignment {
        let s = self.state.lock().unwrap();
        Self::build_assignment(&s, node)
    }

    fn report_utilization(&self, node: NodeId, util: f64) {
        let now = self.clock.now_ns();
        let mut s = self.state.lock().unwrap();
        if let Some(i) = s.instances.get_mut(&node) {
            i.util = util;
            // The report doubles as a heartbeat: liveness piggybacks on
            // the §8.2 utilization channel, no extra message.
            i.last_seen_ns = now;
        }
    }

    fn report_batch_window(&self, node: NodeId, window_us: u64) {
        let mut s = self.state.lock().unwrap();
        if let Some(i) = s.instances.get_mut(&node) {
            i.batch_window_us = window_us;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn nm() -> NodeManager {
        NodeManager::new(ClusterConfig::i2v_default().apps, 0.85)
    }

    fn key(stage: u32) -> StageKey {
        StageKey { app: AppId(1), stage }
    }

    #[test]
    fn register_starts_idle() {
        let nm = nm();
        nm.register_instance(NodeId(1), RegionId(10));
        assert_eq!(nm.idle_pool(), vec![NodeId(1)]);
        let a = nm.get_assignment(NodeId(1));
        assert!(a.role.is_none());
    }

    #[test]
    fn assignment_carries_routing() {
        let nm = nm();
        nm.register_instance(NodeId(1), RegionId(10));
        nm.register_instance(NodeId(2), RegionId(20));
        nm.assign(NodeId(1), Some(key(0)));
        nm.assign(NodeId(2), Some(key(1)));
        let a = nm.get_assignment(NodeId(1));
        let role = a.role.unwrap();
        assert_eq!(role.stage_name, "text_encoder");
        let (app, hops) = &role.routes[0];
        assert_eq!(*app, AppId(1));
        assert_eq!(hops, &vec![NextHop::Instance(RegionId(20))]);
    }

    #[test]
    fn final_stage_routes_to_db() {
        let nm = nm();
        nm.register_instance(NodeId(1), RegionId(10));
        nm.assign(NodeId(1), Some(key(3))); // vae_decode (last)
        let role = nm.get_assignment(NodeId(1)).role.unwrap();
        assert_eq!(role.routes[0].1, vec![NextHop::Database]);
    }

    #[test]
    fn version_bumps_on_downstream_change() {
        let nm = nm();
        nm.register_instance(NodeId(1), RegionId(10));
        nm.register_instance(NodeId(2), RegionId(20));
        nm.assign(NodeId(1), Some(key(0)));
        let v1 = nm.get_assignment(NodeId(1)).version;
        // Adding an instance at stage 1 changes node 1's next hops.
        nm.assign(NodeId(2), Some(key(1)));
        let v2 = nm.get_assignment(NodeId(1)).version;
        assert!(v2 > v1, "upstream must observe routing change");
    }

    #[test]
    fn rebalance_prefers_idle_pool() {
        let nm = nm();
        nm.register_instance(NodeId(1), RegionId(10));
        nm.register_instance(NodeId(2), RegionId(20));
        nm.assign(NodeId(1), Some(key(2))); // diffusion
        nm.report_utilization(NodeId(1), 0.95);
        let action = nm.rebalance().unwrap();
        assert_eq!(action.node, NodeId(2));
        assert_eq!(action.from, None); // came from idle pool
        assert_eq!(action.to, key(2));
        assert_eq!(nm.stage_instances(key(2)).len(), 2);
    }

    #[test]
    fn rebalance_steals_from_underutilized_stage() {
        let nm = nm();
        for (n, stage) in [(1u32, 2u32), (2, 3), (3, 3)] {
            nm.register_instance(NodeId(n), RegionId(n as u64 * 10));
            nm.assign(NodeId(n), Some(key(stage)));
        }
        nm.report_utilization(NodeId(1), 0.99); // diffusion hot
        nm.report_utilization(NodeId(2), 0.10); // decode cold
        nm.report_utilization(NodeId(3), 0.15);
        let action = nm.rebalance().unwrap();
        assert_eq!(action.from, Some(key(3)));
        assert_eq!(action.to, key(2));
        // Decode keeps one instance; diffusion gains one.
        assert_eq!(nm.stage_instances(key(3)).len(), 1);
        assert_eq!(nm.stage_instances(key(2)).len(), 2);
    }

    #[test]
    fn rebalance_noop_below_threshold() {
        let nm = nm();
        nm.register_instance(NodeId(1), RegionId(10));
        nm.assign(NodeId(1), Some(key(2)));
        nm.report_utilization(NodeId(1), 0.5);
        assert!(nm.rebalance().is_none());
    }

    #[test]
    fn rebalance_wont_drain_busy_donor() {
        let nm = nm();
        nm.register_instance(NodeId(1), RegionId(10));
        nm.register_instance(NodeId(2), RegionId(20));
        nm.assign(NodeId(1), Some(key(2)));
        nm.assign(NodeId(2), Some(key(3)));
        nm.report_utilization(NodeId(1), 0.95);
        nm.report_utilization(NodeId(2), 0.80); // donor too busy
        assert!(nm.rebalance().is_none());
    }

    #[test]
    fn release_idle_donates_only_unassigned_capacity() {
        let nm = nm();
        nm.register_instance(NodeId(1), RegionId(10));
        nm.register_instance(NodeId(2), RegionId(20));
        nm.assign(NodeId(1), Some(key(0)));
        // Only node 2 is idle; it is donated, then the pool is empty.
        assert_eq!(nm.release_idle(), Some(NodeId(2)));
        assert!(nm.idle_pool().is_empty());
        assert_eq!(nm.release_idle(), None, "assigned capacity is never donated");
        assert_eq!(nm.stage_instances(key(0)), vec![NodeId(1)]);
    }

    #[test]
    fn deregister_removes_routing_and_bumps_upstream() {
        let nm = nm();
        nm.register_instance(NodeId(1), RegionId(10));
        nm.register_instance(NodeId(2), RegionId(20));
        nm.assign(NodeId(1), Some(key(0)));
        nm.assign(NodeId(2), Some(key(1)));
        let v_before = nm.get_assignment(NodeId(1)).version;
        let gone = nm.deregister_instance(NodeId(2)).unwrap();
        assert_eq!(gone.role, Some(key(1)));
        assert!(nm.stage_instances(key(1)).is_empty());
        // Upstream (stage 0) must observe the routing change…
        assert!(nm.get_assignment(NodeId(1)).version > v_before);
        // …and its next-hop list no longer contains the dead region.
        let role = nm.get_assignment(NodeId(1)).role.unwrap();
        assert!(role.routes[0].1.is_empty());
        // Double-deregister is a no-op.
        assert!(nm.deregister_instance(NodeId(2)).is_none());
    }

    #[test]
    fn donate_reclaim_cycle_restores_capacity() {
        // Federation round-trip: set A donates an idle node, later
        // reclaims equivalent capacity by registering a fresh instance.
        let nm = nm();
        nm.register_instance(NodeId(1), RegionId(10));
        let donated = nm.release_idle().unwrap();
        assert_eq!(donated, NodeId(1));
        nm.register_instance(NodeId(7), RegionId(70));
        assert_eq!(nm.idle_pool(), vec![NodeId(7)]);
        // The reclaimed instance is schedulable like any other.
        nm.assign(NodeId(7), Some(key(2)));
        assert_eq!(nm.stage_instances(key(2)), vec![NodeId(7)]);
    }

    #[test]
    fn failure_detector_evicts_stale_instance_and_repair_promotes_idle() {
        use crate::util::ManualClock;
        let clock = ManualClock::new();
        clock.set(1);
        let nm = NodeManager::with_clock(
            ClusterConfig::i2v_default().apps,
            0.85,
            Arc::new(clock.clone()),
        );
        nm.register_instance(NodeId(1), RegionId(10));
        nm.register_instance(NodeId(2), RegionId(20));
        nm.register_instance(NodeId(3), RegionId(30)); // idle pool
        nm.assign(NodeId(1), Some(key(1)));
        nm.assign(NodeId(2), Some(key(0))); // upstream of stage 1
        let v_before = nm.get_assignment(NodeId(2)).version;

        clock.advance(2_000_000_000);
        // Nodes 2 and 3 heartbeat; node 1 has gone silent.
        nm.report_utilization(NodeId(2), 0.1);
        nm.report_utilization(NodeId(3), 0.0);
        let failed = nm.detect_failures(1_000_000_000);
        assert_eq!(failed.len(), 1);
        assert_eq!(failed[0].node, NodeId(1));
        assert_eq!(failed[0].role, Some(key(1)));
        assert_eq!(failed[0].region, Some(RegionId(10)));
        assert!(nm.stage_instances(key(1)).is_empty());
        // Upstream observed the routing change (dead hop dropped).
        assert!(nm.get_assignment(NodeId(2)).version > v_before);
        assert!(nm.get_assignment(NodeId(2)).role.unwrap().routes[0].1.is_empty());

        // Repair: the idle node takes over the orphaned stage and the
        // upstream route points at its ring.
        let act = nm.promote_replacement(key(1)).unwrap();
        assert_eq!((act.node, act.from, act.to), (NodeId(3), None, key(1)));
        assert_eq!(nm.stage_instances(key(1)), vec![NodeId(3)]);
        let role = nm.get_assignment(NodeId(2)).role.unwrap();
        assert_eq!(role.routes[0].1, vec![NextHop::Instance(RegionId(30))]);
    }

    #[test]
    fn flapping_instance_heartbeat_resumes_before_timeout_is_kept() {
        use crate::util::ManualClock;
        let clock = ManualClock::new();
        clock.set(1);
        let nm = NodeManager::with_clock(
            ClusterConfig::i2v_default().apps,
            0.85,
            Arc::new(clock.clone()),
        );
        nm.register_instance(NodeId(1), RegionId(10));
        nm.assign(NodeId(1), Some(key(2)));
        // Silence for *just under* the timeout, then the heartbeat
        // resumes: the detector must not evict.
        clock.advance(999_999_999);
        nm.report_utilization(NodeId(1), 0.4);
        clock.advance(500_000_000);
        assert!(nm.detect_failures(1_000_000_000).is_empty(), "flapper survives");
        assert_eq!(nm.stage_instances(key(2)), vec![NodeId(1)]);
        // True silence past the timeout is detected.
        clock.advance(600_000_000);
        assert_eq!(nm.detect_failures(1_000_000_000).len(), 1);
    }

    #[test]
    fn promote_replacement_steals_from_donor_when_pool_is_empty() {
        let nm = nm();
        for (n, stage) in [(1u32, 3u32), (2, 3)] {
            nm.register_instance(NodeId(n), RegionId(n as u64 * 10));
            nm.assign(NodeId(n), Some(key(stage)));
        }
        nm.report_utilization(NodeId(1), 0.10);
        nm.report_utilization(NodeId(2), 0.20);
        // Stage 2 lost its only instance; no idle pool — the cold stage
        // 3 (two instances) donates its least-utilized one.
        let act = nm.promote_replacement(key(2)).unwrap();
        assert_eq!((act.node, act.from), (NodeId(1), Some(key(3))));
        assert_eq!(nm.stage_instances(key(2)), vec![NodeId(1)]);
        assert_eq!(nm.stage_instances(key(3)), vec![NodeId(2)]);
        // Nothing left to give: a second repair finds no donor.
        assert!(nm.promote_replacement(key(1)).is_none());
    }

    #[test]
    fn assignment_carries_batch_policy_for_im_stages_only() {
        let mut cfg = ClusterConfig::i2v_default();
        cfg.batch = Some(crate::config::BatchSettings::default());
        let nm = NodeManager::new(cfg.apps_with_effective_batch(), 0.85);
        nm.register_instance(NodeId(1), RegionId(10));
        nm.register_instance(NodeId(2), RegionId(20));
        nm.assign(NodeId(1), Some(key(0))); // text_encoder (Individual)
        nm.assign(NodeId(2), Some(key(2))); // diffusion (Collaboration)
        let policy = nm.get_assignment(NodeId(1)).role.unwrap().batch.unwrap();
        assert_eq!(policy.max_batch, 8);
        assert!(policy.bypasses(crate::client::Priority::Interactive));
        assert!(
            nm.get_assignment(NodeId(2)).role.unwrap().batch.is_none(),
            "CM stages never batch"
        );
        // The adaptive window export lands in the registry snapshot.
        nm.report_batch_window(NodeId(1), 1_234);
        let info = nm
            .instances()
            .into_iter()
            .find(|i| i.node == NodeId(1))
            .unwrap();
        assert_eq!(info.batch_window_us, 1_234);
        // Reassignment invalidates the old stage's window: a stale value
        // would advertise "coalescing on purpose" forever.
        nm.assign(NodeId(1), Some(key(3)));
        let info = nm
            .instances()
            .into_iter()
            .find(|i| i.node == NodeId(1))
            .unwrap();
        assert_eq!(info.batch_window_us, 0, "window resets with the role");
    }

    #[test]
    fn sharing_aliases_routing() {
        // App 2 = LTX-style workflow sharing app 1's encoder stages.
        let mut apps = ClusterConfig::i2v_default().apps;
        let mut ltx = apps[0].clone();
        ltx.id = 2;
        ltx.name = "ltx".into();
        apps.push(ltx);
        let nm = NodeManager::new(apps, 0.85);
        nm.register_instance(NodeId(1), RegionId(10)); // text_encoder (shared)
        nm.register_instance(NodeId(2), RegionId(20)); // i2v vae_encode
        nm.register_instance(NodeId(3), RegionId(30)); // ltx vae_encode
        nm.assign(NodeId(1), Some(StageKey { app: AppId(1), stage: 0 }));
        nm.assign(NodeId(2), Some(StageKey { app: AppId(1), stage: 1 }));
        nm.assign(NodeId(3), Some(StageKey { app: AppId(2), stage: 1 }));
        nm.share_stage(
            StageKey { app: AppId(2), stage: 0 },
            StageKey { app: AppId(1), stage: 0 },
        );
        // App 2 requests enter through app 1's instances...
        assert_eq!(nm.stage_regions(AppId(2), 0), vec![RegionId(10)]);
        // ...and the shared instance routes app-2 messages to app 2's own
        // next stage.
        let role = nm.get_assignment(NodeId(1)).role.unwrap();
        let routes: std::collections::HashMap<_, _> = role.routes.into_iter().collect();
        assert_eq!(routes[&AppId(1)], vec![NextHop::Instance(RegionId(20))]);
        assert_eq!(routes[&AppId(2)], vec![NextHop::Instance(RegionId(30))]);
    }
}
