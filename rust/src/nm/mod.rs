//! The NodeManager (§8): centralized orchestrator holding instance roles,
//! network locations and utilization, with
//!
//! - Paxos-based primary election over a replica set (§8.1) —
//!   [`election::NmCluster`];
//! - GPU-utilization-driven instance (re)assignment with an idle pool
//!   (§8.2) — [`NodeManager::rebalance`];
//! - cross-workflow instance sharing (§8.3) —
//!   [`NodeManager::share_stage`].

mod election;
mod manager;

pub use election::{NmCluster, ReplicaStatus};
pub use manager::{InstanceInfo, NodeManager, RebalanceAction, StageKey};
