//! The NodeManager (§8): centralized orchestrator holding instance roles,
//! network locations and utilization, with
//!
//! - Paxos-based primary election over a replica set (§8.1) —
//!   [`NmCluster`];
//! - GPU-utilization-driven instance (re)assignment with an idle pool
//!   (§8.2) — [`NodeManager::rebalance`];
//! - cross-workflow instance sharing (§8.3) —
//!   [`NodeManager::share_stage`];
//! - cross-set donate/reclaim for the federation layer —
//!   [`NodeManager::release_idle`] / [`NodeManager::deregister_instance`]
//!   (see [`crate::federation`]);
//! - worker-instance failure detection on heartbeat-piggybacked
//!   utilization reports, with route repair and replacement promotion —
//!   [`NodeManager::detect_failures`] /
//!   [`NodeManager::promote_replacement`] (the recovery sweep in
//!   [`crate::wset`] drives both).

mod election;
mod manager;

pub use election::{NmCluster, ReplicaStatus};
pub use manager::{
    FailedInstance, InstanceInfo, NodeManager, RebalanceAction, StageKey,
};
