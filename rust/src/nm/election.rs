//! NM primary election (§8.1): primary-backup replication with
//! heartbeats; on heartbeat loss, any replica starts a Paxos election for
//! the next term. "The Paxos protocol guarantees that at most one leader
//! is elected at any given time."

use crate::paxos::{propose, Acceptor, Ballot, ProposeError};
use crate::util::{Clock, NodeId};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Liveness view of one replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaStatus {
    pub node: NodeId,
    pub alive: bool,
    pub is_primary: bool,
}

struct Replica {
    node: NodeId,
    alive: AtomicBool,
    /// Paxos acceptor per term.
    acceptors: Mutex<HashMap<u64, Arc<Mutex<Acceptor>>>>, // lint: lock-rank(election_acceptors, 21)
}

impl Replica {
    fn acceptor(&self, term: u64) -> Arc<Mutex<Acceptor>> {
        self.acceptors
            .lock()
            .unwrap()
            .entry(term)
            .or_default()
            .clone()
    }
}

/// The NM replica set with heartbeat-triggered Paxos elections.
pub struct NmCluster {
    replicas: Vec<Replica>,
    clock: Arc<dyn Clock>,
    heartbeat_timeout_ns: u64,
    state: Mutex<ClusterState>, // lint: lock-rank(election_state, 22)
}

struct ClusterState {
    term: u64,
    primary: Option<NodeId>,
    last_heartbeat_ns: u64,
}

/// Fallible acceptor handle: dead replicas drop messages.
struct LiveHandle<'a> {
    replica: &'a Replica,
    term: u64,
}

impl crate::paxos::AcceptorHandle for LiveHandle<'_> {
    fn prepare(&self, b: Ballot) -> Option<crate::paxos::PrepareReply> {
        self.replica
            .alive
            .load(Ordering::SeqCst)
            .then(|| self.replica.acceptor(self.term).lock().unwrap().prepare(b))
    }

    fn accept(&self, b: Ballot, v: u64) -> Option<Result<(), Ballot>> {
        self.replica
            .alive
            .load(Ordering::SeqCst)
            .then(|| self.replica.acceptor(self.term).lock().unwrap().accept(b, v))
    }
}

impl NmCluster {
    pub fn new(nodes: Vec<NodeId>, clock: Arc<dyn Clock>, heartbeat_timeout_ns: u64) -> Self {
        Self {
            replicas: nodes
                .into_iter()
                .map(|node| Replica {
                    node,
                    alive: AtomicBool::new(true),
                    acceptors: Mutex::new(HashMap::new()),
                })
                .collect(),
            clock,
            heartbeat_timeout_ns,
            state: Mutex::new(ClusterState {
                term: 0,
                primary: None,
                last_heartbeat_ns: 0,
            }),
        }
    }

    /// Kill / revive a replica (fault injection).
    pub fn set_alive(&self, node: NodeId, alive: bool) {
        if let Some(r) = self.replicas.iter().find(|r| r.node == node) {
            r.alive.store(alive, Ordering::SeqCst);
        }
    }

    /// Current primary, if any.
    pub fn primary(&self) -> Option<NodeId> {
        self.state.lock().unwrap().primary
    }

    /// Current term.
    pub fn term(&self) -> u64 {
        self.state.lock().unwrap().term
    }

    /// The primary broadcasts a heartbeat ("periodically broadcasts
    /// heartbeats to maintain its presence and authority").
    pub fn heartbeat(&self, from: NodeId) -> bool {
        let mut s = self.state.lock().unwrap();
        if s.primary != Some(from) {
            return false; // stale leader: ignored
        }
        // Dead primaries can't heartbeat.
        if !self
            .replicas
            .iter()
            .any(|r| r.node == from && r.alive.load(Ordering::SeqCst))
        {
            return false;
        }
        s.last_heartbeat_ns = self.clock.now_ns();
        true
    }

    /// Does any replica consider the primary lost?
    pub fn primary_lost(&self) -> bool {
        let s = self.state.lock().unwrap();
        s.primary.is_none()
            || self.clock.now_ns().saturating_sub(s.last_heartbeat_ns)
                > self.heartbeat_timeout_ns
            || !self
                .replicas
                .iter()
                .any(|r| Some(r.node) == s.primary && r.alive.load(Ordering::SeqCst))
    }

    /// Candidate `node` runs a Paxos election for the next term. Returns
    /// the elected primary (which may be another candidate that won the
    /// same term — safety: never two winners in one term).
    pub fn elect(&self, candidate: NodeId) -> Option<NodeId> {
        let term = {
            let s = self.state.lock().unwrap();
            s.term + 1
        };
        self.elect_term(candidate, term)
    }

    /// Election for a specific term (concurrent candidates in tests call
    /// this with the same term).
    pub fn elect_term(&self, candidate: NodeId, term: u64) -> Option<NodeId> {
        if !self
            .replicas
            .iter()
            .any(|r| r.node == candidate && r.alive.load(Ordering::SeqCst))
        {
            return None; // dead candidates can't campaign
        }
        let handles: Vec<LiveHandle> = self
            .replicas
            .iter()
            .map(|replica| LiveHandle { replica, term })
            .collect();
        let mut ballot = Ballot::new(1, candidate);
        for _ in 0..16 {
            match propose(&handles, ballot, candidate.0 as u64) {
                Ok(winner) => {
                    let winner = NodeId(winner as u32);
                    let mut s = self.state.lock().unwrap();
                    if term > s.term {
                        s.term = term;
                        s.primary = Some(winner);
                        s.last_heartbeat_ns = self.clock.now_ns();
                    }
                    return Some(winner);
                }
                Err(ProposeError::Preempted { suggested }) => {
                    ballot = suggested.next_for(candidate);
                }
                Err(_) => return None, // no quorum reachable
            }
        }
        None
    }

    /// Status of every replica.
    pub fn status(&self) -> Vec<ReplicaStatus> {
        let primary = self.primary();
        self.replicas
            .iter()
            .map(|r| ReplicaStatus {
                node: r.node,
                alive: r.alive.load(Ordering::SeqCst),
                is_primary: Some(r.node) == primary,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ManualClock;

    fn cluster(n: u32) -> (ManualClock, NmCluster) {
        let clock = ManualClock::new();
        let c = NmCluster::new(
            (0..n).map(NodeId).collect(),
            Arc::new(clock.clone()),
            1_000,
        );
        (clock, c)
    }

    #[test]
    fn elects_a_primary() {
        let (_clk, c) = cluster(3);
        assert!(c.primary_lost());
        let p = c.elect(NodeId(1)).unwrap();
        assert_eq!(p, NodeId(1));
        assert_eq!(c.primary(), Some(NodeId(1)));
        assert!(!c.primary_lost());
    }

    #[test]
    fn at_most_one_winner_per_term() {
        let (_clk, c) = cluster(5);
        let term = 1;
        let w1 = c.elect_term(NodeId(1), term).unwrap();
        let w2 = c.elect_term(NodeId(2), term).unwrap();
        // Second candidate must discover the first winner, not override.
        assert_eq!(w1, w2, "Paxos safety: one decided value per term");
    }

    #[test]
    fn heartbeat_timeout_triggers_loss() {
        let (clk, c) = cluster(3);
        c.elect(NodeId(0)).unwrap();
        assert!(c.heartbeat(NodeId(0)));
        assert!(!c.primary_lost());
        clk.advance(2_000);
        assert!(c.primary_lost());
        assert!(c.heartbeat(NodeId(0)));
        assert!(!c.primary_lost());
    }

    #[test]
    fn failover_after_primary_death() {
        let (clk, c) = cluster(3);
        c.elect(NodeId(0)).unwrap();
        c.set_alive(NodeId(0), false);
        assert!(c.primary_lost());
        clk.advance(2_000);
        let p = c.elect(NodeId(2)).unwrap();
        assert_eq!(p, NodeId(2));
        assert_eq!(c.term(), 2);
        // The dead ex-primary's heartbeats are rejected.
        assert!(!c.heartbeat(NodeId(0)));
    }

    #[test]
    fn no_quorum_no_election() {
        let (_clk, c) = cluster(3);
        c.set_alive(NodeId(1), false);
        c.set_alive(NodeId(2), false);
        assert_eq!(c.elect(NodeId(0)), None);
    }

    #[test]
    fn dead_candidate_cannot_campaign() {
        let (_clk, c) = cluster(3);
        c.set_alive(NodeId(1), false);
        assert_eq!(c.elect(NodeId(1)), None);
    }

    #[test]
    fn stale_leader_heartbeat_rejected() {
        let (clk, c) = cluster(3);
        c.elect(NodeId(0)).unwrap();
        clk.advance(2_000);
        c.elect(NodeId(1)).unwrap();
        assert!(!c.heartbeat(NodeId(0)), "old primary must be rejected");
        assert!(c.heartbeat(NodeId(1)));
    }
}
