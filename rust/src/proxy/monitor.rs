//! Request Monitor (§5): sliding-window arrival-rate estimation feeding
//! the fast-reject decision — "whenever the incoming request rate exceeds
//! K/T_X, the proxy rejects additional requests."
//!
//! Extended for the SLO tiers of the unified [`crate::client`] API: a
//! configurable fraction of the admission budget is **reserved for
//! Interactive traffic**, so under overload Standard/Batch submissions
//! hit their (smaller) ceiling first while user-facing requests still
//! find headroom; and every rejection carries a `retry_after` hint — the
//! time until the oldest admission slides out of the window and frees a
//! slot.

use crate::client::Priority;
use crate::util::Clock;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Brownout levels (degraded admission under fabric fault / partition
/// pressure, DESIGN.md §7): Batch is shed first, then Standard too —
/// Interactive is never shed by brownout, only by its own budget.
pub const BROWNOUT_OFF: u8 = 0;
/// Shed Batch admissions.
pub const BROWNOUT_SHED_BATCH: u8 = 1;
/// Shed Batch and Standard admissions.
pub const BROWNOUT_SHED_STANDARD: u8 = 2;

/// Sliding-window admission controller.
pub struct RequestMonitor {
    clock: Arc<dyn Clock>,
    window_ns: u64,
    /// Admission headroom multiplier on capacity (1.0 = exact Theorem-1
    /// rate).
    headroom: f64,
    /// Fraction of the window budget reserved for Interactive traffic
    /// (0.0 disables the reserve).
    interactive_reserve: f64,
    /// Current brownout level ([`BROWNOUT_OFF`] /
    /// [`BROWNOUT_SHED_BATCH`] / [`BROWNOUT_SHED_STANDARD`]); set by the
    /// federation router's breaker scan, read by the proxy's admission
    /// path.
    brownout: AtomicU8,
    admitted: Mutex<VecDeque<u64>>, // lint: lock-rank(monitor, 30)
}

impl RequestMonitor {
    pub fn new(
        clock: Arc<dyn Clock>,
        window_ns: u64,
        headroom: f64,
        interactive_reserve: f64,
    ) -> Self {
        Self {
            clock,
            window_ns,
            headroom,
            interactive_reserve: interactive_reserve.clamp(0.0, 1.0),
            brownout: AtomicU8::new(BROWNOUT_OFF),
            admitted: Mutex::new(VecDeque::new()),
        }
    }

    /// Set the brownout level (clamped to the defined range). Level
    /// changes are advisory and race-free: a submission in flight sees
    /// either the old or the new level, never an inconsistent mix.
    pub fn set_brownout(&self, level: u8) {
        self.brownout
            .store(level.min(BROWNOUT_SHED_STANDARD), Ordering::Relaxed);
    }

    /// Current brownout level.
    pub fn brownout(&self) -> u8 {
        self.brownout.load(Ordering::Relaxed)
    }

    /// Whether the current brownout level sheds this priority class
    /// before the budget is even consulted.
    pub fn sheds(&self, priority: Priority) -> bool {
        match self.brownout.load(Ordering::Relaxed) {
            BROWNOUT_OFF => false,
            BROWNOUT_SHED_BATCH => priority == Priority::Batch,
            _ => priority != Priority::Interactive,
        }
    }

    /// Window budget at the given capacity.
    fn budget(&self, capacity_rps: f64) -> usize {
        let b = (capacity_rps * (self.window_ns as f64 / 1e9) * self.headroom).floor()
            as usize;
        b.max(1)
    }

    /// Decide admission given the current sustainable capacity
    /// (requests/second) and the request's priority class. Records the
    /// arrival if admitted. Interactive may fill the whole budget;
    /// Standard/Batch stop at `budget - reserve`.
    pub fn admit(&self, capacity_rps: f64, priority: Priority) -> bool {
        if capacity_rps <= 0.0 {
            return false;
        }
        let now = self.clock.now_ns();
        let mut q = self.admitted.lock().unwrap();
        let cutoff = now.saturating_sub(self.window_ns);
        while q.front().is_some_and(|&t| t < cutoff) {
            q.pop_front();
        }
        let budget = self.budget(capacity_rps);
        let reserved = (budget as f64 * self.interactive_reserve).floor() as usize;
        let allowed = if priority == Priority::Interactive {
            budget
        } else {
            // Even a full reserve leaves one non-interactive slot so the
            // class is shed, not starved outright.
            budget.saturating_sub(reserved).max(1)
        };
        if q.len() >= allowed {
            return false;
        }
        q.push_back(now);
        true
    }

    /// How long until the oldest in-window admission slides out and
    /// frees a slot — the `retry_after` hint attached to rejections.
    pub fn retry_after_hint(&self) -> Duration {
        let now = self.clock.now_ns();
        let mut q = self.admitted.lock().unwrap();
        let cutoff = now.saturating_sub(self.window_ns);
        while q.front().is_some_and(|&t| t < cutoff) {
            q.pop_front();
        }
        match q.front() {
            Some(&t0) => {
                Duration::from_nanos((t0 + self.window_ns).saturating_sub(now).max(1))
            }
            // Empty window (capacity starvation, not rate): suggest a
            // fraction of the window.
            None => Duration::from_nanos((self.window_ns / 4).max(1)),
        }
    }

    /// Current admitted-rate estimate (requests/second over the window).
    pub fn rate_rps(&self) -> f64 {
        let now = self.clock.now_ns();
        let mut q = self.admitted.lock().unwrap();
        let cutoff = now.saturating_sub(self.window_ns);
        while q.front().is_some_and(|&t| t < cutoff) {
            q.pop_front();
        }
        q.len() as f64 / (self.window_ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ManualClock;

    fn setup(window_ms: u64) -> (ManualClock, RequestMonitor) {
        let c = ManualClock::new();
        c.set(1);
        let m = RequestMonitor::new(Arc::new(c.clone()), window_ms * 1_000_000, 1.0, 0.0);
        (c, m)
    }

    #[test]
    fn admits_up_to_budget() {
        let (clock, m) = setup(1000);
        // Capacity 10 rps, 1 s window => budget 10.
        let mut ok = 0;
        for _ in 0..20 {
            clock.advance(1_000_000);
            if m.admit(10.0, Priority::Standard) {
                ok += 1;
            }
        }
        assert_eq!(ok, 10);
    }

    #[test]
    fn window_slides() {
        let (clock, m) = setup(100);
        // Budget = 1 per 100 ms at 10 rps.
        assert!(m.admit(10.0, Priority::Standard));
        assert!(!m.admit(10.0, Priority::Standard));
        clock.advance(150_000_000); // slide past the window
        assert!(m.admit(10.0, Priority::Standard));
    }

    #[test]
    fn zero_capacity_rejects_all() {
        let (_clock, m) = setup(100);
        assert!(!m.admit(0.0, Priority::Interactive));
    }

    #[test]
    fn rate_estimate() {
        let (clock, m) = setup(1000);
        for _ in 0..5 {
            clock.advance(10_000_000);
            m.admit(1000.0, Priority::Standard);
        }
        assert!((m.rate_rps() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn headroom_scales_budget() {
        let c = ManualClock::new();
        c.set(1);
        let m = RequestMonitor::new(Arc::new(c.clone()), 1_000_000_000, 2.0, 0.0);
        let mut ok = 0;
        for _ in 0..30 {
            c.advance(1_000_000);
            if m.admit(10.0, Priority::Standard) {
                ok += 1;
            }
        }
        assert_eq!(ok, 20, "2x headroom doubles the budget");
    }

    #[test]
    fn interactive_reserve_holds_headroom_under_overload() {
        let c = ManualClock::new();
        c.set(1);
        // Budget 10, reserve floor(10 * 0.2) = 2: Standard stops at 8.
        let m = RequestMonitor::new(Arc::new(c.clone()), 1_000_000_000, 1.0, 0.2);
        let mut standard = 0;
        for _ in 0..20 {
            c.advance(1_000_000);
            if m.admit(10.0, Priority::Standard) {
                standard += 1;
            }
        }
        assert_eq!(standard, 8, "standard is capped below the full budget");
        // Batch is shed at the same ceiling...
        c.advance(1_000_000);
        assert!(!m.admit(10.0, Priority::Batch));
        // ...while interactive still finds the reserved slots.
        let mut interactive = 0;
        for _ in 0..5 {
            c.advance(1_000_000);
            if m.admit(10.0, Priority::Interactive) {
                interactive += 1;
            }
        }
        assert_eq!(interactive, 2, "the reserve admits exactly the held-back slots");
    }

    #[test]
    fn small_budgets_never_starve_standard() {
        let c = ManualClock::new();
        c.set(1);
        // Budget 1 with a full reserve: standard still gets one slot.
        let m = RequestMonitor::new(Arc::new(c.clone()), 1_000_000_000, 1.0, 1.0);
        c.advance(1_000_000);
        assert!(m.admit(1.0, Priority::Standard));
    }

    #[test]
    fn brownout_sheds_batch_then_standard_never_interactive() {
        let (_clock, m) = setup(1000);
        assert!(!m.sheds(Priority::Batch), "off by default");
        m.set_brownout(BROWNOUT_SHED_BATCH);
        assert!(m.sheds(Priority::Batch));
        assert!(!m.sheds(Priority::Standard));
        assert!(!m.sheds(Priority::Interactive));
        m.set_brownout(BROWNOUT_SHED_STANDARD);
        assert!(m.sheds(Priority::Batch));
        assert!(m.sheds(Priority::Standard));
        assert!(!m.sheds(Priority::Interactive), "interactive is never shed");
        m.set_brownout(BROWNOUT_OFF);
        assert!(!m.sheds(Priority::Batch));
        m.set_brownout(200);
        assert_eq!(m.brownout(), BROWNOUT_SHED_STANDARD, "clamped");
    }

    #[test]
    fn retry_after_hint_tracks_oldest_admission() {
        let (clock, m) = setup(1000);
        assert!(m.admit(1.0, Priority::Standard)); // budget 1, admitted at t=1ms
        clock.advance(1_000_000);
        assert!(!m.admit(1.0, Priority::Standard));
        // Oldest admission at ~1 ms into a 1 s window; ~999 ms remain.
        let hint = m.retry_after_hint();
        assert!(hint > Duration::from_millis(900) && hint <= Duration::from_secs(1));
        // After the window slides, the hint collapses to the empty-window
        // default.
        clock.advance(1_100_000_000);
        assert_eq!(m.retry_after_hint(), Duration::from_millis(250));
    }
}
