//! Request Monitor (§5): sliding-window arrival-rate estimation feeding
//! the fast-reject decision — "whenever the incoming request rate exceeds
//! K/T_X, the proxy rejects additional requests."

use crate::util::Clock;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// Sliding-window admission controller.
pub struct RequestMonitor {
    clock: Arc<dyn Clock>,
    window_ns: u64,
    /// Admission headroom multiplier on capacity (1.0 = exact Theorem-1
    /// rate).
    headroom: f64,
    admitted: Mutex<VecDeque<u64>>,
}

impl RequestMonitor {
    pub fn new(clock: Arc<dyn Clock>, window_ns: u64, headroom: f64) -> Self {
        Self {
            clock,
            window_ns,
            headroom,
            admitted: Mutex::new(VecDeque::new()),
        }
    }

    /// Decide admission given the current sustainable capacity
    /// (requests/second). Records the arrival if admitted.
    pub fn admit(&self, capacity_rps: f64) -> bool {
        if capacity_rps <= 0.0 {
            return false;
        }
        let now = self.clock.now_ns();
        let mut q = self.admitted.lock().unwrap();
        let cutoff = now.saturating_sub(self.window_ns);
        while q.front().is_some_and(|&t| t < cutoff) {
            q.pop_front();
        }
        // Budget over the window: capacity × window seconds × headroom.
        let budget =
            (capacity_rps * (self.window_ns as f64 / 1e9) * self.headroom).floor() as usize;
        if q.len() >= budget.max(1) {
            return false;
        }
        q.push_back(now);
        true
    }

    /// Current admitted-rate estimate (requests/second over the window).
    pub fn rate_rps(&self) -> f64 {
        let now = self.clock.now_ns();
        let mut q = self.admitted.lock().unwrap();
        let cutoff = now.saturating_sub(self.window_ns);
        while q.front().is_some_and(|&t| t < cutoff) {
            q.pop_front();
        }
        q.len() as f64 / (self.window_ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ManualClock;

    fn setup(window_ms: u64) -> (ManualClock, RequestMonitor) {
        let c = ManualClock::new();
        c.set(1);
        let m = RequestMonitor::new(Arc::new(c.clone()), window_ms * 1_000_000, 1.0);
        (c, m)
    }

    #[test]
    fn admits_up_to_budget() {
        let (clock, m) = setup(1000);
        // Capacity 10 rps, 1 s window => budget 10.
        let mut ok = 0;
        for _ in 0..20 {
            clock.advance(1_000_000);
            if m.admit(10.0) {
                ok += 1;
            }
        }
        assert_eq!(ok, 10);
    }

    #[test]
    fn window_slides() {
        let (clock, m) = setup(100);
        // Budget = 1 per 100 ms at 10 rps.
        assert!(m.admit(10.0));
        assert!(!m.admit(10.0));
        clock.advance(150_000_000); // slide past the window
        assert!(m.admit(10.0));
    }

    #[test]
    fn zero_capacity_rejects_all() {
        let (_clock, m) = setup(100);
        assert!(!m.admit(0.0));
    }

    #[test]
    fn rate_estimate() {
        let (clock, m) = setup(1000);
        for _ in 0..5 {
            clock.advance(10_000_000);
            m.admit(1000.0);
        }
        assert!((m.rate_rps() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn headroom_scales_budget() {
        let c = ManualClock::new();
        c.set(1);
        let m = RequestMonitor::new(Arc::new(c.clone()), 1_000_000_000, 2.0);
        let mut ok = 0;
        for _ in 0..30 {
            c.advance(1_000_000);
            if m.admit(10.0) {
                ok += 1;
            }
        }
        assert_eq!(ok, 20, "2x headroom doubles the budget");
    }
}
