//! Proxy nodes (§3.2) + the Request Monitor / fast-reject mechanism (§5).
//!
//! Proxies are the CPU-only entry points of a Workflow Set: they assign
//! the request UID, stamp the arrival time, and forward accepted requests
//! to the entrance stage over RDMA. The Request Monitor continuously
//! computes the sustainable admission rate `K/T_X` from NM instance
//! information (Theorem 1) and **immediately rejects** arrivals beyond
//! it, keeping in-system latency flat under overload; rejected clients
//! retry against a different Workflow Set (§3.2).
//!
//! The submission surface is typed for the unified [`crate::client`]
//! gateway API: [`Proxy::submit_request`] takes
//! [`crate::client::SubmitOptions`] (priority / deadline), registers the
//! admitted UID with the set's [`crate::client::RequestTracker`], counts
//! per-priority accepted/rejected metrics, reserves admission headroom
//! for Interactive traffic under overload, and returns a structured
//! [`crate::client::SubmitError::Overloaded`] with a `retry_after` hint
//! instead of a bare rejection.
//!
//! In a federated deployment the proxy additionally *exports* its
//! admission state ([`Proxy::admission_snapshot`]) so the global
//! [`crate::federation::FederationRouter`] can pick the least-loaded
//! admitting set up front and spill overload to siblings before any
//! client-visible rejection happens.

mod monitor;

pub use monitor::{
    RequestMonitor, BROWNOUT_OFF, BROWNOUT_SHED_BATCH, BROWNOUT_SHED_STANDARD,
};

use crate::client::{Priority, RequestTracker, SubmitError, SubmitOptions};
use crate::config::ProxySettings;
use crate::db::DbClient;
use crate::metrics::{Counter, Registry};
use crate::nm::{NodeManager, StageKey};
use crate::rdma::Fabric;
use crate::transport::{AppId, MessageHeader, Payload, RdmaEndpoint, RdmaSender, StageId, WorkflowMessage};
use crate::util::{now_ns, Clock, NodeId, Uid};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Point-in-time export of one proxy's admission state, consumed by the
/// cross-set [`crate::federation::FederationRouter`]: the federation
/// layer routes each request to the set whose proxy reports the most
/// admission headroom, instead of the paper's client-side random retry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionSnapshot {
    /// Sustainable entrance rate `K/T_X` from live NM instance info (§5).
    pub capacity_rps: f64,
    /// Admitted arrival rate over the monitor window.
    pub arrival_rps: f64,
    /// Lifetime accepted count.
    pub accepted: u64,
    /// Lifetime fast-rejected count.
    pub rejected: u64,
}

impl AdmissionSnapshot {
    /// Normalized admission load: admitted rate over capacity. A set with
    /// no entrance capacity is infinitely loaded (routes last, §3.2
    /// fault-isolation boundary).
    pub fn load(&self) -> f64 {
        if self.capacity_rps <= 0.0 {
            f64::INFINITY
        } else {
            self.arrival_rps / self.capacity_rps
        }
    }
}

/// One app's entrance producers — `(region, sender)` pairs plus the
/// round-robin cursor.
type AppSenders = (Vec<(crate::rdma::RegionId, RdmaSender)>, usize);

/// A proxy bound to one Workflow Set.
pub struct Proxy {
    node: NodeId,
    fabric: Fabric,
    nm: Arc<NodeManager>,
    monitor: RequestMonitor,
    db: Arc<DbClient>,
    tracker: Arc<RequestTracker>,
    /// Entrance-stage senders per app (paired with their ring region so
    /// forwards can record the request's location), round-robin.
    senders: Mutex<HashMap<AppId, AppSenders>>, // lint: lock-rank(proxy_senders, 31)
    /// Per-priority lifetime counters (indexed by [`Priority::index`]),
    /// shared into the set's metrics registry as
    /// `accepted.<priority>` / `rejected.<priority>`.
    accepted: [Arc<Counter>; 3],
    rejected: [Arc<Counter>; 3],
    /// Write the stage-0 admission checkpoint (on only when the set's
    /// failure detector is enabled and can replay it).
    checkpointing: bool,
    /// Eager/rendezvous cutover applied to the entrance senders
    /// (`rdma.rendezvous_threshold_bytes`; 0 = eager only). Atomic so
    /// the set can configure it after build without exclusive access.
    rendezvous_threshold: std::sync::atomic::AtomicUsize,
    /// Full-workflow artifact cache (set once after build, like the
    /// rendezvous threshold). A hit at admission publishes the cached
    /// terminal result directly and never enters the pipeline.
    cache: std::sync::OnceLock<Arc<crate::cache::ArtifactCache>>,
    /// Trace hook for admission events (set once after build when the
    /// config has a `trace` block; absent = zero hot-path cost).
    trace: std::sync::OnceLock<crate::trace::TraceHook>,
    /// `requests_shed.<priority>` counters, registered lazily on the
    /// **first** brownout shed — a run that never browns out leaves
    /// `counters_snapshot` without a shed row.
    shed: std::sync::OnceLock<[Arc<Counter>; 3]>,
}

impl Proxy {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        node: NodeId,
        fabric: Fabric,
        nm: Arc<NodeManager>,
        db: Arc<DbClient>,
        clock: Arc<dyn Clock>,
        settings: &ProxySettings,
        tracker: Arc<RequestTracker>,
        metrics: Registry,
        checkpointing: bool,
    ) -> Self {
        let counters = |kind: &str| {
            Priority::ALL
                .map(|p| metrics.counter(&format!("{kind}.{}", p.label())))
        };
        Self {
            node,
            fabric,
            nm,
            monitor: RequestMonitor::new(
                clock,
                settings.monitor_window_ms * 1_000_000,
                settings.headroom,
                settings.interactive_reserve,
            ),
            db,
            tracker,
            senders: Mutex::new(HashMap::new()),
            accepted: counters("accepted"),
            rejected: counters("rejected"),
            checkpointing,
            rendezvous_threshold: std::sync::atomic::AtomicUsize::new(0),
            cache: std::sync::OnceLock::new(),
            trace: std::sync::OnceLock::new(),
            shed: std::sync::OnceLock::new(),
        }
    }

    /// Set the brownout level ([`BROWNOUT_OFF`] / [`BROWNOUT_SHED_BATCH`]
    /// / [`BROWNOUT_SHED_STANDARD`]): degraded admission that sheds
    /// Batch, then Standard, keeping Interactive goodput while the
    /// fabric is partitioned or the federation breakers are open.
    pub fn set_brownout(&self, level: u8) {
        self.monitor.set_brownout(level);
    }

    /// Current brownout level.
    pub fn brownout(&self) -> u8 {
        self.monitor.brownout()
    }

    /// Attach the set's artifact cache (build-time wiring, set once).
    pub fn set_cache(&self, cache: Arc<crate::cache::ArtifactCache>) {
        let _ = self.cache.set(cache);
    }

    /// Attach the set's trace hook (build-time wiring, set once).
    pub fn set_trace(&self, hook: crate::trace::TraceHook) {
        let _ = self.trace.set(hook);
    }

    /// Set the eager/rendezvous cutover on current and future entrance
    /// senders (`rdma.rendezvous_threshold_bytes`).
    pub fn set_rendezvous_threshold(&self, bytes: usize) {
        self.rendezvous_threshold
            .store(bytes, std::sync::atomic::Ordering::SeqCst);
        let mut senders = self.senders.lock().unwrap();
        for (txs, _) in senders.values_mut() {
            for (_, tx) in txs {
                tx.set_rendezvous_threshold(bytes);
            }
        }
    }

    /// Sustainable admission rate for `app`: K workers at the entrance
    /// stage divided by its execution time (§5: "the Request Monitor
    /// continuously calculates K using real-time instance information
    /// obtained from the NM").
    pub fn capacity_rps(&self, app: AppId) -> f64 {
        let Some(cfg) = self.nm.app_config(app) else { return 0.0 };
        let Some(stage0) = cfg.stages.first() else { return 0.0 };
        let instances = self
            .nm
            .stage_instances(StageKey { app, stage: 0 })
            .len();
        let k = instances * stage0.workers.max(1);
        k as f64 / (stage0.exec_ms / 1000.0)
    }

    /// Submit a generation request. Fast-rejects at capacity with a
    /// structured error; the payload rides back with the error so
    /// multi-set gateways can fall through **without cloning** it up
    /// front.
    pub fn submit_request(
        &self,
        app: AppId,
        payload: Payload,
        opts: &SubmitOptions,
    ) -> Result<Uid, (SubmitError, Payload)> {
        // Full-workflow cache check first: a hit terminates the request
        // here — it consumes no admission budget and never enters the
        // pipeline, so it is served even when the set is overloaded.
        let workflow_key = self
            .cache
            .get()
            .filter(|c| c.workflow_enabled())
            .map(|c| (c, c.key_for(app, crate::cache::WORKFLOW_STAGE, &payload)));
        if let Some((cache, key)) = &workflow_key {
            if let Some(bytes) = cache.lookup(crate::cache::WORKFLOW_STAGE, *key) {
                if let Ok(mut msg) = WorkflowMessage::decode(&bytes) {
                    let uid = Uid::fresh(self.node);
                    self.tracker.register_with(uid, opts);
                    // Cached bytes carry the *original* request's header;
                    // re-stamp identity so the stored result belongs to
                    // this admission (payload bytes are shared verbatim).
                    msg.header.uid = uid;
                    msg.header.ts_ns = now_ns() as u64;
                    self.db.put_shared(uid, msg.encode().into());
                    self.accepted[opts.priority.index()].inc();
                    if let Some(h) = self.trace.get() {
                        use crate::trace::{EventKind, Verdict};
                        h.record(uid, None, EventKind::Admitted);
                        h.record(uid, None, EventKind::CacheHit);
                        h.record(uid, None, EventKind::Terminal { verdict: Verdict::Done });
                    }
                    return Ok(uid);
                }
            }
        }
        let capacity = self.capacity_rps(app);
        if capacity <= 0.0 {
            self.rejected[opts.priority.index()].inc();
            return Err((SubmitError::NoCapacity, payload));
        }
        // Brownout shed before the budget is consulted: a degraded set
        // refuses whole priority classes so the survivors' budget goes
        // to Interactive traffic.
        if self.monitor.sheds(opts.priority) {
            self.rejected[opts.priority.index()].inc();
            let shed = self.shed.get_or_init(|| {
                let m = self.tracker.metrics();
                Priority::ALL.map(|p| m.counter(&format!("requests_shed.{}", p.label())))
            });
            shed[opts.priority.index()].inc();
            let retry_after = self.monitor.retry_after_hint();
            return Err((SubmitError::Overloaded { retry_after }, payload));
        }
        if !self.monitor.admit(capacity, opts.priority) {
            self.rejected[opts.priority.index()].inc();
            let retry_after = self.monitor.retry_after_hint();
            return Err((SubmitError::Overloaded { retry_after }, payload));
        }
        let uid = Uid::fresh(self.node);
        // Replay budget for crash recovery comes from the retry policy.
        self.tracker.register_with(uid, opts);
        if let Some(h) = self.trace.get() {
            h.record(uid, None, crate::trace::EventKind::Admitted);
        }
        let msg = WorkflowMessage {
            header: MessageHeader {
                uid,
                ts_ns: now_ns() as u64,
                app,
                stage: StageId(0),
                origin: self.node,
            },
            payload,
        };
        // Admission checkpoint (stage 0, the original message): if the
        // entrance instance dies before completing, the recovery sweep
        // replays the request from here. Written before the forward so a
        // crash immediately after admission is still recoverable; the
        // forward reuses the same encoding (no second pass).
        let encoded: Option<std::sync::Arc<[u8]>> = if self.checkpointing {
            let ck: std::sync::Arc<[u8]> = msg.encode().into();
            self.db.put_checkpoint(uid, 0, ck.clone());
            if let Some(h) = self.trace.get() {
                h.record(uid, Some(0), crate::trace::EventKind::Checkpoint);
            }
            Some(ck)
        } else {
            None
        };
        if !self.forward(app, &msg, encoded.as_deref()) {
            // No entrance instances (or ring full): hand the payload back
            // so the client retries elsewhere rather than losing the
            // request silently.
            self.rejected[opts.priority.index()].inc();
            self.tracker.finish(uid);
            if self.checkpointing {
                self.db.remove_checkpoint(uid);
            }
            return Err((SubmitError::NoCapacity, msg.payload));
        }
        self.accepted[opts.priority.index()].inc();
        // Remember the admitted request's workflow key: when its terminal
        // result is stored, the deliver path fills the workflow tier so
        // the *next* identical submission hits at admission.
        if let Some((cache, key)) = workflow_key {
            cache.note_workflow_key(uid, key);
        }
        Ok(uid)
    }

    /// Forward to the entrance stage, round-robin. `encoded` carries the
    /// admission checkpoint's encoding when checkpointing is on, so the
    /// message is serialized exactly once either way.
    fn forward(&self, app: AppId, msg: &WorkflowMessage, encoded: Option<&[u8]>) -> bool {
        let mut senders = self.senders.lock().unwrap();
        let entry = senders.entry(app).or_insert_with(|| (Vec::new(), 0));
        // Refresh the sender set if the NM's entrance set changed.
        let regions = self.nm.stage_regions(app, 0);
        if regions.is_empty() {
            return false;
        }
        if entry.0.len() != regions.len()
            || entry.0.iter().map(|(r, _)| *r).ne(regions.iter().copied())
        {
            let ring_metrics =
                crate::transport::RingMetrics::from_registry(self.tracker.metrics());
            let threshold = self
                .rendezvous_threshold
                .load(std::sync::atomic::Ordering::SeqCst);
            entry.0 = regions
                .iter()
                .map(|&rid| {
                    let mut tx = RdmaEndpoint::sender_for(&self.fabric, rid);
                    tx.set_metrics(ring_metrics.clone());
                    tx.set_rendezvous_threshold(threshold);
                    (rid, tx)
                })
                .collect();
        }
        let idx = entry.1 % entry.0.len();
        entry.1 = entry.1.wrapping_add(1);
        let (rid, tx) = &mut entry.0[idx];
        let sent = match encoded {
            Some(bytes) => tx.send_encoded(bytes),
            None => tx.send(msg),
        };
        if sent {
            // Record where the request entered the pipeline — the
            // recovery sweep finds stranded requests by location.
            self.tracker.note_location(msg.header.uid, *rid);
            if let Some(h) = self.trace.get() {
                h.record(msg.header.uid, Some(0), crate::trace::EventKind::RingPush);
            }
        }
        sent
    }

    /// Export the fast-reject state for the federation router.
    pub fn admission_snapshot(&self, app: AppId) -> AdmissionSnapshot {
        let (accepted, rejected) = self.counts();
        AdmissionSnapshot {
            capacity_rps: self.capacity_rps(app),
            arrival_rps: self.monitor.rate_rps(),
            accepted,
            rejected,
        }
    }

    /// Lifetime (accepted, rejected) counts summed over priorities.
    pub fn counts(&self) -> (u64, u64) {
        (
            self.accepted.iter().map(|c| c.get()).sum(),
            self.rejected.iter().map(|c| c.get()).sum(),
        )
    }

    /// Lifetime (accepted, rejected) counts for one priority class.
    pub fn counts_for(&self, priority: Priority) -> (u64, u64) {
        (
            self.accepted[priority.index()].get(),
            self.rejected[priority.index()].get(),
        )
    }

    /// Node id.
    pub fn node(&self) -> NodeId {
        self.node
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::db::MemDb;
    use crate::rdma::RegionId;
    use crate::ringbuf::RingConfig;
    use crate::util::ManualClock;
    use std::time::Duration;

    fn settings() -> ProxySettings {
        ProxySettings {
            monitor_window_ms: 1_000,
            headroom: 1.0,
            interactive_reserve: 0.0,
        }
    }

    fn mk_proxy(
        clock: &ManualClock,
        fabric: Fabric,
        nm: Arc<NodeManager>,
        db: Arc<DbClient>,
        s: ProxySettings,
    ) -> Proxy {
        let tracker = Arc::new(RequestTracker::new(
            Arc::new(clock.clone()),
            Registry::new(),
        ));
        Proxy::new(
            NodeId(1),
            fabric,
            nm,
            db,
            Arc::new(clock.clone()),
            &s,
            tracker,
            Registry::new(),
            true,
        )
    }

    fn setup() -> (ManualClock, Arc<NodeManager>, Fabric, Proxy, RdmaEndpoint) {
        let clock = ManualClock::new();
        clock.set(1);
        let fabric = Fabric::ideal();
        let nm = Arc::new(NodeManager::new(ClusterConfig::i2v_default().apps, 0.85));
        // One entrance instance, real ring so forwards land somewhere.
        let ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        nm.register_instance(NodeId(10), ep.region_id());
        nm.assign(NodeId(10), Some(StageKey { app: AppId(1), stage: 0 }));
        let db = Arc::new(DbClient::new(vec![Arc::new(MemDb::new(
            Arc::new(clock.clone()),
            u64::MAX,
        ))]));
        let proxy = mk_proxy(&clock, fabric.clone(), nm.clone(), db, settings());
        (clock, nm, fabric, proxy, ep)
    }

    fn submit(proxy: &Proxy, payload: Payload) -> Result<Uid, (SubmitError, Payload)> {
        proxy.submit_request(AppId(1), payload, &SubmitOptions::default())
    }

    #[test]
    fn capacity_follows_instances() {
        let (_c, nm, fabric, proxy, _ep) = setup();
        // 1 instance × 1 worker / 4 ms = 250 rps.
        assert!((proxy.capacity_rps(AppId(1)) - 250.0).abs() < 1e-9);
        let ep2 = RdmaEndpoint::new(&fabric, RingConfig::default());
        nm.register_instance(NodeId(11), ep2.region_id());
        nm.assign(NodeId(11), Some(StageKey { app: AppId(1), stage: 0 }));
        assert!((proxy.capacity_rps(AppId(1)) - 500.0).abs() < 1e-9);
    }

    #[test]
    fn accepts_below_capacity_rejects_above() {
        let (clock, _nm, _f, proxy, mut ep) = setup();
        // Capacity 250 rps over a 1 s window => 250 admits per window.
        let mut accepted = 0;
        let mut rejected = 0;
        for i in 0..400 {
            clock.advance(1_000_000); // 1 ms apart = 1000 rps offered
            match submit(&proxy, Payload::Bytes(vec![i as u8])) {
                Ok(_) => accepted += 1,
                Err((SubmitError::Overloaded { retry_after }, _)) => {
                    rejected += 1;
                    assert!(retry_after > Duration::ZERO);
                    assert!(retry_after <= Duration::from_secs(1));
                }
                Err((other, _)) => panic!("unexpected error {other:?}"),
            }
        }
        assert!(accepted > 0 && rejected > 0);
        // Admitted rate is bounded by capacity × window fraction.
        assert!(accepted <= 260, "accepted={accepted}");
        // The accepted requests actually landed in the entrance ring.
        let mut delivered = 0;
        while ep.recv().is_some() {
            delivered += 1;
        }
        assert_eq!(delivered, accepted);
    }

    #[test]
    fn no_entrance_instances_is_no_capacity() {
        let clock = ManualClock::new();
        clock.set(1);
        let fabric = Fabric::ideal();
        let nm = Arc::new(NodeManager::new(ClusterConfig::i2v_default().apps, 0.85));
        let db = Arc::new(DbClient::new(vec![]));
        let proxy = mk_proxy(&clock, fabric, nm, db, settings());
        match submit(&proxy, Payload::Bytes(vec![])) {
            Err((SubmitError::NoCapacity, payload)) => {
                // The payload rides back for a no-clone retry elsewhere.
                assert_eq!(payload, Payload::Bytes(vec![]));
            }
            other => panic!("expected NoCapacity, got {other:?}"),
        }
    }

    #[test]
    fn admitted_requests_are_tracked_with_deadline() {
        let (_c, _nm, _f, proxy, _ep) = setup();
        let opts = SubmitOptions::interactive().with_deadline(Duration::from_secs(1));
        let uid = proxy
            .submit_request(AppId(1), Payload::Bytes(vec![1]), &opts)
            .expect("admitted");
        assert_eq!(proxy.tracker.priority_of(uid), Priority::Interactive);
        assert_eq!(proxy.counts_for(Priority::Interactive), (1, 0));
    }

    #[test]
    fn per_priority_counters_split_accept_and_reject() {
        let (clock, _nm, _f, proxy, _ep) = setup();
        // Budget 250; drive far past it with Batch, then verify the
        // split counters.
        for _ in 0..300 {
            clock.advance(1_000_000);
            let _ = proxy.submit_request(
                AppId(1),
                Payload::Bytes(vec![0]),
                &SubmitOptions::batch(),
            );
        }
        let (acc_b, rej_b) = proxy.counts_for(Priority::Batch);
        assert!(acc_b > 0 && rej_b > 0);
        assert_eq!(proxy.counts_for(Priority::Interactive), (0, 0));
        let (acc, rej) = proxy.counts();
        assert_eq!(acc + rej, 300);
    }

    #[test]
    fn admission_snapshot_tracks_load() {
        let (clock, _nm, _f, proxy, _ep) = setup();
        let s0 = proxy.admission_snapshot(AppId(1));
        assert!((s0.capacity_rps - 250.0).abs() < 1e-9);
        assert_eq!(s0.load(), 0.0);
        // Admit a burst; the exported arrival rate and load rise.
        for _ in 0..50 {
            clock.advance(1_000_000);
            let _ = submit(&proxy, Payload::Bytes(vec![0]));
        }
        let s1 = proxy.admission_snapshot(AppId(1));
        assert!(s1.arrival_rps > 0.0);
        assert!(s1.load() > 0.0);
        assert_eq!(s1.accepted + s1.rejected, 50);
    }

    #[test]
    fn snapshot_load_edge_cases() {
        // Zero capacity: infinite load regardless of arrivals (a dead set
        // must route last), including the 0/0 corner.
        let dead_idle = AdmissionSnapshot {
            capacity_rps: 0.0,
            arrival_rps: 0.0,
            accepted: 0,
            rejected: 0,
        };
        assert_eq!(dead_idle.load(), f64::INFINITY);
        let dead_busy = AdmissionSnapshot { arrival_rps: 50.0, ..dead_idle };
        assert_eq!(dead_busy.load(), f64::INFINITY);
        // Negative capacity (never produced, but load() must not divide).
        let negative = AdmissionSnapshot { capacity_rps: -1.0, ..dead_idle };
        assert_eq!(negative.load(), f64::INFINITY);
        // Zero arrivals with real capacity: exactly idle.
        let idle = AdmissionSnapshot {
            capacity_rps: 100.0,
            arrival_rps: 0.0,
            accepted: 0,
            rejected: 0,
        };
        assert_eq!(idle.load(), 0.0);
        // Sanity: load is arrival/capacity elsewhere.
        let half = AdmissionSnapshot { capacity_rps: 100.0, arrival_rps: 50.0, ..idle };
        assert!((half.load() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn workflow_cache_hit_terminates_at_admission() {
        use crate::config::CacheSettings;
        let clock = ManualClock::new();
        clock.set(1);
        let fabric = Fabric::ideal();
        let nm = Arc::new(NodeManager::new(ClusterConfig::i2v_default().apps, 0.85));
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        nm.register_instance(NodeId(10), ep.region_id());
        nm.assign(NodeId(10), Some(StageKey { app: AppId(1), stage: 0 }));
        let mem = Arc::new(MemDb::new(Arc::new(clock.clone()), u64::MAX));
        let db = Arc::new(DbClient::new(vec![mem.clone()]));
        let proxy = mk_proxy(&clock, fabric.clone(), nm.clone(), db, settings());
        let cache = Arc::new(crate::cache::ArtifactCache::new(
            fabric,
            Arc::new(clock.clone()),
            &CacheSettings::default(),
            &Registry::new(),
        ));
        proxy.set_cache(cache.clone());
        // First submission misses and is forwarded into the pipeline.
        clock.advance(1_000_000);
        let uid1 = submit(&proxy, Payload::Bytes(b"prompt".to_vec())).unwrap();
        assert!(ep.recv().is_some(), "miss enters the pipeline");
        // The pipeline finishes: the terminal store fills the workflow
        // tier (ResultDeliver calls this in production).
        let terminal = WorkflowMessage {
            header: MessageHeader {
                uid: uid1,
                ts_ns: 9,
                app: AppId(1),
                stage: StageId(3),
                origin: NodeId(1),
            },
            payload: Payload::Bytes(b"video".to_vec()),
        };
        assert!(cache.complete_workflow(uid1, &terminal.encode().into()));
        // Identical resubmission: served at admission under a fresh uid,
        // byte-identical payload, nothing forwarded.
        clock.advance(1_000_000);
        let uid2 = submit(&proxy, Payload::Bytes(b"prompt".to_vec())).unwrap();
        assert_ne!(uid1, uid2);
        assert!(ep.recv().is_none(), "hit never enters the pipeline");
        let stored = WorkflowMessage::decode(&mem.fetch(uid2).unwrap()).unwrap();
        assert_eq!(stored.header.uid, uid2, "identity re-stamped per admission");
        assert_eq!(stored.payload, Payload::Bytes(b"video".to_vec()));
        // A different prompt still misses.
        clock.advance(1_000_000);
        let uid3 = submit(&proxy, Payload::Bytes(b"other".to_vec())).unwrap();
        assert!(ep.recv().is_some());
        assert!(mem.fetch(uid3).is_none());
    }

    #[test]
    fn brownout_sheds_batch_then_standard_keeps_interactive() {
        let (clock, _nm, _f, proxy, mut ep) = setup();
        // No shed counter exists until the first actual shed.
        assert!(proxy
            .tracker
            .metrics()
            .counters_snapshot()
            .iter()
            .all(|(name, _)| !name.starts_with("requests_shed.")));
        proxy.set_brownout(BROWNOUT_SHED_BATCH);
        clock.advance(1_000_000);
        let r = proxy.submit_request(AppId(1), Payload::Bytes(vec![1]), &SubmitOptions::batch());
        assert!(matches!(r, Err((SubmitError::Overloaded { .. }, _))));
        clock.advance(1_000_000);
        assert!(submit(&proxy, Payload::Bytes(vec![2])).is_ok(), "standard admitted at L1");
        proxy.set_brownout(BROWNOUT_SHED_STANDARD);
        clock.advance(1_000_000);
        let r = submit(&proxy, Payload::Bytes(vec![3]));
        assert!(matches!(r, Err((SubmitError::Overloaded { .. }, _))));
        clock.advance(1_000_000);
        assert!(
            proxy
                .submit_request(
                    AppId(1),
                    Payload::Bytes(vec![4]),
                    &SubmitOptions::interactive()
                )
                .is_ok(),
            "interactive survives full brownout"
        );
        let snap = proxy.tracker.metrics().counters_snapshot();
        let get = |n: &str| snap.iter().find(|(k, _)| k == n).map(|(_, v)| *v);
        assert_eq!(get("requests_shed.batch"), Some(1));
        assert_eq!(get("requests_shed.standard"), Some(1));
        assert_eq!(get("requests_shed.interactive"), Some(0));
        // Heal: batch admits again.
        proxy.set_brownout(BROWNOUT_OFF);
        clock.advance(1_000_000);
        assert!(proxy
            .submit_request(AppId(1), Payload::Bytes(vec![5]), &SubmitOptions::batch())
            .is_ok());
        while ep.recv().is_some() {}
    }

    #[test]
    fn unknown_region_id_type_is_distinct() {
        // Guard: RegionId newtype prevents mixing with NodeId.
        let _r: RegionId = RegionId(5);
    }
}
