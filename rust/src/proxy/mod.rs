//! Proxy nodes (§3.2) + the Request Monitor / fast-reject mechanism (§5).
//!
//! Proxies are the CPU-only entry points of a Workflow Set: they assign
//! the request UID, stamp the arrival time, and forward accepted requests
//! to the entrance stage over RDMA. The Request Monitor continuously
//! computes the sustainable admission rate `K/T_X` from NM instance
//! information (Theorem 1) and **immediately rejects** arrivals beyond
//! it, keeping in-system latency flat under overload; rejected clients
//! retry against a different Workflow Set (§3.2).
//!
//! In a federated deployment the proxy additionally *exports* its
//! admission state ([`Proxy::admission_snapshot`]) so the global
//! [`crate::federation::FederationRouter`] can pick the least-loaded
//! admitting set up front and spill overload to siblings before any
//! client-visible rejection happens.

mod monitor;

pub use monitor::RequestMonitor;

use crate::db::DbClient;
use crate::nm::{NodeManager, StageKey};
use crate::rdma::Fabric;
use crate::transport::{AppId, MessageHeader, Payload, RdmaEndpoint, RdmaSender, StageId, WorkflowMessage};
use crate::util::{now_ns, Clock, NodeId, Uid};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Submission outcome.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Admission {
    /// Accepted; poll the DB with this UID.
    Accepted(Uid),
    /// Fast-rejected: the set is at capacity — try another set.
    Rejected,
}

/// Point-in-time export of one proxy's admission state, consumed by the
/// cross-set [`crate::federation::FederationRouter`]: the federation
/// layer routes each request to the set whose proxy reports the most
/// admission headroom, instead of the paper's client-side random retry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionSnapshot {
    /// Sustainable entrance rate `K/T_X` from live NM instance info (§5).
    pub capacity_rps: f64,
    /// Admitted arrival rate over the monitor window.
    pub arrival_rps: f64,
    /// Lifetime accepted count.
    pub accepted: u64,
    /// Lifetime fast-rejected count.
    pub rejected: u64,
}

impl AdmissionSnapshot {
    /// Normalized admission load: admitted rate over capacity. A set with
    /// no entrance capacity is infinitely loaded (routes last, §3.2
    /// fault-isolation boundary).
    pub fn load(&self) -> f64 {
        if self.capacity_rps <= 0.0 {
            f64::INFINITY
        } else {
            self.arrival_rps / self.capacity_rps
        }
    }
}

/// A proxy bound to one Workflow Set.
pub struct Proxy {
    node: NodeId,
    fabric: Fabric,
    nm: Arc<NodeManager>,
    monitor: RequestMonitor,
    db: Arc<DbClient>,
    /// Entrance-stage senders per app, round-robin.
    senders: Mutex<HashMap<AppId, (Vec<RdmaSender>, usize)>>,
    accepted: std::sync::atomic::AtomicU64,
    rejected: std::sync::atomic::AtomicU64,
}

impl Proxy {
    pub fn new(
        node: NodeId,
        fabric: Fabric,
        nm: Arc<NodeManager>,
        db: Arc<DbClient>,
        clock: Arc<dyn Clock>,
        monitor_window_ns: u64,
        headroom: f64,
    ) -> Self {
        Self {
            node,
            fabric,
            nm,
            monitor: RequestMonitor::new(clock, monitor_window_ns, headroom),
            db,
            senders: Mutex::new(HashMap::new()),
            accepted: Default::default(),
            rejected: Default::default(),
        }
    }

    /// Sustainable admission rate for `app`: K workers at the entrance
    /// stage divided by its execution time (§5: "the Request Monitor
    /// continuously calculates K using real-time instance information
    /// obtained from the NM").
    pub fn capacity_rps(&self, app: AppId) -> f64 {
        let Some(cfg) = self.nm.app_config(app) else { return 0.0 };
        let Some(stage0) = cfg.stages.first() else { return 0.0 };
        let instances = self
            .nm
            .stage_instances(StageKey { app, stage: 0 })
            .len();
        let k = instances * stage0.workers.max(1);
        k as f64 / (stage0.exec_ms / 1000.0)
    }

    /// Submit a generation request. Fast-rejects at capacity.
    pub fn submit(&self, app: AppId, payload: Payload) -> Admission {
        let capacity = self.capacity_rps(app);
        if !self.monitor.admit(capacity) {
            self.rejected.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return Admission::Rejected;
        }
        let uid = Uid::fresh(self.node);
        let msg = WorkflowMessage {
            header: MessageHeader {
                uid,
                ts_ns: now_ns() as u64,
                app,
                stage: StageId(0),
                origin: self.node,
            },
            payload,
        };
        if !self.forward(app, &msg) {
            // No entrance instances (or ring full): treat as rejection so
            // the client retries elsewhere rather than losing the request
            // silently.
            self.rejected.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return Admission::Rejected;
        }
        self.accepted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Admission::Accepted(uid)
    }

    fn forward(&self, app: AppId, msg: &WorkflowMessage) -> bool {
        let mut senders = self.senders.lock().unwrap();
        let entry = senders.entry(app).or_insert_with(|| (Vec::new(), 0));
        // Refresh the sender set if the NM's entrance set changed size.
        let regions = self.nm.stage_regions(app, 0);
        if regions.is_empty() {
            return false;
        }
        if entry.0.len() != regions.len() {
            entry.0 = regions
                .iter()
                .map(|&rid| RdmaEndpoint::sender_for(&self.fabric, rid))
                .collect();
        }
        let idx = entry.1 % entry.0.len();
        entry.1 = entry.1.wrapping_add(1);
        entry.0[idx].send(msg)
    }

    /// Export the fast-reject state for the federation router.
    pub fn admission_snapshot(&self, app: AppId) -> AdmissionSnapshot {
        AdmissionSnapshot {
            capacity_rps: self.capacity_rps(app),
            arrival_rps: self.monitor.rate_rps(),
            accepted: self.accepted.load(std::sync::atomic::Ordering::Relaxed),
            rejected: self.rejected.load(std::sync::atomic::Ordering::Relaxed),
        }
    }

    /// Poll for a result (client retrieval path; purges on success).
    pub fn poll_result(&self, uid: Uid) -> Option<Vec<u8>> {
        self.db.fetch(uid)
    }

    /// (accepted, rejected) counters.
    pub fn counts(&self) -> (u64, u64) {
        (
            self.accepted.load(std::sync::atomic::Ordering::Relaxed),
            self.rejected.load(std::sync::atomic::Ordering::Relaxed),
        )
    }

    /// Node id.
    pub fn node(&self) -> NodeId {
        self.node
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::db::MemDb;
    use crate::rdma::RegionId;
    use crate::ringbuf::RingConfig;
    use crate::util::ManualClock;

    fn setup() -> (ManualClock, Arc<NodeManager>, Fabric, Proxy, RdmaEndpoint) {
        let clock = ManualClock::new();
        clock.set(1);
        let fabric = Fabric::ideal();
        let nm = Arc::new(NodeManager::new(ClusterConfig::i2v_default().apps, 0.85));
        // One entrance instance, real ring so forwards land somewhere.
        let ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        nm.register_instance(NodeId(10), ep.region_id());
        nm.assign(NodeId(10), Some(StageKey { app: AppId(1), stage: 0 }));
        let db = Arc::new(DbClient::new(vec![Arc::new(MemDb::new(
            Arc::new(clock.clone()),
            u64::MAX,
        ))]));
        let proxy = Proxy::new(
            NodeId(1),
            fabric.clone(),
            nm.clone(),
            db,
            Arc::new(clock.clone()),
            1_000_000_000, // 1 s window
            1.0,
        );
        (clock, nm, fabric, proxy, ep)
    }

    #[test]
    fn capacity_follows_instances() {
        let (_c, nm, fabric, proxy, _ep) = setup();
        // 1 instance × 1 worker / 4 ms = 250 rps.
        assert!((proxy.capacity_rps(AppId(1)) - 250.0).abs() < 1e-9);
        let ep2 = RdmaEndpoint::new(&fabric, RingConfig::default());
        nm.register_instance(NodeId(11), ep2.region_id());
        nm.assign(NodeId(11), Some(StageKey { app: AppId(1), stage: 0 }));
        assert!((proxy.capacity_rps(AppId(1)) - 500.0).abs() < 1e-9);
    }

    #[test]
    fn accepts_below_capacity_rejects_above() {
        let (clock, _nm, _f, proxy, mut ep) = setup();
        // Capacity 250 rps over a 1 s window => 250 admits per window.
        let mut accepted = 0;
        let mut rejected = 0;
        for i in 0..400 {
            clock.advance(1_000_000); // 1 ms apart = 1000 rps offered
            match proxy.submit(AppId(1), Payload::Bytes(vec![i as u8])) {
                Admission::Accepted(_) => accepted += 1,
                Admission::Rejected => rejected += 1,
            }
        }
        assert!(accepted > 0 && rejected > 0);
        // Admitted rate is bounded by capacity × window fraction.
        assert!(accepted <= 260, "accepted={accepted}");
        // The accepted requests actually landed in the entrance ring.
        let mut delivered = 0;
        while ep.recv().is_some() {
            delivered += 1;
        }
        assert_eq!(delivered, accepted);
    }

    #[test]
    fn no_entrance_instances_rejects() {
        let clock = ManualClock::new();
        clock.set(1);
        let fabric = Fabric::ideal();
        let nm = Arc::new(NodeManager::new(ClusterConfig::i2v_default().apps, 0.85));
        let db = Arc::new(DbClient::new(vec![]));
        let proxy = Proxy::new(
            NodeId(1),
            fabric,
            nm,
            db,
            Arc::new(clock.clone()),
            1_000_000_000,
            1.0,
        );
        assert_eq!(proxy.submit(AppId(1), Payload::Bytes(vec![])), Admission::Rejected);
    }

    #[test]
    fn admission_snapshot_tracks_load() {
        let (clock, _nm, _f, proxy, _ep) = setup();
        let s0 = proxy.admission_snapshot(AppId(1));
        assert!((s0.capacity_rps - 250.0).abs() < 1e-9);
        assert_eq!(s0.load(), 0.0);
        // Admit a burst; the exported arrival rate and load rise.
        for _ in 0..50 {
            clock.advance(1_000_000);
            let _ = proxy.submit(AppId(1), Payload::Bytes(vec![0]));
        }
        let s1 = proxy.admission_snapshot(AppId(1));
        assert!(s1.arrival_rps > 0.0);
        assert!(s1.load() > 0.0);
        assert_eq!(s1.accepted + s1.rejected, 50);
        // Zero capacity exports an infinite load (routes last).
        let zero = AdmissionSnapshot {
            capacity_rps: 0.0,
            arrival_rps: 0.0,
            accepted: 0,
            rejected: 0,
        };
        assert_eq!(zero.load(), f64::INFINITY);
    }

    #[test]
    fn unknown_region_id_type_is_distinct() {
        // Guard: RegionId newtype prevents mixing with NodeId.
        let _r: RegionId = RegionId(5);
    }
}
