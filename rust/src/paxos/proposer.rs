//! Paxos proposer: drives one ballot through phases 1 and 2 against a set
//! of acceptors reachable through fallible [`AcceptorHandle`]s (message
//! loss = handle returns `None`).

use super::{AcceptedValue, Acceptor, Ballot, PrepareReply};
use std::sync::{Arc, Mutex};

/// Transport-agnostic access to one acceptor. `None` models a lost
/// message or dead acceptor (the proposer just doesn't count it toward
/// the quorum).
pub trait AcceptorHandle {
    fn prepare(&self, b: Ballot) -> Option<PrepareReply>;
    fn accept(&self, b: Ballot, value: u64) -> Option<Result<(), Ballot>>;
}

/// In-process acceptor behind a mutex (the NM replica set).
impl AcceptorHandle for Arc<Mutex<Acceptor>> {
    fn prepare(&self, b: Ballot) -> Option<PrepareReply> {
        Some(self.lock().unwrap().prepare(b))
    }

    fn accept(&self, b: Ballot, value: u64) -> Option<Result<(), Ballot>> {
        Some(self.lock().unwrap().accept(b, value))
    }
}

/// Proposal failure reasons.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProposeError {
    /// Fewer than a quorum of acceptors replied to Prepare.
    NoPrepareQuorum,
    /// Fewer than a quorum accepted.
    NoAcceptQuorum,
    /// A higher ballot exists; retry with `suggested` or higher.
    Preempted { suggested: Ballot },
}

/// Run one ballot. On success returns the **chosen value** — which may be
/// a previously-accepted value the proposer was forced to adopt (this is
/// the heart of Paxos safety, exercised heavily in `tests/paxos.rs`).
pub fn propose<H: AcceptorHandle>(
    acceptors: &[H],
    ballot: Ballot,
    my_value: u64,
) -> Result<u64, ProposeError> {
    let quorum = acceptors.len() / 2 + 1;

    // Phase 1: Prepare.
    let mut promises = 0usize;
    let mut adopted: Option<AcceptedValue> = None;
    let mut highest_nack: Option<Ballot> = None;
    for a in acceptors {
        match a.prepare(ballot) {
            Some(PrepareReply::Promise { accepted, .. }) => {
                promises += 1;
                if let Some(v) = accepted {
                    if adopted.map_or(true, |cur| v.ballot > cur.ballot) {
                        adopted = Some(v);
                    }
                }
            }
            Some(PrepareReply::Nack { promised }) => {
                highest_nack =
                    Some(highest_nack.map_or(promised, |h: Ballot| h.max(promised)));
            }
            None => {} // lost message
        }
    }
    if promises < quorum {
        return match highest_nack {
            Some(suggested) => Err(ProposeError::Preempted { suggested }),
            None => Err(ProposeError::NoPrepareQuorum),
        };
    }

    // Phase 2: Accept (must adopt the highest previously-accepted value).
    let value = adopted.map(|v| v.value).unwrap_or(my_value);
    let mut accepts = 0usize;
    let mut highest_reject: Option<Ballot> = None;
    for a in acceptors {
        match a.accept(ballot, value) {
            Some(Ok(())) => accepts += 1,
            Some(Err(promised)) => {
                highest_reject =
                    Some(highest_reject.map_or(promised, |h: Ballot| h.max(promised)));
            }
            None => {}
        }
    }
    if accepts < quorum {
        return match highest_reject {
            Some(suggested) => Err(ProposeError::Preempted { suggested }),
            None => Err(ProposeError::NoAcceptQuorum),
        };
    }
    Ok(value)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::NodeId;

    fn acceptors(n: usize) -> Vec<Arc<Mutex<Acceptor>>> {
        (0..n).map(|_| Arc::new(Mutex::new(Acceptor::new()))).collect()
    }

    fn b(round: u64, node: u32) -> Ballot {
        Ballot::new(round, NodeId(node))
    }

    #[test]
    fn simple_decide() {
        let acc = acceptors(3);
        assert_eq!(propose(&acc, b(1, 0), 42), Ok(42));
    }

    #[test]
    fn second_proposer_adopts_chosen_value() {
        let acc = acceptors(3);
        assert_eq!(propose(&acc, b(1, 0), 100), Ok(100));
        // A later proposer with its own value MUST decide the same value.
        assert_eq!(propose(&acc, b(2, 1), 200), Ok(100));
    }

    #[test]
    fn stale_ballot_preempted() {
        let acc = acceptors(3);
        propose(&acc, b(5, 0), 1).unwrap();
        match propose(&acc, b(1, 1), 2) {
            Err(ProposeError::Preempted { suggested }) => assert!(suggested >= b(5, 0)),
            other => panic!("expected preemption, got {other:?}"),
        }
    }

    /// Unreliable handle: drops messages to a subset of acceptors.
    struct Flaky {
        inner: Arc<Mutex<Acceptor>>,
        reachable: bool,
    }

    impl AcceptorHandle for Flaky {
        fn prepare(&self, b: Ballot) -> Option<PrepareReply> {
            self.reachable.then(|| self.inner.lock().unwrap().prepare(b))
        }
        fn accept(&self, b: Ballot, v: u64) -> Option<Result<(), Ballot>> {
            self.reachable.then(|| self.inner.lock().unwrap().accept(b, v))
        }
    }

    #[test]
    fn minority_unreachable_still_decides() {
        let acc = acceptors(5);
        let handles: Vec<Flaky> = acc
            .iter()
            .enumerate()
            .map(|(i, a)| Flaky { inner: a.clone(), reachable: i < 3 })
            .collect();
        assert_eq!(propose(&handles, b(1, 0), 7), Ok(7));
    }

    #[test]
    fn majority_unreachable_fails() {
        let acc = acceptors(5);
        let handles: Vec<Flaky> = acc
            .iter()
            .enumerate()
            .map(|(i, a)| Flaky { inner: a.clone(), reachable: i < 2 })
            .collect();
        assert_eq!(propose(&handles, b(1, 0), 7), Err(ProposeError::NoPrepareQuorum));
    }

    #[test]
    fn value_adopted_from_partial_accept() {
        // Proposer A gets value accepted by only one acceptor (then
        // "crashes"); proposer B must still never decide differently once
        // any quorum decided. Here we only check adoption preference.
        let acc = acceptors(3);
        // A: prepare quorum on ballot 1, but accept lands on acc[0] only.
        acc[0].lock().unwrap().prepare(b(1, 0));
        acc[1].lock().unwrap().prepare(b(1, 0));
        acc[2].lock().unwrap().prepare(b(1, 0));
        acc[0].lock().unwrap().accept(b(1, 0), 111).unwrap();
        // B proposes 222 at ballot 2: sees 111 in a promise, adopts it.
        assert_eq!(propose(&acc, b(2, 1), 222), Ok(111));
    }
}
