//! Paxos acceptor: the durable, quorum-forming role.

use super::Ballot;

/// A value accepted under some ballot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AcceptedValue {
    pub ballot: Ballot,
    pub value: u64,
}

/// Phase-1 reply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrepareReply {
    /// Promise not to accept ballots < `promised`; reports any previously
    /// accepted value the proposer must adopt.
    Promise {
        promised: Ballot,
        accepted: Option<AcceptedValue>,
    },
    /// Rejected: a higher ballot was already promised.
    Nack { promised: Ballot },
}

/// Acceptor state for one Paxos instance (one election term).
#[derive(Debug, Default, Clone)]
pub struct Acceptor {
    promised: Option<Ballot>,
    accepted: Option<AcceptedValue>,
}

impl Acceptor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Phase 1 (Prepare): promise iff `b` is the highest ballot seen.
    pub fn prepare(&mut self, b: Ballot) -> PrepareReply {
        match self.promised {
            Some(p) if p > b => PrepareReply::Nack { promised: p },
            _ => {
                self.promised = Some(b);
                PrepareReply::Promise {
                    promised: b,
                    accepted: self.accepted,
                }
            }
        }
    }

    /// Phase 2 (Accept): accept iff no higher promise was made since.
    /// Returns `Ok(())` on acceptance, `Err(promised)` otherwise.
    pub fn accept(&mut self, b: Ballot, value: u64) -> Result<(), Ballot> {
        match self.promised {
            Some(p) if p > b => Err(p),
            _ => {
                self.promised = Some(b);
                self.accepted = Some(AcceptedValue { ballot: b, value });
                Ok(())
            }
        }
    }

    /// Most recently accepted value (learner read).
    pub fn accepted(&self) -> Option<AcceptedValue> {
        self.accepted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::NodeId;

    fn b(round: u64, node: u32) -> Ballot {
        Ballot::new(round, NodeId(node))
    }

    #[test]
    fn promises_highest_ballot() {
        let mut a = Acceptor::new();
        assert!(matches!(a.prepare(b(1, 0)), PrepareReply::Promise { .. }));
        assert!(matches!(a.prepare(b(2, 0)), PrepareReply::Promise { .. }));
        // Lower ballot after a higher promise: nack.
        assert!(matches!(a.prepare(b(1, 0)), PrepareReply::Nack { .. }));
    }

    #[test]
    fn equal_ballot_re_promise_allowed() {
        let mut a = Acceptor::new();
        a.prepare(b(3, 1));
        assert!(matches!(a.prepare(b(3, 1)), PrepareReply::Promise { .. }));
    }

    #[test]
    fn accept_blocked_by_higher_promise() {
        let mut a = Acceptor::new();
        a.prepare(b(5, 0));
        assert_eq!(a.accept(b(4, 0), 42), Err(b(5, 0)));
        assert_eq!(a.accept(b(5, 0), 42), Ok(()));
        assert_eq!(a.accepted().unwrap().value, 42);
    }

    #[test]
    fn promise_reports_accepted_value() {
        let mut a = Acceptor::new();
        a.prepare(b(1, 0));
        a.accept(b(1, 0), 7).unwrap();
        match a.prepare(b(2, 1)) {
            PrepareReply::Promise { accepted: Some(v), .. } => {
                assert_eq!(v.value, 7);
                assert_eq!(v.ballot, b(1, 0));
            }
            other => panic!("expected promise with value, got {other:?}"),
        }
    }

    #[test]
    fn accept_without_prepare_is_allowed_if_unpromised() {
        // An acceptor that never saw a prepare can still accept (classic
        // Paxos permits this; safety comes from quorum intersection).
        let mut a = Acceptor::new();
        assert_eq!(a.accept(b(1, 0), 9), Ok(()));
    }
}
