//! Single-decree Paxos (§8.1) for NodeManager primary election.
//!
//! The paper: "if any instance detects the absence of heartbeats ... it
//! initiates a new leader election using the Paxos consensus algorithm.
//! The Paxos protocol guarantees that at most one leader is elected at
//! any given time." We implement classic single-decree Paxos (Lamport,
//! *Paxos Made Simple*): each election **term** is one Paxos instance
//! whose decided value is the winning candidate's node id. Safety (at
//! most one decided value per term, even with concurrent proposers and
//! message loss) is exercised in `tests/paxos.rs`; the election layer on
//! top lives in [`crate::nm`].

mod acceptor;
mod proposer;

pub use acceptor::{AcceptedValue, Acceptor, PrepareReply};
pub use proposer::{propose, AcceptorHandle, ProposeError};

use crate::util::NodeId;

/// Totally-ordered ballot: (round, proposer id) — proposer id breaks ties
/// so two proposers can never issue the same ballot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Ballot {
    pub round: u64,
    pub node: u32,
}

impl Ballot {
    pub fn new(round: u64, node: NodeId) -> Self {
        Self { round, node: node.0 }
    }

    /// Smallest ballot strictly greater than `self` for `node`.
    pub fn next_for(&self, node: NodeId) -> Ballot {
        Ballot { round: self.round + 1, node: node.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ballot_ordering() {
        let a = Ballot { round: 1, node: 2 };
        let b = Ballot { round: 1, node: 3 };
        let c = Ballot { round: 2, node: 0 };
        assert!(a < b && b < c);
    }

    #[test]
    fn next_for_is_greater() {
        let a = Ballot { round: 5, node: 9 };
        assert!(a.next_for(NodeId(1)) > a);
        assert!(a.next_for(NodeId(1)).round == 6);
    }
}
