//! The OnePiece rule set (L1–L5) over scanned source files.
//!
//! Each rule guards an invariant DESIGN.md states in prose (see the
//! "Invariants & static checks" section there for the rule ↔ anchor
//! table). Rules only fire on non-test lines and honor
//! `// lint: allow(<rule>)` suppression; `suppressed` counts how many
//! hits an allow swallowed so the report can show what the tree relies
//! on.

use super::scanner::{has_word, ident_before, SourceFile};
use std::collections::HashMap;

/// Modules whose failure must escalate through strand/fail_for — a
/// panic in these tears down a worker mid-protocol (the exact class of
/// death the Case 1–8 machinery exists to survive, not to cause).
pub const DATA_PLANE: &[&str] = &["ringbuf", "rdma", "transport", "workflow", "db", "cache"];

/// RDMA verbs whose call sites must keep the e15 verb budget honest.
const ACCOUNTED_VERBS: &[&str] = &[
    "post_read_words",
    "post_write_words",
    "post_cas_pair",
    "post_fetch_add",
];

/// Accounting tokens accepted by L4: the producer/session idiom
/// (`self.verbs += 1`), a `RingMetrics::record` call, or a direct
/// counter increment on the rendezvous/warm-read paths.
const ACCOUNTING_TOKENS: &[&str] = &["verbs", ".record(", "rendezvous_reads", "warm_reads"];

/// Files whose output feeds content-addressed cache keys: any wall
/// clock read here makes "same bytes in, same key out" false.
const DETERMINISM_PATHS: &[&str] = &["cache/key.rs", "transport/message.rs"];

const CLOCK_READS: &[&str] = &["Instant::now", "SystemTime::now", "now_ns("];

/// One rule hit.
#[derive(Debug, Clone, PartialEq)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
    /// Trimmed source line (baseline fingerprints hash this, so a pure
    /// line-number shift does not invalidate a baseline entry).
    pub snippet: String,
}

/// Per-run tallies alongside the violations themselves.
#[derive(Debug, Default)]
pub struct RuleStats {
    pub suppressed: usize,
}

/// Global rank table: name → rank (collected from every file), plus
/// per-file field bindings resolved during the per-file pass.
pub struct RankTable {
    pub by_name: HashMap<String, u32>,
}

pub fn build_rank_table(files: &[SourceFile]) -> RankTable {
    let mut by_name = HashMap::new();
    for f in files {
        for r in &f.ranks {
            by_name.insert(r.name.clone(), r.rank);
        }
    }
    RankTable { by_name }
}

fn is_data_plane(f: &SourceFile) -> bool {
    DATA_PLANE.contains(&f.top_module())
}

fn allowed(f: &SourceFile, line_idx: usize, rule: &str) -> bool {
    f.lines[line_idx].allows.iter().any(|a| a == rule)
}

fn push_or_suppress(
    out: &mut Vec<Violation>,
    stats: &mut RuleStats,
    f: &SourceFile,
    line_idx: usize,
    rule: &'static str,
    message: String,
) {
    if allowed(f, line_idx, rule) {
        stats.suppressed += 1;
        return;
    }
    out.push(Violation {
        rule,
        file: f.path.clone(),
        line: line_idx + 1,
        message,
        snippet: f.lines[line_idx].code.trim().to_string(),
    });
}

/// Statement accumulator: joins code lines until a `;`, `{`, or `}` so
/// multi-line method chains (`let g = self\n.inner\n.lock()`) can be
/// inspected as one unit.
struct StmtBuf {
    buf: String,
}

impl StmtBuf {
    fn new() -> Self {
        Self { buf: String::new() }
    }
    /// Append a line; returns the statement text up to each terminator
    /// encountered (callers inspect `self.buf` *before* reset points).
    fn push_line(&mut self, code: &str) {
        self.buf.push(' ');
        self.buf.push_str(code);
    }
    fn reset(&mut self) {
        self.buf.clear();
    }
}

/// L1: no `unwrap()/expect()/panic!/todo!/unimplemented!` in data-plane
/// modules outside tests. Unwraps *directly on a lock/rwlock/condvar
/// result* are exempt: propagating poisoning by panicking is this
/// crate's accepted idiom (a poisoned mutex means a peer already
/// panicked mid-critical-section — limping on would publish torn
/// state), and L1 exists to catch crash-the-worker paths that should
/// strand/fail_for instead, not to churn 100+ poison propagations.
fn check_l1(f: &SourceFile, out: &mut Vec<Violation>, stats: &mut RuleStats) {
    if !is_data_plane(f) {
        return;
    }
    let patterns: [(&str, &str); 5] = [
        (".unwrap()", "unwrap()"),
        (".expect(", "expect()"),
        ("panic!", "panic!"),
        ("todo!", "todo!"),
        ("unimplemented!", "unimplemented!"),
    ];
    let mut stmt = StmtBuf::new();
    for (i, line) in f.lines.iter().enumerate() {
        if line.in_test {
            stmt.reset();
            continue;
        }
        let code = &line.code;
        for (pat, label) in patterns {
            let mut start = 0;
            while let Some(pos) = code[start..].find(pat) {
                let abs = start + pos;
                start = abs + pat.len();
                // Macro patterns need a word boundary on the left
                // (`panic!` must not fire on `catch_panic!`).
                if !pat.starts_with('.') {
                    let before = code[..abs].chars().next_back();
                    if before.is_some_and(|c| c.is_ascii_alphanumeric() || c == '_') {
                        continue;
                    }
                }
                if pat == ".unwrap()" || pat == ".expect(" {
                    // Poison-class exemption: chain directly follows a
                    // lock()/read()/write()/wait_timeout() call in this
                    // statement.
                    let chain = {
                        let mut s = stmt.buf.clone();
                        s.push_str(&code[..abs]);
                        s
                    };
                    let tail = chain.trim_end();
                    if tail.ends_with(".lock()")
                        || tail.ends_with(".read()")
                        || tail.ends_with(".write()")
                        || poison_wait_chain(tail)
                    {
                        continue;
                    }
                }
                push_or_suppress(
                    out,
                    stats,
                    f,
                    i,
                    "l1",
                    format!(
                        "{label} in data-plane module `{}` (strand/fail_for instead of crashing the worker)",
                        f.top_module()
                    ),
                );
            }
        }
        // Advance the statement buffer.
        stmt.push_line(code);
        if code.contains(';') || code.contains('{') || code.contains('}') {
            stmt.reset();
        }
    }
}

/// `...wait_timeout(g, d)` directly before the unwrap — the returned
/// `LockResult` carries poisoning exactly like `lock()`.
fn poison_wait_chain(tail: &str) -> bool {
    if !tail.ends_with(')') {
        return false;
    }
    // Walk back over one balanced paren group, then require the call
    // name to end with `wait_timeout` / `wait_timeout_while` / `wait`.
    let bytes = tail.as_bytes();
    let mut depth = 0i32;
    let mut i = bytes.len();
    while i > 0 {
        match bytes[i - 1] {
            b')' => depth += 1,
            b'(' => {
                depth -= 1;
                if depth == 0 {
                    i -= 1;
                    break;
                }
            }
            _ => {}
        }
        i -= 1;
    }
    let name_end = i;
    let mut name_start = name_end;
    while name_start > 0 && {
        let c = bytes[name_start - 1] as char;
        c.is_ascii_alphanumeric() || c == '_'
    } {
        name_start -= 1;
    }
    let name = &tail[name_start..name_end];
    name == "wait_timeout" || name == "wait_timeout_while" || name == "wait"
}

/// L2: every Condvar wait in non-test code is bounded
/// (`wait_timeout*`). An unbounded `.wait()` on a dead-leader path
/// wedges followers forever — the exact failure §5's election exists
/// to avoid.
fn check_l2(f: &SourceFile, out: &mut Vec<Violation>, stats: &mut RuleStats) {
    if f.condvars.is_empty() {
        return;
    }
    for (i, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        let mut start = 0;
        while let Some(pos) = code[start..].find(".wait(") {
            let abs = start + pos;
            start = abs + ".wait(".len();
            let Some(recv) = ident_before(code, abs) else {
                continue;
            };
            if f.condvars.contains(&recv) {
                push_or_suppress(
                    out,
                    stats,
                    f,
                    i,
                    "l2",
                    format!(
                        "unbounded Condvar::wait on `{recv}` — use wait_timeout and recheck (a dead notifier wedges this thread forever)"
                    ),
                );
            }
        }
    }
}

/// L3: nested `.lock()` acquisitions of rank-annotated mutexes within
/// one function body must strictly ascend. Guard liveness is
/// approximated: a `let`-bound guard lives to the end of its brace
/// scope (or an explicit `drop(ident)`), an expression temporary to the
/// end of its statement.
fn check_l3(f: &SourceFile, out: &mut Vec<Violation>, stats: &mut RuleStats, table: &RankTable) {
    // Per-file field → (name, rank) bindings from decl-line annotations.
    let mut field_ranks: HashMap<String, (String, u32)> = HashMap::new();
    for r in &f.ranks {
        if let Some(fi) = &r.field {
            field_ranks.insert(fi.clone(), (r.name.clone(), r.rank));
        }
    }
    if field_ranks.is_empty() && table.by_name.is_empty() {
        return;
    }
    struct Guard {
        name: String,
        rank: u32,
        depth: i32,
        binding: Option<String>,
        temp: bool,
    }
    for span in &f.fns {
        let mut guards: Vec<Guard> = Vec::new();
        let mut stmt = StmtBuf::new();
        let mut depth = f.lines[span.start - 1].depth_start;
        for i in (span.start - 1)..span.end.min(f.lines.len()) {
            let line = &f.lines[i];
            if line.in_test {
                continue;
            }
            let code = &line.code;
            // Locate .lock() calls on this line first (the guard list
            // reflects everything acquired before this point).
            let mut start = 0;
            while let Some(pos) = code[start..].find(".lock()") {
                let abs = start + pos;
                start = abs + ".lock()".len();
                let Some(recv) = ident_before(code, abs) else {
                    continue;
                };
                let resolved = field_ranks
                    .get(&recv)
                    .cloned()
                    .or_else(|| table.by_name.get(&recv).map(|&n| (recv.clone(), n)));
                let Some((lname, lrank)) = resolved else {
                    continue;
                };
                for g in &guards {
                    if g.rank >= lrank {
                        push_or_suppress(
                            out,
                            stats,
                            f,
                            i,
                            "l3",
                            format!(
                                "lock-rank inversion in `{}`: acquiring `{lname}` (rank {lrank}) while holding `{}` (rank {}) — ranks must strictly ascend",
                                span.name, g.name, g.rank
                            ),
                        );
                        break;
                    }
                }
                let stmt_so_far = format!("{} {}", stmt.buf, &code[..abs]);
                let bound = has_word(&stmt_so_far, "let");
                let binding = if bound { let_binding(&stmt_so_far) } else { None };
                guards.push(Guard {
                    name: lname,
                    rank: lrank,
                    depth,
                    binding,
                    temp: !bound,
                });
            }
            // drop(ident) releases a named guard early.
            let mut dstart = 0;
            while let Some(pos) = code[dstart..].find("drop(") {
                let abs = dstart + pos;
                dstart = abs + 5;
                let before_ok = abs == 0
                    || !code[..abs]
                        .chars()
                        .next_back()
                        .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.');
                if !before_ok {
                    continue;
                }
                let arg: String = code[abs + 5..]
                    .chars()
                    .take_while(|&c| c.is_ascii_alphanumeric() || c == '_')
                    .collect();
                guards.retain(|g| g.binding.as_deref() != Some(arg.as_str()));
            }
            // Walk braces/semicolons to expire guards.
            for c in code.chars() {
                match c {
                    '{' => depth += 1,
                    '}' => {
                        depth -= 1;
                        guards.retain(|g| g.depth <= depth);
                    }
                    ';' => {
                        guards.retain(|g| !g.temp);
                    }
                    _ => {}
                }
            }
            stmt.push_line(code);
            if code.contains(';') || code.contains('{') || code.contains('}') {
                stmt.reset();
            }
        }
    }
}

/// Best-effort binding ident from `let [mut] name = ...` in a
/// statement prefix (tuple patterns yield the first ident).
fn let_binding(stmt: &str) -> Option<String> {
    let pos = stmt.rfind("let ")?;
    let rest = stmt[pos + 4..].trim_start();
    let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
    let rest = rest.strip_prefix('(').unwrap_or(rest).trim_start();
    let id: String = rest
        .chars()
        .take_while(|&c| c.is_ascii_alphanumeric() || c == '_')
        .collect();
    if id.is_empty() {
        None
    } else {
        Some(id)
    }
}

/// L4: every accounted RDMA verb call site lives in a function that
/// also touches an accounting token, so the e15 verb-budget assertions
/// cannot silently rot when a new call site forgets its increment.
fn check_l4(f: &SourceFile, out: &mut Vec<Violation>, stats: &mut RuleStats) {
    if !is_data_plane(f) {
        return;
    }
    for span in &f.fns {
        // The verb *definitions* (QueuePair methods in rdma/fabric.rs)
        // are not call sites — accounting happens in their callers.
        if ACCOUNTED_VERBS.contains(&span.name.as_str()) {
            continue;
        }
        let mut verb_lines: Vec<(usize, &'static str)> = Vec::new();
        let mut accounted = false;
        for i in (span.start - 1)..span.end.min(f.lines.len()) {
            let line = &f.lines[i];
            if line.in_test {
                continue;
            }
            for v in ACCOUNTED_VERBS {
                if line.code.contains(v) {
                    verb_lines.push((i, v));
                }
            }
            for t in ACCOUNTING_TOKENS {
                let hit = if t.starts_with('.') {
                    line.code.contains(t)
                } else {
                    has_word(&line.code, t)
                };
                if hit {
                    accounted = true;
                }
            }
        }
        if !accounted {
            for (i, v) in verb_lines {
                push_or_suppress(
                    out,
                    stats,
                    f,
                    i,
                    "l4",
                    format!(
                        "`{v}` in `{}` without a RingMetrics/verb-count increment in the same function (e15 verb budget would rot)",
                        span.name
                    ),
                );
            }
        }
    }
}

/// L5: no wall-clock reads in cache-key / payload-encode paths —
/// content-addressed keys must be a pure function of their input.
fn check_l5(f: &SourceFile, out: &mut Vec<Violation>, stats: &mut RuleStats) {
    if !DETERMINISM_PATHS.iter().any(|p| f.path.ends_with(p)) {
        return;
    }
    for (i, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for pat in CLOCK_READS {
            if line.code.contains(pat) {
                push_or_suppress(
                    out,
                    stats,
                    f,
                    i,
                    "l5",
                    format!(
                        "wall-clock read `{}` in a cache-key/encode path breaks content-key determinism",
                        pat.trim_end_matches('(')
                    ),
                );
            }
        }
    }
}

/// Run every rule over one file.
pub fn check_file(
    f: &SourceFile,
    table: &RankTable,
    out: &mut Vec<Violation>,
    stats: &mut RuleStats,
) {
    check_l1(f, out, stats);
    check_l2(f, out, stats);
    check_l3(f, out, stats, table);
    check_l4(f, out, stats);
    check_l5(f, out, stats);
}

/// All rule ids, for the report.
pub const RULES: &[&str] = &["l1", "l2", "l3", "l4", "l5"];
