//! `onepiece lint` — an in-crate static-analysis pass enforcing the
//! concurrency and RDMA-protocol invariants DESIGN.md states in prose.
//!
//! Seven PRs of ring/rendezvous/cache machinery shipped on manual
//! review (the ROADMAP "compile truth" standing debt); this pass
//! mechanizes the invariants that keep Case 1–8 liveness, first-writer
//! -wins terminals, and cache-key determinism honest:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `l1` | no `unwrap/expect/panic!/todo!/unimplemented!` in data-plane modules outside tests (poison-propagating unwraps on lock results are exempt) |
//! | `l2` | every `Condvar` wait in non-test code is a bounded `wait_timeout*` |
//! | `l3` | nested `.lock()` acquisitions of rank-annotated mutexes strictly ascend (`// lint: lock-rank(<name>, N)` on the field decl) |
//! | `l4` | every accounted RDMA verb call site increments a verb counter / `RingMetrics` in the same function |
//! | `l5` | no wall-clock reads in `cache/key.rs` / `transport/message.rs` (content-key determinism) |
//!
//! Suppression: `// lint: allow(<rule>)` on the offending line or the
//! comment line directly above it; or a fingerprint entry in the
//! checked-in `LINT_BASELINE.json`.
//!
//! The runtime complement lives in [`runtime`]: a debug-build
//! lock-order witness that enforces the same rank order dynamically
//! and detects cross-thread deadlock cycles among witnessed locks.

pub mod baseline;
pub mod rules;
pub mod runtime;
pub mod scanner;

pub use rules::{Violation, DATA_PLANE, RULES};

use crate::util::json::Json;
use std::collections::{BTreeMap, HashSet};
use std::io;
use std::path::{Path, PathBuf};

/// Outcome of one lint run.
#[derive(Debug, Default)]
pub struct LintOutcome {
    /// Unsuppressed, un-baselined violations (the failing set).
    pub violations: Vec<Violation>,
    /// Hits swallowed by `// lint: allow(...)`.
    pub suppressed: usize,
    /// Hits swallowed by the baseline file.
    pub baselined: usize,
    /// Source files scanned.
    pub files: usize,
}

impl LintOutcome {
    /// One-line stdout contract (CI greps `lint: 0 violations`).
    pub fn summary(&self) -> String {
        format!(
            "lint: {} violations ({} suppressed, {} baselined) across {} files",
            self.violations.len(),
            self.suppressed,
            self.baselined,
            self.files
        )
    }

    /// Machine-readable report (written to `LINT_REPORT.json`).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert(
            "rules".to_string(),
            Json::Arr(RULES.iter().map(|r| Json::Str(r.to_string())).collect()),
        );
        obj.insert(
            "violations".to_string(),
            Json::Arr(
                self.violations
                    .iter()
                    .map(|v| {
                        let mut o = BTreeMap::new();
                        o.insert("rule".to_string(), Json::Str(v.rule.to_string()));
                        o.insert("file".to_string(), Json::Str(v.file.clone()));
                        o.insert("line".to_string(), Json::Num(v.line as f64));
                        o.insert("message".to_string(), Json::Str(v.message.clone()));
                        o.insert(
                            "fingerprint".to_string(),
                            Json::Str(baseline::fingerprint(v)),
                        );
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        obj.insert("suppressed".to_string(), Json::Num(self.suppressed as f64));
        obj.insert("baselined".to_string(), Json::Num(self.baselined as f64));
        obj.insert("files_scanned".to_string(), Json::Num(self.files as f64));
        Json::Obj(obj)
    }
}

/// Lint in-memory sources: `(path, contents)` pairs where `path` is
/// relative to the source root (e.g. `ringbuf/producer.rs`). This is
/// the seam the fixture tests drive.
pub fn lint_sources(sources: &[(String, String)], baseline_set: &HashSet<String>) -> LintOutcome {
    let files: Vec<scanner::SourceFile> = sources
        .iter()
        .map(|(p, s)| scanner::scan(p, s))
        .collect();
    let table = rules::build_rank_table(&files);
    let mut out = LintOutcome {
        files: files.len(),
        ..Default::default()
    };
    let mut stats = rules::RuleStats::default();
    let mut raw: Vec<Violation> = Vec::new();
    for f in &files {
        rules::check_file(f, &table, &mut raw, &mut stats);
    }
    out.suppressed = stats.suppressed;
    for v in raw {
        if baseline_set.contains(&baseline::fingerprint(&v)) {
            out.baselined += 1;
        } else {
            out.violations.push(v);
        }
    }
    // Deterministic order: path, then line, then rule.
    out.violations
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    out
}

/// Recursively collect `.rs` files under `root` (sorted, deterministic).
fn collect_rs(root: &Path, into: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(root)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, into)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            into.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (typically `rust/src`).
pub fn lint_tree(root: &Path, baseline_set: &HashSet<String>) -> io::Result<LintOutcome> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)?;
    let mut sources = Vec::with_capacity(paths.len());
    for p in &paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        sources.push((rel, std::fs::read_to_string(p)?));
    }
    Ok(lint_sources(&sources, baseline_set))
}

/// Load a baseline file if present; a missing path is an empty set.
pub fn load_baseline(path: &Path) -> Result<HashSet<String>, String> {
    if !path.exists() {
        return Ok(HashSet::new());
    }
    let text = std::fs::read_to_string(path).map_err(|e| format!("baseline {path:?}: {e}"))?;
    baseline::parse(&text)
}
