//! Baseline filtering: a checked-in JSON array of violation
//! fingerprints that are acknowledged and do not fail the build.
//!
//! A fingerprint is `"<rule>|<file>|<trimmed source line>"` — line
//! numbers are deliberately absent so unrelated edits above a
//! baselined site do not invalidate the entry, while any edit to the
//! offending line itself does (the entry then goes stale and the
//! violation resurfaces, forcing a fresh decision).

use super::rules::Violation;
use crate::util::json::Json;
use std::collections::{BTreeMap, HashSet};

/// Stable fingerprint for one violation.
pub fn fingerprint(v: &Violation) -> String {
    format!("{}|{}|{}", v.rule, v.file, v.snippet)
}

/// Parse a baseline file's contents: either a bare JSON array of
/// fingerprint strings, or `{"entries": [...]}`.
pub fn parse(text: &str) -> Result<HashSet<String>, String> {
    let json = Json::parse(text).map_err(|e| format!("baseline: {e}"))?;
    let arr: Vec<Json> = match &json {
        Json::Arr(a) => a.clone(),
        Json::Obj(_) => match json.get("entries") {
            Some(Json::Arr(a)) => a.clone(),
            _ => return Err("baseline: expected array or {\"entries\": [...]}".to_string()),
        },
        _ => return Err("baseline: expected array or {\"entries\": [...]}".to_string()),
    };
    let mut set = HashSet::new();
    for item in arr {
        match item {
            Json::Str(s) => {
                set.insert(s);
            }
            _ => return Err("baseline: entries must be strings".to_string()),
        }
    }
    Ok(set)
}

/// Serialize violations as a baseline file (used by `--write-baseline`
/// to accept the current state wholesale).
pub fn render(violations: &[Violation]) -> String {
    let mut seen = HashSet::new();
    let entries: Vec<Json> = violations
        .iter()
        .map(fingerprint)
        .filter(|f| seen.insert(f.clone()))
        .map(Json::Str)
        .collect();
    let mut obj = BTreeMap::new();
    obj.insert("entries".to_string(), Json::Arr(entries));
    Json::Obj(obj).to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(rule: &'static str, file: &str, snippet: &str) -> Violation {
        Violation {
            rule,
            file: file.to_string(),
            line: 7,
            message: "m".to_string(),
            snippet: snippet.to_string(),
        }
    }

    #[test]
    fn roundtrip() {
        let vs = [v("l1", "a.rs", "x.unwrap();"), v("l2", "b.rs", "cv.wait(g)")];
        let text = render(&vs);
        let set = parse(&text).unwrap();
        assert!(set.contains(&fingerprint(&vs[0])));
        assert!(set.contains(&fingerprint(&vs[1])));
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn bare_array_accepted() {
        let set = parse("[\"l1|a.rs|x.unwrap();\"]").unwrap();
        assert!(set.contains("l1|a.rs|x.unwrap();"));
    }

    #[test]
    fn line_number_independent() {
        let mut a = v("l1", "a.rs", "x.unwrap();");
        a.line = 7;
        let mut b = v("l1", "a.rs", "x.unwrap();");
        b.line = 900;
        assert_eq!(fingerprint(&a), fingerprint(&b));
    }
}
