//! Runtime lock-order witness — the dynamic complement to the static
//! L3 rank check.
//!
//! [`WitnessMutex`] wraps a `std::sync::Mutex` with a name and a rank.
//! Under `debug_assertions` (every `cargo test` build) or the
//! `lockwitness` feature, each acquisition:
//!
//! 1. checks the thread-local held-lock stack: acquiring a rank ≤ any
//!    held rank panics with both lock names (the would-be inversion,
//!    caught in the acquiring thread *before* it can deadlock),
//! 2. registers a wait-for edge in a global graph and runs a DFS: if
//!    following `waiting-thread → lock → owner-thread` edges reaches
//!    the acquiring thread, it panics with the full cycle — the second
//!    line of defense for locks that opted out of ranking
//!    ([`WitnessMutex::new_unranked`]).
//!
//! In release builds without the feature every hook compiles to a
//! no-op and the wrapper is exactly a `Mutex` (one `Option` discriminant
//! in the guard; no global state touched).
//!
//! The ring spin-lock (a remote CAS word, not a process-local mutex —
//! see `ringbuf/producer.rs`) participates through the explicit
//! [`ring_lock_acquired`] / [`ring_lock_released`] hooks, called on
//! CAS success and session drop. It gets no wait-for edges: a spinning
//! producer is never blocked indefinitely (the lease timeout lets it
//! *steal* — the paper's deadlock resolution), so only the rank check
//! applies. Witness release is tied to `ProducerSession` drop rather
//! than the remote unlock verb: a session abandoned mid-protocol
//! (fault injection, lock stolen) leaves the remote word set, but this
//! thread no longer holds anything in the ordering sense.
//!
//! ## Rank order (outer → inner, strictly ascending)
//!
//! The constants below are the canonical order; the static
//! `// lint: lock-rank(...)` annotations on each mutex's field
//! declaration must agree with them.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, LockResult, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Federation router state (outermost: routes into everything).
pub const RANK_FEDERATION: u32 = 10;
/// Workflow-set registry / housekeeper shared state.
pub const RANK_WSET: u32 = 12;
/// Node-manager membership state.
pub const RANK_NM: u32 = 20;
/// Proxy load monitor.
pub const RANK_MONITOR: u32 = 30;
/// Client handle interior (holds while probing tracker/db).
pub const RANK_HANDLE: u32 = 35;
/// Request tracker verdict map.
pub const RANK_TRACKER: u32 = 40;
/// Scheduler priority queue.
pub const RANK_SCHEDULER: u32 = 45;
/// Artifact-cache tier store.
pub const RANK_CACHE_STORE: u32 = 50;
/// Single-flight coalescing maps.
pub const RANK_SINGLEFLIGHT: u32 = 55;
/// MemDb store.
pub const RANK_DB: u32 = 60;
/// Shared result-delivery fan-out.
pub const RANK_DELIVER: u32 = 65;
/// Ring spin-lock (remote CAS word).
pub const RANK_RING_SPIN: u32 = 70;
/// Simulated fabric interior (region table, config).
pub const RANK_FABRIC: u32 = 80;
/// Trace collector (drain-time stitching only; the trace *record* path
/// is lock-free and never acquires this).
pub const RANK_TRACE: u32 = 85;
/// Metrics registry maps (leaf: never held across a call).
pub const RANK_METRICS: u32 = 90;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Id space for ring locks, disjoint from `NEXT_ID`.
fn ring_key(region: u64) -> u64 {
    (1 << 63) | region
}

/// A named, ranked mutex participating in the witness.
pub struct WitnessMutex<T> {
    name: &'static str,
    rank: Option<u32>,
    id: u64,
    inner: Mutex<T>,
}

impl<T> WitnessMutex<T> {
    pub fn new(name: &'static str, rank: u32, value: T) -> Self {
        Self {
            name,
            rank: Some(rank),
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            inner: Mutex::new(value),
        }
    }

    /// A witness that joins the wait-for graph but skips the rank
    /// check. Exists for locks with no natural place in the global
    /// order — and for tests that need a real ABBA cycle to reach the
    /// graph DFS (rank checking fires first otherwise).
    pub fn new_unranked(name: &'static str, value: T) -> Self {
        Self {
            name,
            rank: None,
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            inner: Mutex::new(value),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Lock, with witness checks. Poisoning maps through like
    /// `Mutex::lock` so `.lock().unwrap()` keeps the crate's
    /// poison-propagation idiom.
    pub fn lock(&self) -> LockResult<WitnessGuard<'_, T>> {
        hooks::on_acquiring(self.id, self.name, self.rank);
        let res = self.inner.lock();
        hooks::on_acquired(self.id, self.name, self.rank);
        match res {
            Ok(g) => Ok(WitnessGuard {
                lock: self,
                inner: Some(g),
            }),
            Err(p) => Err(PoisonError::new(WitnessGuard {
                lock: self,
                inner: Some(p.into_inner()),
            })),
        }
    }

    /// Exclusive access without locking (needs `&mut self`).
    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for WitnessMutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WitnessMutex")
            .field("name", &self.name)
            .field("rank", &self.rank)
            .field("inner", &self.inner)
            .finish()
    }
}

impl<T: Default> Default for WitnessMutex<T> {
    fn default() -> Self {
        Self::new_unranked("anonymous", T::default())
    }
}

/// Guard for a [`WitnessMutex`]; releases the witness entry on drop.
/// `inner` is `None` only transiently inside [`WitnessGuard::wait_timeout`].
pub struct WitnessGuard<'a, T> {
    lock: &'a WitnessMutex<T>,
    inner: Option<MutexGuard<'a, T>>,
}

impl<T> std::ops::Deref for WitnessGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard taken by wait_timeout")
    }
}

impl<T> std::ops::DerefMut for WitnessGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard taken by wait_timeout")
    }
}

impl<T> Drop for WitnessGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.is_some() {
            hooks::on_released(self.lock.id);
        }
    }
}

impl<'a, T> WitnessGuard<'a, T> {
    /// Condvar wait with timeout, preserving the witness bookkeeping
    /// across the release/re-acquire the wait performs. Mirrors
    /// `Condvar::wait_timeout` with the receiver flipped (the guard
    /// owns the witness state, so it must orchestrate).
    pub fn wait_timeout(
        mut self,
        cv: &Condvar,
        dur: Duration,
    ) -> LockResult<(WitnessGuard<'a, T>, WaitTimeoutResult)> {
        let lock = self.lock;
        let inner = self.inner.take().expect("guard taken by wait_timeout");
        hooks::on_released(lock.id);
        drop(self); // inner is None: no double release
        let res = cv.wait_timeout(inner, dur);
        // Re-acquisition is an acquisition for ordering purposes: if
        // this thread picked up other locks before the wait (it should
        // not have — waiting while holding is its own smell), the rank
        // check fires here exactly as for a fresh `lock()`.
        hooks::on_acquiring(lock.id, lock.name, lock.rank);
        hooks::on_acquired(lock.id, lock.name, lock.rank);
        match res {
            Ok((g, t)) => Ok((
                WitnessGuard {
                    lock,
                    inner: Some(g),
                },
                t,
            )),
            Err(p) => {
                let (g, t) = p.into_inner();
                Err(PoisonError::new((
                    WitnessGuard {
                        lock,
                        inner: Some(g),
                    },
                    t,
                )))
            }
        }
    }
}

/// Ring spin-lock acquired (CAS succeeded) — rank check + ownership.
///
/// Uses a relaxed acquire: the stepped-session protocol legitimately
/// overlaps two sessions of the *same ring* on one thread (a steal of
/// an expired lease while the losing session object is still alive —
/// Cases 4–8 of the liveness argument), so same-ring re-entry and
/// ring-vs-ring rank ties are allowed. Holding any *higher-ranked*
/// witnessed mutex while entering the ring still panics.
pub fn ring_lock_acquired(region: u64) {
    hooks::on_ring_acquired(ring_key(region), RANK_RING_SPIN);
}

/// Ring session over (unlocked, stolen, or abandoned) — this thread no
/// longer holds the ring in the ordering sense.
pub fn ring_lock_released(region: u64) {
    hooks::on_released(ring_key(region));
}

/// Number of witnessed locks the current thread holds (test hook).
pub fn held_count() -> usize {
    hooks::held_count()
}

#[cfg(any(debug_assertions, feature = "lockwitness"))]
mod hooks {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    use std::thread::ThreadId;

    thread_local! {
        /// (lock id, name, rank) stack for the current thread.
        static HELD: RefCell<Vec<(u64, &'static str, Option<u32>)>> =
            const { RefCell::new(Vec::new()) };
    }

    #[derive(Default)]
    struct Graph {
        /// lock id → (owner thread, lock name)
        owners: HashMap<u64, (ThreadId, &'static str)>,
        /// thread → (lock id it is blocked acquiring, lock name)
        waiting: HashMap<ThreadId, (u64, &'static str)>,
    }

    fn graph() -> &'static Mutex<Graph> {
        static G: OnceLock<Mutex<Graph>> = OnceLock::new();
        G.get_or_init(|| Mutex::new(Graph::default()))
    }

    /// The graph mutex may be poisoned by a witness panic in another
    /// thread; the bookkeeping stays sound (every mutation is a single
    /// map op), so keep going rather than cascade.
    fn graph_lock() -> std::sync::MutexGuard<'static, Graph> {
        graph().lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn on_acquiring(id: u64, name: &'static str, rank: Option<u32>) {
        // 1. Thread-local checks: reentrancy and rank order.
        HELD.with(|h| {
            let held = h.borrow();
            for (hid, hname, hrank) in held.iter() {
                if *hid == id {
                    panic!(
                        "lock-order witness: thread re-acquiring `{name}` it already holds \
                         (held stack: {})",
                        render_stack(&held)
                    );
                }
                if let (Some(hr), Some(r)) = (hrank, rank) {
                    if *hr >= r {
                        panic!(
                            "lock-order witness: acquiring `{name}` (rank {r}) while holding \
                             `{hname}` (rank {hr}) — ranks must strictly ascend \
                             (held stack: {})",
                            render_stack(&held)
                        );
                    }
                }
            }
        });
        // 2. Wait-for edge + deadlock DFS.
        let me = std::thread::current().id();
        let mut g = graph_lock();
        g.waiting.insert(me, (id, name));
        // Follow waiting(thread) → lock → owner(lock) → thread ...
        let mut cycle = vec![format!("{me:?} waits for `{name}`")];
        let mut cur_lock = id;
        let mut hops = 0;
        loop {
            let Some(&(owner, owner_lock_name)) = g.owners.get(&cur_lock) else {
                break; // unowned: acquisition will succeed
            };
            if owner == me {
                g.waiting.remove(&me);
                panic!(
                    "lock-order witness: deadlock cycle detected: {}",
                    cycle.join("; ") + &format!("; `{owner_lock_name}` is held by {me:?}")
                );
            }
            let Some(&(next_lock, next_name)) = g.waiting.get(&owner) else {
                break; // owner is running: it will release eventually
            };
            cycle.push(format!(
                "`{owner_lock_name}` is held by {owner:?} which waits for `{next_name}`"
            ));
            cur_lock = next_lock;
            hops += 1;
            if hops > 1024 {
                break; // defensive bound; graphs this deep are corrupt
            }
        }
    }

    pub fn on_acquired(id: u64, name: &'static str, rank: Option<u32>) {
        let me = std::thread::current().id();
        {
            let mut g = graph_lock();
            g.waiting.remove(&me);
            g.owners.insert(id, (me, name));
        }
        HELD.with(|h| h.borrow_mut().push((id, name, rank)));
    }

    pub fn on_released(id: u64) {
        let me = std::thread::current().id();
        {
            let mut g = graph_lock();
            // A ring steal can transfer ownership while the original
            // session still exists: only the current owner clears it.
            if g.owners.get(&id).is_some_and(|(t, _)| *t == me) {
                g.owners.remove(&id);
            }
        }
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(pos) = held.iter().rposition(|(hid, _, _)| *hid == id) {
                held.remove(pos);
            }
        });
    }

    /// Relaxed acquire for the ring spin-lock: no reentrancy check and
    /// no rank comparison against other ring entries (same rank), but
    /// still panics when a strictly higher-ranked mutex is held — that
    /// would invert the global order.
    pub fn on_ring_acquired(id: u64, rank: u32) {
        HELD.with(|h| {
            let held = h.borrow();
            for (_, hname, hrank) in held.iter() {
                if let Some(hr) = hrank {
                    if *hr > rank {
                        panic!(
                            "lock-order witness: entering ring spin-lock (rank {rank}) \
                             while holding `{hname}` (rank {hr}) — ranks must strictly \
                             ascend (held stack: {})",
                            render_stack(&held)
                        );
                    }
                }
            }
        });
        let me = std::thread::current().id();
        graph_lock().owners.insert(id, (me, "ring_spin"));
        HELD.with(|h| h.borrow_mut().push((id, "ring_spin", Some(rank))));
    }

    pub fn held_count() -> usize {
        HELD.with(|h| h.borrow().len())
    }

    fn render_stack(held: &[(u64, &'static str, Option<u32>)]) -> String {
        held.iter()
            .map(|(_, n, r)| match r {
                Some(r) => format!("{n}({r})"),
                None => format!("{n}(unranked)"),
            })
            .collect::<Vec<_>>()
            .join(" -> ")
    }
}

#[cfg(not(any(debug_assertions, feature = "lockwitness")))]
mod hooks {
    pub fn on_acquiring(_id: u64, _name: &'static str, _rank: Option<u32>) {}
    pub fn on_acquired(_id: u64, _name: &'static str, _rank: Option<u32>) {}
    pub fn on_ring_acquired(_id: u64, _rank: u32) {}
    pub fn on_released(_id: u64) {}
    pub fn held_count() -> usize {
        0
    }
}

// Gated like the hooks themselves: under `cargo test --release` (no
// debug_assertions, no `lockwitness`) the witness is compiled out and
// every held_count() assertion below would trivially fail.
#[cfg(all(test, any(debug_assertions, feature = "lockwitness")))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ascending_ranks_pass() {
        let a = WitnessMutex::new("a", 1, 0u32);
        let b = WitnessMutex::new("b", 2, 0u32);
        let ga = a.lock().unwrap();
        let gb = b.lock().unwrap();
        assert_eq!(held_count(), 2);
        drop(gb);
        drop(ga);
        assert_eq!(held_count(), 0);
    }

    #[test]
    fn rank_inversion_panics() {
        let res = std::thread::spawn(|| {
            let hi = WitnessMutex::new("hi", 50, 0u32);
            let lo = WitnessMutex::new("lo", 40, 0u32);
            let _g = hi.lock().unwrap();
            let _g2 = lo.lock().unwrap(); // 40 while holding 50: panic
        })
        .join();
        let err = res.expect_err("inversion must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("ranks must strictly ascend"), "got: {msg}");
        assert!(msg.contains("`lo`") && msg.contains("`hi`"), "got: {msg}");
    }

    #[test]
    fn guard_drop_unwinds_witness() {
        let m = Arc::new(WitnessMutex::new("m", 5, 1u32));
        {
            let mut g = m.lock().unwrap();
            *g += 1;
        }
        assert_eq!(held_count(), 0);
        // Reacquirable after release, and the value persisted.
        assert_eq!(*m.lock().unwrap(), 2);
    }

    #[test]
    fn wait_timeout_preserves_witness() {
        let m = WitnessMutex::new("m", 5, 0u32);
        let cv = std::sync::Condvar::new();
        let g = m.lock().unwrap();
        let (g, timed_out) = g
            .wait_timeout(&cv, std::time::Duration::from_millis(1))
            .unwrap();
        assert!(timed_out.timed_out());
        assert_eq!(held_count(), 1);
        drop(g);
        assert_eq!(held_count(), 0);
    }

    #[test]
    fn ring_hooks_pair() {
        ring_lock_acquired(424242);
        assert_eq!(held_count(), 1);
        ring_lock_released(424242);
        assert_eq!(held_count(), 0);
    }
}
