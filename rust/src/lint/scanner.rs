//! Hand-rolled Rust source scanner for the `onepiece lint` pass.
//!
//! Zero dependencies by construction (the offline build has no
//! crates.io access): a character-level pass classifies every byte of a
//! source file as code, comment, or literal interior, then a line-level
//! pass derives the structure the rules need — `#[cfg(test)]` regions,
//! function spans, brace depths, `// lint: ...` annotations, and
//! `Condvar` field declarations.
//!
//! The scanner is deliberately an *approximation* of a real parser:
//! it understands strings (including raw strings), char literals vs
//! lifetimes, nested block comments, and brace nesting, but not macro
//! expansion or type inference. Every rule built on top of it is
//! written so that the approximation errs toward *missing* exotic
//! violations rather than inventing false positives — and any residual
//! false positive is suppressible with `// lint: allow(<rule>)`.

/// One scanned source line.
#[derive(Debug, Clone)]
pub struct LineInfo {
    /// Code content: comments stripped, string/char literal *interiors*
    /// blanked to spaces (delimiters kept so expression shape survives).
    pub code: String,
    /// Comment text on this line (both `//` and `/* */` bodies).
    pub comment: String,
    /// True if the line sits inside a `#[cfg(test)]`-gated item or a
    /// `#[test]` function.
    pub in_test: bool,
    /// Brace depth at the start of the line.
    pub depth_start: i32,
    /// Rules suppressed on this line via `// lint: allow(rule, ...)`
    /// (same line, or a directly preceding comment-only line).
    pub allows: Vec<String>,
}

/// A `// lint: lock-rank(<name>, N)` annotation. When the annotated
/// line declares a struct field of mutex type, `field` carries the
/// field identifier so `.lock()` receivers in the same file resolve to
/// this rank even when field names collide across files.
#[derive(Debug, Clone)]
pub struct RankDecl {
    pub name: String,
    pub rank: u32,
    pub field: Option<String>,
    pub line: usize,
}

/// Span of one `fn` item body (1-based, inclusive lines).
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub start: usize,
    pub end: usize,
}

/// A fully scanned source file.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the lint root, forward slashes.
    pub path: String,
    pub lines: Vec<LineInfo>,
    pub ranks: Vec<RankDecl>,
    /// Field names declared with type `Condvar` in this file.
    pub condvars: Vec<String>,
    pub fns: Vec<FnSpan>,
}

impl SourceFile {
    /// First path segment (module directory or file stem) — used for
    /// data-plane classification.
    pub fn top_module(&self) -> &str {
        let p = self.path.as_str();
        match p.find('/') {
            Some(i) => &p[..i],
            None => p.strip_suffix(".rs").unwrap_or(p),
        }
    }
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Character-level pass: split `src` into parallel `code` / `comment`
/// streams of identical length (literal interiors and comment bodies
/// blanked in `code`; everything non-comment blanked in `comment`).
fn classify(src: &str) -> (String, String) {
    #[derive(PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
        Char,
    }
    let mut code = String::with_capacity(src.len());
    let mut comment = String::with_capacity(src.len());
    let chars: Vec<char> = src.chars().collect();
    let mut st = St::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied();
        if c == '\n' {
            // Newlines pass through both streams; a line comment ends.
            if st == St::LineComment {
                st = St::Code;
            }
            code.push('\n');
            comment.push('\n');
            i += 1;
            continue;
        }
        match st {
            St::Code => match c {
                '/' if next == Some('/') => {
                    st = St::LineComment;
                    code.push(' ');
                    comment.push(' ');
                    i += 1;
                }
                '/' if next == Some('*') => {
                    st = St::BlockComment(1);
                    code.push(' ');
                    comment.push(' ');
                    i += 1;
                }
                '"' => {
                    // Raw string? Look back for r / r# prefixes.
                    st = St::Str;
                    code.push('"');
                    comment.push(' ');
                }
                'r' if next == Some('"') || next == Some('#') => {
                    // Possible raw string r"..." or r#"..."#.
                    let mut j = i + 1;
                    let mut hashes = 0u32;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        for _ in i..=j {
                            code.push(if chars[i] == 'r' { '"' } else { ' ' });
                            comment.push(' ');
                            i += 1;
                        }
                        st = St::RawStr(hashes);
                        continue;
                    } else {
                        code.push(c);
                        comment.push(' ');
                    }
                }
                '\'' => {
                    // Char literal vs lifetime: '\x' is a char; 'a' is a
                    // char if the char after next is a closing quote;
                    // otherwise a lifetime ('a in generics).
                    let is_char = next == Some('\\')
                        || (next.is_some() && chars.get(i + 2) == Some(&'\''));
                    if is_char {
                        st = St::Char;
                        code.push('\'');
                        comment.push(' ');
                    } else {
                        code.push('\'');
                        comment.push(' ');
                    }
                }
                _ => {
                    code.push(c);
                    comment.push(' ');
                }
            },
            St::LineComment => {
                code.push(' ');
                comment.push(c);
            }
            St::BlockComment(d) => {
                if c == '*' && next == Some('/') {
                    st = if d == 1 { St::Code } else { St::BlockComment(d - 1) };
                    code.push(' ');
                    code.push(' ');
                    comment.push(' ');
                    comment.push(' ');
                    i += 2;
                    continue;
                } else if c == '/' && next == Some('*') {
                    st = St::BlockComment(d + 1);
                    code.push(' ');
                    code.push(' ');
                    comment.push(' ');
                    comment.push(' ');
                    i += 2;
                    continue;
                }
                code.push(' ');
                comment.push(c);
            }
            St::Str => {
                if c == '\\' {
                    code.push(' ');
                    comment.push(' ');
                    if next.is_some() && next != Some('\n') {
                        code.push(' ');
                        comment.push(' ');
                        i += 2;
                        continue;
                    }
                } else if c == '"' {
                    st = St::Code;
                    code.push('"');
                    comment.push(' ');
                } else {
                    code.push(' ');
                    comment.push(' ');
                }
            }
            St::RawStr(hashes) => {
                if c == '"' {
                    // Closing needs `"` followed by `hashes` hashes.
                    let mut ok = true;
                    for k in 0..hashes {
                        if chars.get(i + 1 + k as usize) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        code.push('"');
                        comment.push(' ');
                        for _ in 0..hashes {
                            code.push(' ');
                            comment.push(' ');
                        }
                        i += 1 + hashes as usize;
                        st = St::Code;
                        continue;
                    }
                }
                code.push(' ');
                comment.push(' ');
            }
            St::Char => {
                if c == '\\' && next.is_some() && next != Some('\n') {
                    code.push(' ');
                    code.push(' ');
                    comment.push(' ');
                    comment.push(' ');
                    i += 2;
                    continue;
                }
                if c == '\'' {
                    st = St::Code;
                    code.push('\'');
                    comment.push(' ');
                } else {
                    code.push(' ');
                    comment.push(' ');
                }
            }
        }
        i += 1;
    }
    (code, comment)
}

/// Parse `lint: allow(a, b)` / `lint: lock-rank(name, 3)` out of one
/// line's comment text.
fn parse_annotations(comment: &str, allows: &mut Vec<String>, rank: &mut Option<(String, u32)>) {
    let Some(pos) = comment.find("lint:") else {
        return;
    };
    let rest = comment[pos + 5..].trim_start();
    if let Some(args) = rest.strip_prefix("allow(").and_then(|r| r.split(')').next()) {
        for a in args.split(',') {
            let a = a.trim().to_lowercase();
            if !a.is_empty() {
                allows.push(a);
            }
        }
    } else if let Some(args) = rest.strip_prefix("lock-rank(").and_then(|r| r.split(')').next()) {
        let mut parts = args.splitn(2, ',');
        if let (Some(name), Some(n)) = (parts.next(), parts.next()) {
            if let Ok(n) = n.trim().parse::<u32>() {
                *rank = Some((name.trim().to_string(), n));
            }
        }
    }
}

/// Extract the field identifier from a struct-field declaration line
/// like `inner: Mutex<Inner>,` → `inner`.
fn field_ident(code: &str) -> Option<String> {
    let colon = code.find(':')?;
    let before = code[..colon].trim();
    let id: String = before
        .chars()
        .rev()
        .take_while(|&c| is_ident_char(c))
        .collect::<Vec<_>>()
        .into_iter()
        .rev()
        .collect();
    if id.is_empty() || id.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        None
    } else {
        Some(id)
    }
}

/// Whether `code` contains `word` as a whole identifier token.
pub fn has_word(code: &str, word: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let abs = start + pos;
        let before_ok = abs == 0
            || !is_ident_char(code[..abs].chars().next_back().unwrap_or(' '));
        let after = code[abs + word.len()..].chars().next();
        let after_ok = !after.is_some_and(is_ident_char);
        if before_ok && after_ok {
            return true;
        }
        start = abs + word.len();
    }
    false
}

/// The identifier immediately preceding byte offset `at` in `code`
/// (used to resolve `.lock()` / `.wait(` receivers).
pub fn ident_before(code: &str, at: usize) -> Option<String> {
    let bytes = code.as_bytes();
    let mut end = at;
    while end > 0 && !(bytes[end - 1] as char).is_ascii_whitespace() && !is_ident_char(bytes[end - 1] as char) {
        // Skip closing parens etc. only if directly a `)` chain like
        // `foo().lock()` — we only step over `)` and matching `(`.
        if bytes[end - 1] == b')' {
            let mut depth = 1;
            end -= 1;
            while end > 0 && depth > 0 {
                match bytes[end - 1] {
                    b')' => depth += 1,
                    b'(' => depth -= 1,
                    _ => {}
                }
                end -= 1;
            }
        } else {
            return None;
        }
    }
    let mut start = end;
    while start > 0 && is_ident_char(bytes[start - 1] as char) {
        start -= 1;
    }
    if start == end {
        None
    } else {
        Some(code[start..end].to_string())
    }
}

/// Scan one source file into line/structure info.
pub fn scan(path: &str, src: &str) -> SourceFile {
    let (code_s, comment_s) = classify(src);
    let code_lines: Vec<&str> = code_s.split('\n').collect();
    let comment_lines: Vec<&str> = comment_s.split('\n').collect();
    let n = code_lines.len();

    let mut lines: Vec<LineInfo> = Vec::with_capacity(n);
    let mut ranks: Vec<RankDecl> = Vec::new();
    let mut condvars: Vec<String> = Vec::new();
    let mut fns: Vec<FnSpan> = Vec::new();

    // Pending allow() annotations from comment-only lines: apply to the
    // next line that carries code.
    let mut pending_allows: Vec<String> = Vec::new();
    // Pending lock-rank annotation (comment-only line → next code line).
    let mut pending_rank: Option<(String, u32)> = None;

    // Test-region tracking: depth at which a #[cfg(test)] item's brace
    // block opened; None = not inside one. `test_pending` is set when
    // the attribute has been seen but the item's block not yet opened.
    let mut depth: i32 = 0;
    let mut test_region_depth: Option<i32> = None;
    let mut test_pending = false;

    // Function-span tracking.
    struct PendingFn {
        name: String,
    }
    let mut fn_pending: Option<PendingFn> = None;
    let mut fn_stack: Vec<(String, i32, usize)> = Vec::new(); // (name, open depth, start line)

    for idx in 0..n {
        let code = code_lines[idx];
        let comment = comment_lines[idx];
        let depth_start = depth;
        let in_test_now = test_region_depth.is_some() || test_pending;

        // Annotations.
        let mut line_allows: Vec<String> = Vec::new();
        let mut line_rank: Option<(String, u32)> = None;
        parse_annotations(comment, &mut line_allows, &mut line_rank);

        let code_trim = code.trim();
        let has_code = !code_trim.is_empty();

        if has_code {
            line_allows.extend(pending_allows.drain(..));
            if line_rank.is_none() {
                line_rank = pending_rank.take();
            }
        } else {
            // Comment-only line: defer annotations to the next code line.
            pending_allows.extend(line_allows.iter().cloned());
            if let Some(r) = line_rank.clone() {
                pending_rank = Some(r);
            }
            line_allows.clear();
        }

        if let Some((name, rank)) = line_rank {
            ranks.push(RankDecl {
                name,
                rank,
                field: field_ident(code),
                line: idx + 1,
            });
        }

        // Condvar field declarations (`signal: Condvar,`).
        if has_code && (code.contains(": Condvar") || code.contains(":Condvar")) {
            if let Some(f) = field_ident(code) {
                if !condvars.contains(&f) {
                    condvars.push(f);
                }
            }
        }

        // #[cfg(test)] / #[test] attribute detection.
        if code.contains("#[cfg(test)]")
            || code.contains("#[cfg(all(test")
            || code.contains("#[cfg(any(test")
            || code_trim == "#[test]"
            || code.contains("#[test]")
        {
            test_pending = true;
        }

        // `fn name` detection (word-boundary).
        if test_region_depth.is_none() {
            if let Some(name) = find_fn_name(code) {
                fn_pending = Some(PendingFn { name });
            }
        }

        // Char walk for braces / statement ends.
        for c in code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if test_pending && test_region_depth.is_none() {
                        test_region_depth = Some(depth);
                        test_pending = false;
                    }
                    if let Some(pf) = fn_pending.take() {
                        fn_stack.push((pf.name, depth, idx + 1));
                    }
                }
                '}' => {
                    depth -= 1;
                    if let Some(td) = test_region_depth {
                        if depth < td {
                            test_region_depth = None;
                        }
                    }
                    while let Some((_, d, _)) = fn_stack.last() {
                        if depth < *d {
                            let (name, _, start) = fn_stack.pop().unwrap();
                            fns.push(FnSpan {
                                name,
                                start,
                                end: idx + 1,
                            });
                        } else {
                            break;
                        }
                    }
                }
                ';' => {
                    // A `;` before any `{` ends the pending item: the
                    // cfg(test) attribute applied to a single statement
                    // (`#[cfg(test)] use ...;`), or a trait fn decl.
                    if test_pending && test_region_depth.is_none() {
                        test_pending = false;
                    }
                    if fn_pending.is_some() {
                        fn_pending = None;
                    }
                }
                _ => {}
            }
        }

        lines.push(LineInfo {
            code: code.to_string(),
            comment: comment.to_string(),
            in_test: in_test_now || test_region_depth.is_some(),
            depth_start,
            allows: line_allows,
        });
    }
    // Close any unterminated fns at EOF.
    while let Some((name, _, start)) = fn_stack.pop() {
        fns.push(FnSpan {
            name,
            start,
            end: n,
        });
    }

    SourceFile {
        path: path.replace('\\', "/"),
        lines,
        ranks,
        condvars,
        fns,
    }
}

/// Find `fn <name>` on a code line, honoring word boundaries (skips
/// `Fn(`, `fn_ptr` idents, etc.). Returns the function name.
fn find_fn_name(code: &str) -> Option<String> {
    let bytes = code.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = code[start..].find("fn ") {
        let abs = start + pos;
        let before_ok =
            abs == 0 || !is_ident_char(bytes[abs - 1] as char);
        if before_ok {
            let rest = code[abs + 3..].trim_start();
            let name: String = rest.chars().take_while(|&c| is_ident_char(c)).collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
        start = abs + 3;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        let f = scan(
            "x.rs",
            "let a = \"unwrap() inside\"; // unwrap() in comment\nlet b = a.unwrap();\n",
        );
        assert!(!f.lines[0].code.contains("unwrap"));
        assert!(f.lines[0].comment.contains("unwrap() in comment"));
        assert!(f.lines[1].code.contains(".unwrap()"));
    }

    #[test]
    fn cfg_test_region() {
        let src = "fn a() { b(); }\n#[cfg(test)]\nmod tests {\n    fn c() { d.unwrap(); }\n}\nfn e() {}\n";
        let f = scan("x.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[3].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn allow_annotations_attach() {
        let src = "// lint: allow(l1)\nlet x = y.unwrap();\nlet z = q.unwrap(); // lint: allow(l1, l4)\n";
        let f = scan("x.rs", src);
        assert_eq!(f.lines[1].allows, vec!["l1".to_string()]);
        assert_eq!(f.lines[2].allows, vec!["l1".to_string(), "l4".to_string()]);
    }

    #[test]
    fn lock_rank_binds_field() {
        let src = "struct S {\n    inner: Mutex<u32>, // lint: lock-rank(tracker, 40)\n}\n";
        let f = scan("x.rs", src);
        assert_eq!(f.ranks.len(), 1);
        assert_eq!(f.ranks[0].name, "tracker");
        assert_eq!(f.ranks[0].rank, 40);
        assert_eq!(f.ranks[0].field.as_deref(), Some("inner"));
    }

    #[test]
    fn condvar_fields_and_fn_spans() {
        let src = "struct S {\n    signal: Condvar,\n}\nfn wait_loop() {\n    x();\n}\n";
        let f = scan("x.rs", src);
        assert_eq!(f.condvars, vec!["signal".to_string()]);
        assert_eq!(f.fns.len(), 1);
        assert_eq!(f.fns[0].name, "wait_loop");
        assert_eq!((f.fns[0].start, f.fns[0].end), (4, 6));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let f = scan("x.rs", "impl<'a> Foo<'a> { fn g(&'a self) { h('x'); } }\n");
        assert!(f.lines[0].code.contains("fn g"));
        assert_eq!(f.fns.len(), 1);
    }

    #[test]
    fn ident_before_resolves_receivers() {
        let code = "        let g = self.inner.lock().unwrap();";
        let at = code.find(".lock()").unwrap();
        assert_eq!(ident_before(code, at).as_deref(), Some("inner"));
        let code2 = "        let g = store().lock();";
        let at2 = code2.find(".lock()").unwrap();
        assert_eq!(ident_before(code2, at2).as_deref(), Some("store"));
    }
}
