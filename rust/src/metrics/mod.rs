//! Lightweight metrics: counters, gauges, log-bucketed latency histograms,
//! and the sliding **utilization window** the NodeManager's load-aware
//! scheduler consumes (§8.2: "average GPU utilization ... over a recent
//! time window").
//!
//! Everything is lock-free (atomics) so metric updates are safe on the
//! request hot path.

mod histogram;
mod utilization;

pub use histogram::{Histogram, HistogramSnapshot};
pub use utilization::UtilizationWindow;

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonically increasing event counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time signed value.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Named metric registry shared across a node's components.
#[derive(Default, Clone)]
pub struct Registry {
    inner: Arc<RegistryInner>,
}

#[derive(Default)]
struct RegistryInner {
    counters: Mutex<HashMap<String, Arc<Counter>>>, // lint: lock-rank(metrics_counters, 90)
    gauges: Mutex<HashMap<String, Arc<Gauge>>>, // lint: lock-rank(metrics_gauges, 91)
    histograms: Mutex<HashMap<String, Arc<Histogram>>>, // lint: lock-rank(metrics_histograms, 92)
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create the named counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.inner.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the named gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.inner.gauges.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the named histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.inner.histograms.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Sorted snapshot of every counter (the federation layer's
    /// spill/reject/donation accounting reads this for its reports).
    pub fn counters_snapshot(&self) -> Vec<(String, u64)> {
        let mut out: Vec<(String, u64)> = self
            .inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect();
        out.sort();
        out
    }

    /// Render all metrics as sorted `name value` lines (for logs/demos).
    pub fn render(&self) -> String {
        let mut lines = Vec::new();
        for (k, v) in self.inner.counters.lock().unwrap().iter() {
            lines.push(format!("counter {k} {}", v.get()));
        }
        for (k, v) in self.inner.gauges.lock().unwrap().iter() {
            lines.push(format!("gauge {k} {}", v.get()));
        }
        for (k, v) in self.inner.histograms.lock().unwrap().iter() {
            let s = v.snapshot();
            lines.push(format!(
                "histogram {k} count={} p50={}ns p95={}ns p99={}ns max={}ns",
                s.count, s.p50, s.p95, s.p99, s.max
            ));
        }
        lines.sort();
        lines.join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        r.counter("reqs").inc();
        r.counter("reqs").add(4);
        assert_eq!(r.counter("reqs").get(), 5);
        r.gauge("depth").set(7);
        r.gauge("depth").add(-2);
        assert_eq!(r.gauge("depth").get(), 5);
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.inc();
        assert_eq!(b.get(), 1);
    }

    #[test]
    fn counters_snapshot_sorted() {
        let r = Registry::new();
        r.counter("b").add(2);
        r.counter("a").inc();
        assert_eq!(
            r.counters_snapshot(),
            vec![("a".to_string(), 1), ("b".to_string(), 2)]
        );
    }

    #[test]
    fn render_contains_names() {
        let r = Registry::new();
        r.counter("a").inc();
        r.histogram("lat").record(100);
        let out = r.render();
        assert!(out.contains("counter a 1"));
        assert!(out.contains("histogram lat"));
    }
}
