//! Lightweight metrics: counters, gauges, log-bucketed latency histograms,
//! and the sliding **utilization window** the NodeManager's load-aware
//! scheduler consumes (§8.2: "average GPU utilization ... over a recent
//! time window").
//!
//! Everything is lock-free (atomics) so metric updates are safe on the
//! request hot path.

mod histogram;
mod utilization;

pub use histogram::{Histogram, HistogramSnapshot};
pub use utilization::UtilizationWindow;

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonically increasing event counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time signed value.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Named metric registry shared across a node's components.
#[derive(Default, Clone)]
pub struct Registry {
    inner: Arc<RegistryInner>,
}

#[derive(Default)]
struct RegistryInner {
    counters: Mutex<HashMap<String, Arc<Counter>>>, // lint: lock-rank(metrics_counters, 90)
    gauges: Mutex<HashMap<String, Arc<Gauge>>>, // lint: lock-rank(metrics_gauges, 91)
    histograms: Mutex<HashMap<String, Arc<Histogram>>>, // lint: lock-rank(metrics_histograms, 92)
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create the named counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.inner.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the named gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.inner.gauges.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the named histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.inner.histograms.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Sorted snapshot of every counter (the federation layer's
    /// spill/reject/donation accounting reads this for its reports).
    pub fn counters_snapshot(&self) -> Vec<(String, u64)> {
        let mut out: Vec<(String, u64)> = self
            .inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect();
        out.sort();
        out
    }

    /// Render all metrics as sorted `name value` lines (for logs/demos).
    pub fn render(&self) -> String {
        let mut lines = Vec::new();
        for (k, v) in self.inner.counters.lock().unwrap().iter() {
            lines.push(format!("counter {k} {}", v.get()));
        }
        for (k, v) in self.inner.gauges.lock().unwrap().iter() {
            lines.push(format!("gauge {k} {}", v.get()));
        }
        for (k, v) in self.inner.histograms.lock().unwrap().iter() {
            let s = v.snapshot();
            lines.push(format!(
                "histogram {k} count={} p50={}ns p95={}ns p99={}ns max={}ns",
                s.count, s.p50, s.p95, s.p99, s.max
            ));
        }
        lines.sort();
        lines.join("\n")
    }

    /// Fold another registry's metrics into this one: counters and
    /// gauges add, histograms merge bucket-wise ([`Histogram::merge`]).
    /// Multi-set / federation runs call this per set registry to build
    /// one fleet view, then render that once.
    ///
    /// Snapshots each source collection before touching this registry,
    /// so merging a registry into itself (or two registries in either
    /// order, concurrently) cannot deadlock on the rank-ordered map
    /// locks.
    pub fn merge_from(&self, other: &Registry) {
        let counters: Vec<(String, u64)> = other.counters_snapshot();
        let gauges: Vec<(String, i64)> = other
            .inner
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect();
        let histograms: Vec<(String, HistogramSnapshot)> = other
            .inner
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot()))
            .collect();
        for (k, v) in counters {
            if v > 0 {
                self.counter(&k).add(v);
            }
        }
        for (k, v) in gauges {
            if v != 0 {
                self.gauge(&k).add(v);
            }
        }
        for (k, s) in histograms {
            self.histogram(&k).merge(&s);
        }
    }

    /// Prometheus text exposition (format 0.0.4) of every metric:
    /// counters and gauges as single samples, histograms as summaries
    /// with `quantile` labels plus `_sum`/`_count`. Names are sanitized
    /// to the metric charset; output is name-sorted so runs diff.
    pub fn render_prometheus(&self) -> String {
        fn sanitize(name: &str) -> String {
            let mut s: String = name
                .chars()
                .map(|c| {
                    if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                        c
                    } else {
                        '_'
                    }
                })
                .collect();
            if s.chars().next().is_some_and(|c| c.is_ascii_digit()) {
                s.insert(0, '_');
            }
            s
        }
        let mut blocks: Vec<String> = Vec::new();
        for (k, v) in self.counters_snapshot() {
            let n = sanitize(&k);
            blocks.push(format!("# TYPE {n} counter\n{n} {v}\n"));
        }
        let mut gauges: Vec<(String, i64)> = self
            .inner
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect();
        gauges.sort();
        for (k, v) in gauges {
            let n = sanitize(&k);
            blocks.push(format!("# TYPE {n} gauge\n{n} {v}\n"));
        }
        let mut hists: Vec<(String, HistogramSnapshot)> = self
            .inner
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot()))
            .collect();
        hists.sort_by(|a, b| a.0.cmp(&b.0));
        for (k, s) in hists {
            let n = sanitize(&k);
            blocks.push(format!(
                "# TYPE {n} summary\n\
                 {n}{{quantile=\"0.5\"}} {}\n\
                 {n}{{quantile=\"0.9\"}} {}\n\
                 {n}{{quantile=\"0.95\"}} {}\n\
                 {n}{{quantile=\"0.99\"}} {}\n\
                 {n}_sum {}\n\
                 {n}_count {}\n",
                s.p50, s.p90, s.p95, s.p99, s.sum, s.count
            ));
        }
        blocks.concat()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        r.counter("reqs").inc();
        r.counter("reqs").add(4);
        assert_eq!(r.counter("reqs").get(), 5);
        r.gauge("depth").set(7);
        r.gauge("depth").add(-2);
        assert_eq!(r.gauge("depth").get(), 5);
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.inc();
        assert_eq!(b.get(), 1);
    }

    #[test]
    fn counters_snapshot_sorted() {
        let r = Registry::new();
        r.counter("b").add(2);
        r.counter("a").inc();
        assert_eq!(
            r.counters_snapshot(),
            vec![("a".to_string(), 1), ("b".to_string(), 2)]
        );
    }

    #[test]
    fn render_contains_names() {
        let r = Registry::new();
        r.counter("a").inc();
        r.histogram("lat").record(100);
        let out = r.render();
        assert!(out.contains("counter a 1"));
        assert!(out.contains("histogram lat"));
    }

    #[test]
    fn merge_from_aggregates_fleet_view() {
        let a = Registry::new();
        let b = Registry::new();
        a.counter("reqs").add(3);
        b.counter("reqs").add(4);
        b.counter("only_b").inc();
        a.gauge("depth").set(2);
        b.gauge("depth").set(5);
        a.histogram("lat").record(100);
        b.histogram("lat").record(10_000);
        let fleet = Registry::new();
        fleet.merge_from(&a);
        fleet.merge_from(&b);
        assert_eq!(fleet.counter("reqs").get(), 7);
        assert_eq!(fleet.counter("only_b").get(), 1);
        assert_eq!(fleet.gauge("depth").get(), 7);
        let s = fleet.histogram("lat").snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.max, 10_000);
        // Sources are untouched.
        assert_eq!(a.counter("reqs").get(), 3);
        assert_eq!(b.histogram("lat").snapshot().count, 1);
    }

    #[test]
    fn fault_plane_counters_surface_in_exposition() {
        // The fault/breaker/brownout counters are plain registry rows:
        // once registered (only when faults are on) they must surface in
        // the Prometheus exposition with sanitized names.
        let r = Registry::new();
        r.counter("verbs_lost_total").add(3);
        r.counter("verb_retries_total").add(5);
        r.counter("requests_shed.batch").inc();
        r.counter("fed.set0.breaker_open_total").inc();
        let out = r.render_prometheus();
        assert!(out.contains("# TYPE verbs_lost_total counter\nverbs_lost_total 3\n"));
        assert!(out.contains("# TYPE verb_retries_total counter\nverb_retries_total 5\n"));
        assert!(out.contains("requests_shed_batch 1\n"));
        assert!(out.contains("fed_set0_breaker_open_total 1\n"));
    }

    #[test]
    fn prometheus_exposition_shape() {
        let r = Registry::new();
        r.counter("ring.pushes-total").add(9);
        r.gauge("queue_depth").set(-3);
        r.histogram("e2e_latency_ns").record(1_000);
        let out = r.render_prometheus();
        assert!(out.contains("# TYPE ring_pushes_total counter\nring_pushes_total 9\n"));
        assert!(out.contains("# TYPE queue_depth gauge\nqueue_depth -3\n"));
        assert!(out.contains("# TYPE e2e_latency_ns summary\n"));
        assert!(out.contains("e2e_latency_ns{quantile=\"0.99\"}"));
        assert!(out.contains("e2e_latency_ns_sum 1000\n"));
        assert!(out.contains("e2e_latency_ns_count 1\n"));
        // Deterministic: same registry renders identically.
        assert_eq!(out, r.render_prometheus());
    }
}
