//! Lock-free log-bucketed histogram for latency recording (ns scale).
//!
//! 64 buckets: bucket *i* covers `[2^i, 2^(i+1))` ns — enough range for
//! sub-ns to ~584 years. Percentile error is bounded by the 2× bucket
//! width, which is fine for p50/p95/p99 reporting in the benches.

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 64;

/// Concurrent histogram; `record` is wait-free.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Immutable view of a histogram at a point in time. Carries the raw
/// bucket counts and sum alongside the derived percentiles so snapshots
/// are *mergeable*: [`Histogram::merge`] folds one into another
/// histogram losslessly (fleet aggregation across sets/registries).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub count: u64,
    /// Raw sum of every observation (mean = sum / count, exact).
    pub sum: u64,
    pub mean: u64,
    pub p50: u64,
    pub p90: u64,
    pub p95: u64,
    pub p99: u64,
    pub max: u64,
    /// Raw per-bucket counts (bucket *i* covers `[2^i, 2^(i+1))` ns).
    pub buckets: [u64; BUCKETS],
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: [(); BUCKETS].map(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one observation (e.g. nanoseconds of latency).
    pub fn record(&self, value: u64) {
        let idx = (64 - value.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.max.fetch_max(value, Ordering::Relaxed);
    }

    fn percentile(&self, counts: &[u64; BUCKETS], total: u64, p: f64) -> u64 {
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * p).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                // Midpoint of bucket [2^i, 2^(i+1)).
                return (1u64 << i) + (1u64 << i) / 2;
            }
        }
        self.max.load(Ordering::Relaxed)
    }

    /// Snapshot percentiles (approximate to bucket resolution).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let counts: [u64; BUCKETS] =
            std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed));
        let count = self.count.load(Ordering::Relaxed);
        let sum = self.sum.load(Ordering::Relaxed);
        HistogramSnapshot {
            count,
            sum,
            mean: if count == 0 { 0 } else { sum / count },
            p50: self.percentile(&counts, count, 0.50),
            p90: self.percentile(&counts, count, 0.90),
            p95: self.percentile(&counts, count, 0.95),
            p99: self.percentile(&counts, count, 0.99),
            max: self.max.load(Ordering::Relaxed),
            buckets: counts,
        }
    }

    /// Fold another histogram's snapshot into this one: bucket-wise
    /// add, so merged percentiles are exactly what a single histogram
    /// observing both streams would report. The federation/fleet view
    /// merges per-set snapshots with this.
    pub fn merge(&self, snap: &HistogramSnapshot) {
        for (b, &c) in self.buckets.iter().zip(&snap.buckets) {
            if c > 0 {
                b.fetch_add(c, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(snap.count, Ordering::Relaxed);
        self.sum.fetch_add(snap.sum, Ordering::Relaxed);
        self.max.fetch_max(snap.max, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, 0);
    }

    #[test]
    fn percentiles_ordered() {
        let h = Histogram::new();
        for i in 1..=10_000u64 {
            h.record(i);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 10_000);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99);
        assert!(s.max == 10_000);
        // p50 of uniform 1..10000 ≈ 5000; bucket resolution gives [4096, 8192).
        assert!(s.p50 >= 4096 && s.p50 < 8192, "p50={}", s.p50);
    }

    #[test]
    fn zero_value_goes_to_first_bucket() {
        let h = Histogram::new();
        h.record(0);
        assert_eq!(h.snapshot().count, 1);
    }

    #[test]
    fn huge_value_clamps() {
        let h = Histogram::new();
        h.record(u64::MAX);
        assert_eq!(h.snapshot().max, u64::MAX);
    }

    #[test]
    fn mean_exact() {
        let h = Histogram::new();
        h.record(100);
        h.record(300);
        assert_eq!(h.snapshot().mean, 200);
    }

    #[test]
    fn merge_equals_single_stream() {
        // Two histograms over disjoint streams, merged, must snapshot
        // identically to one histogram that saw both streams.
        let a = Histogram::new();
        let b = Histogram::new();
        let all = Histogram::new();
        for i in 1..=1_000u64 {
            a.record(i * 3);
            all.record(i * 3);
        }
        for i in 1..=500u64 {
            b.record(i * 1_000);
            all.record(i * 1_000);
        }
        a.merge(&b.snapshot());
        assert_eq!(a.snapshot(), all.snapshot());
    }

    #[test]
    fn merge_into_empty_is_identity() {
        let src = Histogram::new();
        for v in [5u64, 500, 50_000] {
            src.record(v);
        }
        let dst = Histogram::new();
        dst.merge(&src.snapshot());
        assert_eq!(dst.snapshot(), src.snapshot());
    }
}
