//! Sliding-window busy-fraction tracker — the "GPU utilization" signal
//! workflow instances report to the NodeManager (§4.2, §8.2).
//!
//! Workers bracket each task with [`UtilizationWindow::busy`] /
//! [`UtilizationWindow::idle`]; the NM polls [`UtilizationWindow::value`],
//! which returns the busy fraction over the last `window_ns` (the paper's
//! "recent time window (e.g. 5 minutes)" — configurable, seconds in tests).

use crate::util::Clock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Busy/idle interval record. `weight` is the number of requests the
/// interval served: a micro-batched execution covers several requests in
/// one (amortized, shorter) busy span, and counting it as one unit of
/// work would make the NodeManager under-estimate load on batching
/// stages (and the elastic allocator then mis-size them). Weighting the
/// span by its members reports the *demand* the stage absorbed.
#[derive(Debug, Clone, Copy)]
struct Span {
    start_ns: u64,
    end_ns: u64,
    weight: u32,
}

/// Sliding-window utilization estimator. Thread-safe; one per worker (the
/// instance aggregates across its worker pool).
pub struct UtilizationWindow {
    clock: Arc<dyn Clock>,
    window_ns: u64,
    busy_since: AtomicU64, // 0 = currently idle
    spans: Mutex<Vec<Span>>, // lint: lock-rank(util_spans, 93)
}

impl UtilizationWindow {
    /// `window_ns`: lookback horizon for the busy fraction.
    pub fn new(clock: Arc<dyn Clock>, window_ns: u64) -> Self {
        Self {
            clock,
            window_ns,
            busy_since: AtomicU64::new(0),
            spans: Mutex::new(Vec::new()),
        }
    }

    /// Mark the start of a busy interval (task execution).
    pub fn busy(&self) {
        self.busy_since
            .store(self.clock.now_ns().max(1), Ordering::SeqCst);
    }

    /// Mark the end of the current busy interval (one request served).
    pub fn idle(&self) {
        self.idle_n(1);
    }

    /// Mark the end of the current busy interval, which served `n`
    /// requests (a micro-batch): the span is weighted by `n`, so an
    /// amortized batch execution reports the demand it absorbed instead
    /// of only its wall time — one unit per *request*, not one per
    /// worker invocation.
    pub fn idle_n(&self, n: u32) {
        let since = self.busy_since.swap(0, Ordering::SeqCst);
        if since == 0 {
            return;
        }
        let now = self.clock.now_ns();
        let mut spans = self.spans.lock().unwrap();
        spans.push(Span {
            start_ns: since,
            end_ns: now,
            weight: n.max(1),
        });
        // Garbage-collect spans that fell out of the window.
        let cutoff = now.saturating_sub(self.window_ns);
        spans.retain(|s| s.end_ns >= cutoff);
    }

    /// Busy fraction in [0, 1] over the trailing window (weighted spans
    /// can saturate it early; the cap keeps the §8.2 semantics "1.0 =
    /// fully loaded").
    pub fn value(&self) -> f64 {
        let now = self.clock.now_ns();
        let cutoff = now.saturating_sub(self.window_ns);
        let mut busy = 0u64;
        {
            let spans = self.spans.lock().unwrap();
            for s in spans.iter() {
                let start = s.start_ns.max(cutoff);
                if s.end_ns > start {
                    busy += (s.end_ns - start).saturating_mul(s.weight as u64);
                }
            }
        }
        // Include the in-flight busy interval, if any (its batch size is
        // unknown until it ends — weight 1 until then).
        let since = self.busy_since.load(Ordering::SeqCst);
        if since != 0 {
            busy += now.saturating_sub(since.max(cutoff));
        }
        let horizon = (now - cutoff).max(1);
        (busy as f64 / horizon as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ManualClock;

    fn setup(window: u64) -> (ManualClock, UtilizationWindow) {
        let clock = ManualClock::new();
        clock.set(1); // avoid t=0 edge
        let w = UtilizationWindow::new(Arc::new(clock.clone()), window);
        (clock, w)
    }

    #[test]
    fn idle_is_zero() {
        let (clock, w) = setup(1_000);
        clock.advance(10_000);
        assert_eq!(w.value(), 0.0);
    }

    #[test]
    fn fully_busy_is_one() {
        let (clock, w) = setup(1_000);
        clock.advance(5_000);
        w.busy();
        clock.advance(2_000);
        w.idle();
        // Window is the last 1000ns, entirely inside the busy span.
        assert!((w.value() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn half_busy() {
        let (clock, w) = setup(2_000);
        clock.advance(10_000);
        w.busy();
        clock.advance(1_000);
        w.idle(); // busy for the first half of the 2000ns window
        clock.advance(1_000);
        let v = w.value();
        assert!((v - 0.5).abs() < 0.01, "v={v}");
    }

    #[test]
    fn inflight_busy_counts() {
        let (clock, w) = setup(1_000);
        clock.advance(1_000);
        w.busy();
        clock.advance(500);
        let v = w.value(); // still busy, never called idle()
        assert!((v - 0.5).abs() < 0.01, "v={v}");
    }

    #[test]
    fn batched_span_counts_per_request() {
        // A batch of 4 served in 500 ns of a 2000 ns window: per-request
        // accounting reports 4×500/2000 = 1.0-capped demand, where
        // per-invocation accounting would claim a misleading 0.25.
        let (clock, w) = setup(2_000);
        clock.advance(2_000);
        w.busy();
        clock.advance(500);
        w.idle_n(4);
        clock.advance(1_500);
        assert!((w.value() - 1.0).abs() < 1e-9, "v={}", w.value());
        // Weight 1 degenerates to the unweighted fraction.
        let (clock, w) = setup(2_000);
        clock.advance(2_000);
        w.busy();
        clock.advance(500);
        w.idle_n(1);
        clock.advance(1_500);
        assert!((w.value() - 0.25).abs() < 0.01, "v={}", w.value());
    }

    #[test]
    fn old_spans_expire() {
        let (clock, w) = setup(1_000);
        clock.advance(1_000);
        w.busy();
        clock.advance(1_000);
        w.idle();
        clock.advance(10_000); // busy span far outside window now
        assert_eq!(w.value(), 0.0);
    }
}
