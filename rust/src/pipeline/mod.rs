//! Pipelining math (§5): Theorem 1 instance sizing, multi-stage chain
//! planning, and the discrete schedule tracer that regenerates the
//! paper's Figure 5 / Figure 6 gantt examples.
//!
//! Theorem 1: stages X (K parallel requests, time `T_X`) and Y (M
//! parallel, time `T_Y`, `T_X < T_Y`) produce at equal rates when
//! `M = ⌈K·T_Y/T_X⌉`; the steady-state output interval is `T_X/K`.

mod plan;
mod trace;

pub use plan::{instances_needed, plan_chain, ChainPlan, StagePlan, StageReq};
pub use trace::{trace_schedule, ScheduleEvent, ScheduleTrace, TraceStage};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem1_paper_examples() {
        // Fig 5: K=1 worker at T_X=4s, T_Y=12s -> M=3.
        assert_eq!(instances_needed(1, 4.0, 12.0), 3);
        // Fig 6: K=2 workers -> M=6.
        assert_eq!(instances_needed(2, 4.0, 12.0), 6);
    }

    #[test]
    fn theorem1_ceiling() {
        // M = ceil(K * T_Y / T_X).
        assert_eq!(instances_needed(1, 4.0, 10.0), 3); // 2.5 -> 3
        assert_eq!(instances_needed(3, 5.0, 7.0), 5); // 4.2 -> 5
    }

    #[test]
    fn faster_downstream_needs_one() {
        // T_Y <= T_X: one instance keeps up (theorem precondition is
        // T_X < T_Y; the planner still returns a sane answer).
        assert_eq!(instances_needed(1, 10.0, 5.0), 1);
    }
}
