//! Instance-count planning over a stage chain.

/// Theorem 1: instances needed at a downstream stage so its output rate
/// matches an upstream stage running `k` requests in parallel.
///
/// `M = ⌈k · t_down / t_up⌉` (at least 1).
pub fn instances_needed(k: usize, t_up_s: f64, t_down_s: f64) -> usize {
    assert!(k > 0 && t_up_s > 0.0 && t_down_s > 0.0);
    let m = (k as f64 * t_down_s / t_up_s).ceil() as usize;
    m.max(1)
}

/// A stage's requirements as declared in the workflow config.
#[derive(Debug, Clone)]
pub struct StageReq {
    pub name: String,
    /// Per-request execution time, seconds.
    pub exec_s: f64,
    /// GPUs consumed by one instance of this stage.
    pub gpus_per_instance: usize,
    /// Parallel requests one instance processes (workers in IM; 1 in CM).
    pub workers: usize,
}

/// Planned allocation for one stage.
#[derive(Debug, Clone, PartialEq)]
pub struct StagePlan {
    pub name: String,
    pub instances: usize,
    pub gpus: usize,
    /// Requests/second this allocation sustains.
    pub rate: f64,
}

/// Full pipeline plan.
#[derive(Debug, Clone)]
pub struct ChainPlan {
    pub stages: Vec<StagePlan>,
    /// Steady-state end-to-end output rate (requests/second).
    pub output_rate: f64,
    /// Steady-state output interval, seconds (1/rate).
    pub output_interval_s: f64,
    /// Pipeline fill latency for one request: sum of stage times.
    pub request_latency_s: f64,
    /// Total GPUs across all stages.
    pub total_gpus: usize,
}

/// Plan a multi-stage chain: given the entrance stage's instance count,
/// size every later stage with Theorem 1 (applied pairwise along the
/// chain: each stage must match the *entrance* throughput, which by
/// induction equals every intermediate throughput).
///
/// `entrance_instances` — instances of stage 0 (the paper's stage X).
pub fn plan_chain(stages: &[StageReq], entrance_instances: usize) -> ChainPlan {
    assert!(!stages.is_empty());
    let first = &stages[0];
    let k0 = entrance_instances * first.workers.max(1);
    // Entrance throughput: K/T_X requests per second (Theorem 1 proof).
    let rate = k0 as f64 / first.exec_s;

    let mut plans = Vec::with_capacity(stages.len());
    let mut total_gpus = 0usize;
    let mut latency = 0.0;
    for (i, s) in stages.iter().enumerate() {
        let instances = if i == 0 {
            entrance_instances
        } else {
            // Need `rate * exec_s` requests in flight; each instance
            // holds `workers` of them.
            let parallel = (rate * s.exec_s).ceil() as usize;
            parallel.div_ceil(s.workers.max(1)).max(1)
        };
        let gpus = instances * s.gpus_per_instance;
        total_gpus += gpus;
        latency += s.exec_s;
        let stage_rate = (instances * s.workers.max(1)) as f64 / s.exec_s;
        plans.push(StagePlan {
            name: s.name.clone(),
            instances,
            gpus,
            rate: stage_rate,
        });
    }

    // The chain's sustainable rate is the minimum stage rate (== entrance
    // rate when Theorem 1 sizing succeeded).
    let output_rate = plans.iter().map(|p| p.rate).fold(f64::INFINITY, f64::min);
    ChainPlan {
        stages: plans,
        output_rate,
        output_interval_s: 1.0 / output_rate,
        request_latency_s: latency,
        total_gpus,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wan_like() -> Vec<StageReq> {
        vec![
            StageReq { name: "t5_clip".into(), exec_s: 1.0, gpus_per_instance: 1, workers: 1 },
            StageReq { name: "vae_encode".into(), exec_s: 0.5, gpus_per_instance: 1, workers: 1 },
            StageReq { name: "diffusion".into(), exec_s: 12.0, gpus_per_instance: 4, workers: 1 },
            StageReq { name: "vae_decode".into(), exec_s: 1.5, gpus_per_instance: 1, workers: 1 },
        ]
    }

    #[test]
    fn fig5_chain() {
        // Two stages: X (4s, 1 worker) and Y (12s) -> Y needs 3 instances,
        // output every 4s.
        let stages = vec![
            StageReq { name: "x".into(), exec_s: 4.0, gpus_per_instance: 1, workers: 1 },
            StageReq { name: "y".into(), exec_s: 12.0, gpus_per_instance: 1, workers: 1 },
        ];
        let plan = plan_chain(&stages, 1);
        assert_eq!(plan.stages[1].instances, 3);
        assert!((plan.output_interval_s - 4.0).abs() < 1e-9);
        assert!((plan.request_latency_s - 16.0).abs() < 1e-9);
    }

    #[test]
    fn fig6_chain_two_workers() {
        let stages = vec![
            StageReq { name: "x".into(), exec_s: 4.0, gpus_per_instance: 1, workers: 2 },
            StageReq { name: "y".into(), exec_s: 12.0, gpus_per_instance: 1, workers: 1 },
        ];
        let plan = plan_chain(&stages, 1);
        assert_eq!(plan.stages[1].instances, 6);
        assert!((plan.output_interval_s - 2.0).abs() < 1e-9);
    }

    #[test]
    fn wan_pipeline_balances() {
        let plan = plan_chain(&wan_like(), 1);
        // Entrance rate 1 req/s; diffusion (12 s) needs 12 instances.
        assert_eq!(plan.stages[2].instances, 12);
        // VAE decode (1.5 s) needs 2.
        assert_eq!(plan.stages[3].instances, 2);
        // Every stage sustains >= output rate.
        for s in &plan.stages {
            assert!(s.rate >= plan.output_rate - 1e-9, "{s:?}");
        }
        assert!((plan.output_rate - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gpu_accounting() {
        let plan = plan_chain(&wan_like(), 1);
        // 1*1 + 1*1 + 12*4 + 2*1 = 52 GPUs.
        assert_eq!(plan.total_gpus, 52);
    }

    #[test]
    fn multi_worker_stage_downstream() {
        // Downstream with 4 workers per instance needs fewer instances.
        let stages = vec![
            StageReq { name: "x".into(), exec_s: 1.0, gpus_per_instance: 1, workers: 1 },
            StageReq { name: "y".into(), exec_s: 8.0, gpus_per_instance: 1, workers: 4 },
        ];
        let plan = plan_chain(&stages, 1);
        assert_eq!(plan.stages[1].instances, 2); // 8 parallel / 4 workers
    }
}
