//! Discrete schedule tracer: simulates the two-stage pipeline examples of
//! Figures 5 and 6 exactly (who runs which request when) so the E2 bench
//! can print the same gantt the paper draws.

/// Stage description for tracing.
#[derive(Debug, Clone)]
pub struct TraceStage {
    pub name: String,
    pub exec_s: f64,
    pub instances: usize,
    /// Parallel requests per instance (workers).
    pub workers: usize,
}

/// One execution span: request `req` ran on `(stage, instance, worker)`
/// during `[start_s, end_s)`.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleEvent {
    pub stage: usize,
    pub instance: usize,
    pub worker: usize,
    pub req: usize,
    pub start_s: f64,
    pub end_s: f64,
}

/// Full trace of a pipelined run.
#[derive(Debug, Clone)]
pub struct ScheduleTrace {
    pub events: Vec<ScheduleEvent>,
    /// Completion time of each request (by request index).
    pub completions: Vec<f64>,
    /// Steady-state interval between final-stage outputs.
    pub output_interval_s: f64,
}

/// Simulate `n_requests` flowing through a chain of stages, with the
/// entrance stage admitting a new request every `admit_interval_s`
/// (the proxy's Theorem-1 rate) and each later stage starting a request
/// as soon as (a) its predecessor finished it and (b) a worker is free.
pub fn trace_schedule(
    stages: &[TraceStage],
    n_requests: usize,
    admit_interval_s: f64,
) -> ScheduleTrace {
    let mut events = Vec::new();
    // ready[r] = when request r becomes available to the current stage.
    let mut ready: Vec<f64> = (0..n_requests)
        .map(|r| r as f64 * admit_interval_s)
        .collect();

    for (si, stage) in stages.iter().enumerate() {
        // worker_free[(instance, worker)] = next free time.
        let mut worker_free =
            vec![vec![0.0f64; stage.workers.max(1)]; stage.instances.max(1)];
        let mut done = vec![0.0f64; n_requests];
        for (r, &t_ready) in ready.iter().enumerate() {
            // Earliest-free worker (round-robin tiebreak = RD round-robin
            // delivery + IM pull queue behaviour).
            let (mut bi, mut bw, mut bt) = (0usize, 0usize, f64::INFINITY);
            for (i, inst) in worker_free.iter().enumerate() {
                for (w, &t) in inst.iter().enumerate() {
                    if t < bt {
                        (bi, bw, bt) = (i, w, t);
                    }
                }
            }
            let start = t_ready.max(bt);
            let end = start + stage.exec_s;
            worker_free[bi][bw] = end;
            done[r] = end;
            events.push(ScheduleEvent {
                stage: si,
                instance: bi,
                worker: bw,
                req: r,
                start_s: start,
                end_s: end,
            });
        }
        ready = done;
    }

    let completions = ready;
    let output_interval_s = if n_requests >= 2 {
        // Median gap over the steady-state tail.
        let tail = &completions[n_requests / 2..];
        if tail.len() >= 2 {
            (tail[tail.len() - 1] - tail[0]) / (tail.len() - 1) as f64
        } else {
            completions[1] - completions[0]
        }
    } else {
        0.0
    };

    ScheduleTrace { events, completions, output_interval_s }
}

impl ScheduleTrace {
    /// Render an ASCII gantt like the paper's Figure 5/6 (1 column per
    /// `tick_s` seconds).
    pub fn render_gantt(&self, stages: &[TraceStage], tick_s: f64) -> String {
        let horizon = self
            .events
            .iter()
            .map(|e| e.end_s)
            .fold(0.0f64, f64::max);
        let cols = (horizon / tick_s).ceil() as usize;
        let mut out = String::new();
        for (si, stage) in stages.iter().enumerate() {
            out.push_str(&format!("Stage {} ({})\n", si, stage.name));
            for i in 0..stage.instances {
                for w in 0..stage.workers.max(1) {
                    let mut row = vec![b'.'; cols];
                    for e in self
                        .events
                        .iter()
                        .filter(|e| e.stage == si && e.instance == i && e.worker == w)
                    {
                        let c0 = (e.start_s / tick_s) as usize;
                        let c1 = ((e.end_s / tick_s).ceil() as usize).min(cols);
                        let ch = char::from(b'0' + (e.req % 10) as u8) as u8;
                        for c in row.iter_mut().take(c1).skip(c0) {
                            *c = ch;
                        }
                    }
                    out.push_str(&format!(
                        "  inst{:>2}/w{} |{}|\n",
                        i,
                        w,
                        String::from_utf8(row).unwrap()
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig5_stages() -> Vec<TraceStage> {
        vec![
            TraceStage { name: "X".into(), exec_s: 4.0, instances: 1, workers: 1 },
            TraceStage { name: "Y".into(), exec_s: 12.0, instances: 3, workers: 1 },
        ]
    }

    #[test]
    fn fig5_output_every_4s() {
        let trace = trace_schedule(&fig5_stages(), 9, 4.0);
        // Steady state: one output every 4 s (the paper's claim).
        assert!(
            (trace.output_interval_s - 4.0).abs() < 1e-9,
            "interval={}",
            trace.output_interval_s
        );
        // First request: T_X + T_Y = 16 s, no queueing anywhere.
        assert!((trace.completions[0] - 16.0).abs() < 1e-9);
        // No request waits inside the pipeline: completion = admit + 16.
        for (r, &c) in trace.completions.iter().enumerate() {
            assert!((c - (r as f64 * 4.0 + 16.0)).abs() < 1e-9, "req {r}: {c}");
        }
    }

    #[test]
    fn fig6_output_every_2s() {
        let stages = vec![
            TraceStage { name: "X".into(), exec_s: 4.0, instances: 1, workers: 2 },
            TraceStage { name: "Y".into(), exec_s: 12.0, instances: 6, workers: 1 },
        ];
        let trace = trace_schedule(&stages, 12, 2.0);
        assert!(
            (trace.output_interval_s - 2.0).abs() < 1e-9,
            "interval={}",
            trace.output_interval_s
        );
    }

    #[test]
    fn undersized_downstream_queues() {
        // Only 2 Y instances instead of the Theorem-1 three: output
        // interval degrades to T_Y/2 = 6 s.
        let stages = vec![
            TraceStage { name: "X".into(), exec_s: 4.0, instances: 1, workers: 1 },
            TraceStage { name: "Y".into(), exec_s: 12.0, instances: 2, workers: 1 },
        ];
        let trace = trace_schedule(&stages, 10, 4.0);
        assert!(
            (trace.output_interval_s - 6.0).abs() < 0.5,
            "interval={}",
            trace.output_interval_s
        );
    }

    #[test]
    fn gantt_renders() {
        let stages = fig5_stages();
        let trace = trace_schedule(&stages, 6, 4.0);
        let g = trace.render_gantt(&stages, 4.0);
        assert!(g.contains("Stage 0 (X)"));
        assert!(g.contains("Stage 1 (Y)"));
        // Three Y instance rows.
        assert_eq!(g.matches("inst").count(), 1 + 3);
    }

    #[test]
    fn single_request_latency() {
        let trace = trace_schedule(&fig5_stages(), 1, 4.0);
        assert_eq!(trace.completions.len(), 1);
        assert!((trace.completions[0] - 16.0).abs() < 1e-9);
    }
}
