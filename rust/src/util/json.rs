//! Minimal JSON parser/serializer (the offline build has no serde).
//! Supports the full JSON grammar minus exotic number forms; used for
//! `artifacts/manifest.json` and the cluster config files.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: &'static str,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as u64 (must be a non-negative integer).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Array items.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Bool value.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &'static str) -> JsonError {
        JsonError { pos: self.pos, msg }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err("unexpected character"))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_u64(), Some(2));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☂\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☂"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"stages":{"x":{"inputs":[{"shape":[256,16],"dtype":"float32"}]}},"n":-2.5,"t":true}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn manifest_shape() {
        // Mirrors aot.py's manifest structure.
        let m = Json::parse(
            r#"{"dims": {"d_latent": 16}, "stages": {"vae_encode":
               {"inputs": [{"name": "image", "dtype": "float32",
                "shape": [32, 32, 3]}],
                "output": {"dtype": "float32", "shape": [64, 16]},
                "file": "vae_encode.hlo.txt"}}}"#,
        )
        .unwrap();
        let stage = m.get("stages").unwrap().get("vae_encode").unwrap();
        let input = &stage.get("inputs").unwrap().as_arr().unwrap()[0];
        let shape: Vec<u64> = input
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_u64().unwrap())
            .collect();
        assert_eq!(shape, vec![32, 32, 3]);
    }
}
