//! Small shared utilities: request UIDs, monotonic time, CRC32, and
//! little-endian buffer codecs used by the zero-dependency wire format.

mod checksum;
mod codec;
mod id;
mod json;
mod rng;
mod time;

pub use checksum::{crc32, frame_checksum};
pub use codec::{BufReader, BufWriter, CodecError};
pub use id::{NodeId, Uid};
pub use json::{Json, JsonError};
pub use rng::{backoff_ns, Rng};
pub use time::{now_ns, Clock, ManualClock, SystemClock};
