//! CRC32 (IEEE 802.3 polynomial, reflected) — used by the ring buffer to
//! detect the "delayed sender overwrote a live entry" corruption described
//! in §6.1 of the paper.
//!
//! Implementation: **slicing-by-8** — eight 256-entry tables built in a
//! `const fn`, processing 8 input bytes per step. ~8× the throughput of
//! the classic bytewise loop, which dominated the ring-buffer hot path
//! before this change (EXPERIMENTS.md §Perf: 47.7 µs → ~6 µs per 16 KiB
//! frame on the test host). No external dependency.

const fn build_tables() -> [[u32; 256]; 8] {
    let mut t = [[0u32; 256]; 8];
    // Table 0: classic bit-by-bit.
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        t[0][i] = crc;
        i += 1;
    }
    // Tables 1..8: t[k][i] = one more byte of zeros folded in.
    let mut k = 1;
    while k < 8 {
        let mut i = 0;
        while i < 256 {
            t[k][i] = (t[k - 1][i] >> 8) ^ t[0][(t[k - 1][i] & 0xFF) as usize];
            i += 1;
        }
        k += 1;
    }
    t
}

static TABLES: [[u32; 256]; 8] = build_tables();

/// CRC32 of `data` (IEEE, init all-ones, final xor all-ones).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    let mut chunks = data.chunks_exact(8);
    for c in chunks.by_ref() {
        let lo = u32::from_le_bytes(c[0..4].try_into().unwrap()) ^ crc;
        let hi = u32::from_le_bytes(c[4..8].try_into().unwrap());
        crc = TABLES[7][(lo & 0xFF) as usize]
            ^ TABLES[6][((lo >> 8) & 0xFF) as usize]
            ^ TABLES[5][((lo >> 16) & 0xFF) as usize]
            ^ TABLES[4][((lo >> 24) & 0xFF) as usize]
            ^ TABLES[3][(hi & 0xFF) as usize]
            ^ TABLES[2][((hi >> 8) & 0xFF) as usize]
            ^ TABLES[1][((hi >> 16) & 0xFF) as usize]
            ^ TABLES[0][((hi >> 24) & 0xFF) as usize];
    }
    for &b in chunks.remainder() {
        crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// Protocol frame checksum: hardware CRC32C (SSE4.2, ~20 GB/s) when
/// available, else the software IEEE CRC32. The ring-buffer protocol only
/// needs *self-consistency within a process*, so the polynomial choice is
/// free — feature detection is stable for the process lifetime.
pub fn frame_checksum(data: &[u8]) -> u32 {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("sse4.2") {
            // SAFETY: guarded by the feature check above.
            return unsafe { crc32c_hw(data) };
        }
    }
    crc32(data)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.2")]
unsafe fn crc32c_hw(data: &[u8]) -> u32 {
    use std::arch::x86_64::{_mm_crc32_u64, _mm_crc32_u8};
    let mut crc = 0xFFFF_FFFFu64;
    let mut chunks = data.chunks_exact(8);
    for c in chunks.by_ref() {
        let mut b = [0u8; 8];
        b.copy_from_slice(c);
        // SAFETY: caller guarantees SSE4.2 (the `#[target_feature]`
        // contract); the intrinsic itself has no other preconditions.
        crc = unsafe { _mm_crc32_u64(crc, u64::from_le_bytes(b)) };
    }
    let mut crc = crc as u32;
    for &b in chunks.remainder() {
        // SAFETY: as above.
        crc = unsafe { _mm_crc32_u8(crc, b) };
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference bytewise implementation for differential testing.
    fn crc32_bytewise(data: &[u8]) -> u32 {
        let mut crc = 0xFFFF_FFFFu32;
        for &b in data {
            crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
        }
        !crc
    }

    #[test]
    fn known_vectors() {
        // Standard test vector for CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn matches_bytewise_all_lengths() {
        // Differential test across alignment/length boundaries.
        let data: Vec<u8> = (0..1024u32).map(|i| (i.wrapping_mul(31) % 251) as u8).collect();
        for len in 0..128 {
            assert_eq!(crc32(&data[..len]), crc32_bytewise(&data[..len]), "len={len}");
        }
        assert_eq!(crc32(&data), crc32_bytewise(&data));
    }

    #[test]
    fn sensitive_to_single_bit() {
        let a = crc32(b"hello world");
        let b = crc32(b"hello worle");
        assert_ne!(a, b);
    }

    #[test]
    fn long_input() {
        let data: Vec<u8> = (0..65536u32).map(|i| (i % 251) as u8).collect();
        let c1 = crc32(&data);
        let mut flipped = data.clone();
        flipped[40000] ^= 0x80;
        assert_ne!(c1, crc32(&flipped));
    }

    #[test]
    fn frame_checksum_detects_corruption() {
        let data: Vec<u8> = (0..4096u32).map(|i| (i % 256) as u8).collect();
        let c1 = frame_checksum(&data);
        assert_eq!(c1, frame_checksum(&data), "deterministic");
        let mut flipped = data.clone();
        flipped[1000] ^= 1;
        assert_ne!(c1, frame_checksum(&flipped));
        // Empty and odd lengths work.
        assert_eq!(frame_checksum(b""), frame_checksum(b""));
        assert_ne!(frame_checksum(b"abc"), frame_checksum(b"abd"));
    }
}
