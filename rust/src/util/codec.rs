//! Minimal little-endian wire codecs for the zero-dependency message
//! format ([`crate::transport::WorkflowMessage`]). Hot-path friendly: the
//! writer appends into a caller-owned `Vec<u8>` (reusable across sends)
//! and the reader borrows without copying until payload extraction.

use std::fmt;

/// Decode error (truncated or malformed buffer).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodecError(pub &'static str);

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "codec error: {}", self.0)
    }
}

impl std::error::Error for CodecError {}

/// Append-only little-endian writer over a caller-owned buffer.
pub struct BufWriter<'a> {
    buf: &'a mut Vec<u8>,
}

impl<'a> BufWriter<'a> {
    /// Wrap `buf`, appending after its current contents.
    pub fn new(buf: &'a mut Vec<u8>) -> Self {
        Self { buf }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed byte slice.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Raw bytes, no length prefix.
    pub fn put_raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// f32 slice as raw LE words, length-prefixed by element count.
    pub fn put_f32s(&mut self, v: &[f32]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Borrowing little-endian reader with position tracking.
pub struct BufReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BufReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.remaining() < n {
            return Err(CodecError("truncated buffer"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_u128(&mut self) -> Result<u128, CodecError> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    /// Length-prefixed byte slice (borrowed).
    pub fn get_bytes(&mut self) -> Result<&'a [u8], CodecError> {
        let n = self.get_u32()? as usize;
        self.take(n)
    }

    /// f32 slice written by [`BufWriter::put_f32s`].
    pub fn get_f32s(&mut self) -> Result<Vec<f32>, CodecError> {
        let n = self.get_u32()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or(CodecError("f32 len overflow"))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        let mut buf = Vec::new();
        let mut w = BufWriter::new(&mut buf);
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_u128(u128::MAX / 3);
        let mut r = BufReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_u128().unwrap(), u128::MAX / 3);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn roundtrip_bytes_and_floats() {
        let mut buf = Vec::new();
        let mut w = BufWriter::new(&mut buf);
        w.put_bytes(b"payload");
        w.put_f32s(&[1.5, -2.25, 0.0, f32::MIN_POSITIVE]);
        let mut r = BufReader::new(&buf);
        assert_eq!(r.get_bytes().unwrap(), b"payload");
        assert_eq!(r.get_f32s().unwrap(), vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE]);
    }

    #[test]
    fn truncated_errors() {
        let mut buf = Vec::new();
        BufWriter::new(&mut buf).put_u64(5);
        let mut r = BufReader::new(&buf[..4]);
        assert!(r.get_u64().is_err());
    }

    #[test]
    fn empty_bytes() {
        let mut buf = Vec::new();
        BufWriter::new(&mut buf).put_bytes(b"");
        let mut r = BufReader::new(&buf);
        assert_eq!(r.get_bytes().unwrap(), b"");
    }
}
