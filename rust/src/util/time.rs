//! Time sources. Production code uses [`SystemClock`]; deterministic tests
//! and the discrete-event resource simulator use [`ManualClock`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{SystemTime, UNIX_EPOCH};

/// Nanoseconds since the Unix epoch (wall clock — used for message
/// timestamps in headers, matching the paper's proxy-stamped timestamp).
pub fn now_ns() -> u128 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .expect("clock before epoch")
        .as_nanos()
}

/// Abstract monotonic clock, injectable for deterministic tests.
pub trait Clock: Send + Sync + 'static {
    /// Monotonic nanoseconds.
    fn now_ns(&self) -> u64;
}

/// Real monotonic clock.
#[derive(Clone, Default)]
pub struct SystemClock;

impl Clock for SystemClock {
    fn now_ns(&self) -> u64 {
        use std::time::Instant;
        use std::sync::OnceLock;
        static EPOCH: OnceLock<Instant> = OnceLock::new();
        let epoch = *EPOCH.get_or_init(Instant::now);
        Instant::now().duration_since(epoch).as_nanos() as u64
    }
}

/// Hand-advanced clock for deterministic protocol tests.
#[derive(Clone, Default)]
pub struct ManualClock(Arc<AtomicU64>);

impl ManualClock {
    /// New clock starting at t=0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance by `ns` nanoseconds.
    pub fn advance(&self, ns: u64) {
        self.0.fetch_add(ns, Ordering::SeqCst);
    }

    /// Set the absolute time.
    pub fn set(&self, ns: u64) {
        self.0.store(ns, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_ns(&self) -> u64 {
        self.0.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_monotonic() {
        let c = SystemClock;
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn manual_clock_advances() {
        let c = ManualClock::new();
        assert_eq!(c.now_ns(), 0);
        c.advance(1_000);
        assert_eq!(c.now_ns(), 1_000);
        c.set(5);
        assert_eq!(c.now_ns(), 5);
    }

    #[test]
    fn wall_clock_sane() {
        // After 2020, before 2100.
        let ns = now_ns();
        assert!(ns > 1_577_836_800_000_000_000);
        assert!(ns < 4_102_444_800_000_000_000);
    }
}
