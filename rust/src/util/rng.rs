//! Deterministic PRNG + arrival-process samplers (zero-dependency —
//! the offline build has no `rand`). xoshiro256** core; quality is far
//! beyond what workload generation needs and it is fully reproducible
//! from a seed, which the experiments require.

/// Seedable xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (any u64 is a fine seed, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n) (n > 0).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Rejection-free multiply-shift (Lemire); bias negligible here.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Exponential with rate `lambda` (mean 1/lambda) — Poisson-process
    /// inter-arrival times.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda` (Knuth for small
    /// lambda, normal approximation above 64).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let n = lambda + lambda.sqrt() * self.gaussian();
            return n.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Standard normal (Box–Muller).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> Option<&'a T> {
        if v.is_empty() {
            None
        } else {
            Some(&v[self.below(v.len() as u64) as usize])
        }
    }
}

/// Seeded-jitter exponential backoff: attempt `attempt`'s wait in
/// nanoseconds, uniformly jittered over `[exp/2, exp)` where
/// `exp = min(cap_ns, base_ns << attempt)` ("equal jitter").
///
/// The jitter is a pure function of `(seed, attempt)` (one SplitMix64
/// step), so every retry loop in the crate — `client::retry_rounds`,
/// the `RdmaSender` ring-full loop, the producer verb-retry loop —
/// shares this one helper and still replays deterministically, while
/// distinct seeds desynchronize concurrent retriers: without jitter, N
/// senders that collide once would all sleep the same fixed delay and
/// collide forever (a synchronized retry storm).
pub fn backoff_ns(seed: u64, attempt: u32, base_ns: u64, cap_ns: u64) -> u64 {
    let base = base_ns.max(1);
    let exp = base
        .saturating_mul(1u64 << attempt.min(63))
        .min(cap_ns.max(base));
    // One SplitMix64 step over (seed, attempt) — no state to thread.
    let mut z = seed ^ (attempt as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    let half = exp / 2;
    half + z % half.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(6);
        let n = 20_000;
        for lambda in [0.5, 4.0, 100.0] {
            let mean: f64 =
                (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.06,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(8);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn backoff_grows_caps_and_jitters() {
        // Deterministic for a (seed, attempt) pair.
        assert_eq!(backoff_ns(7, 3, 1_000, 1 << 30), backoff_ns(7, 3, 1_000, 1 << 30));
        // Different seeds desynchronize (the whole point).
        assert_ne!(backoff_ns(1, 3, 1_000, 1 << 30), backoff_ns(2, 3, 1_000, 1 << 30));
        // Equal-jitter bounds: [exp/2, exp).
        for attempt in 0..10 {
            let exp = 1_000u64 << attempt;
            let w = backoff_ns(42, attempt, 1_000, 1 << 40);
            assert!(w >= exp / 2 && w < exp, "attempt={attempt} w={w}");
        }
        // Cap holds for huge attempts (no overflow, no unbounded sleep).
        let w = backoff_ns(42, 200, 1_000, 1_000_000);
        assert!(w >= 500_000 && w < 1_000_000, "w={w}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
