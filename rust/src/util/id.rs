//! Identifiers: the request [`Uid`] assigned by proxies (§3.2 of the
//! paper — tracks a generation request through its whole lifecycle) and
//! the cluster-wide [`NodeId`].

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Unique request identifier. The paper's proxies assign a UUID; we use a
/// 128-bit value composed of (proxy id, per-proxy counter, timestamp
/// entropy) which has the same uniqueness property without an extra
/// dependency, and is `Copy` for the hot path.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Uid(pub u128);

static UID_COUNTER: AtomicU64 = AtomicU64::new(1);

impl Uid {
    /// Allocate a fresh UID on behalf of `proxy`. Unique across all proxies
    /// in the process (and, with the timestamp entropy, across restarts).
    pub fn fresh(proxy: NodeId) -> Self {
        let seq = UID_COUNTER.fetch_add(1, Ordering::Relaxed);
        let ts = crate::util::now_ns() as u64;
        Uid(((proxy.0 as u128) << 96) | ((seq as u128) << 32) | (ts as u128 & 0xFFFF_FFFF))
    }

    /// The proxy that issued this UID.
    pub fn proxy(&self) -> NodeId {
        NodeId((self.0 >> 96) as u32)
    }
}

impl fmt::Debug for Uid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Uid({:032x})", self.0)
    }
}

impl fmt::Display for Uid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:032x}", self.0)
    }
}

/// Logical node (machine) identifier within the cluster.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct NodeId(pub u32);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn uids_unique() {
        let proxy = NodeId(3);
        let uids: HashSet<Uid> = (0..10_000).map(|_| Uid::fresh(proxy)).collect();
        assert_eq!(uids.len(), 10_000);
    }

    #[test]
    fn uid_encodes_proxy() {
        assert_eq!(Uid::fresh(NodeId(42)).proxy(), NodeId(42));
    }

    #[test]
    fn uid_unique_across_proxies() {
        let a = Uid::fresh(NodeId(1));
        let b = Uid::fresh(NodeId(2));
        assert_ne!(a, b);
    }
}
