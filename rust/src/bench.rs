//! Minimal benchmarking harness (the offline build has no criterion).
//!
//! Provides warmup + timed iterations with mean/p50/p95/p99 reporting,
//! and a tabular reporter the `benches/e*.rs` binaries share so every
//! experiment prints paper-style rows. Wall-clock based; for modelled
//! results (fabric latency) the benches read simulated-ns counters
//! instead.
//!
//! Every experiment additionally writes a machine-readable
//! `BENCH_<name>.json` via [`Report`] — a flat `metric → value` map —
//! so the performance trajectory of the repo can be tracked across
//! commits instead of living only in scrollback.

use crate::util::Json;
use std::time::{Duration, Instant};

/// Result of one timed benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    /// Throughput in ops/second at the mean.
    pub fn ops_per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` repeatedly: warm up for `warmup`, then time individual
/// iterations for at least `measure` (and at least 10 samples).
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, mut f: F) -> BenchResult {
    let wstart = Instant::now();
    while wstart.elapsed() < warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let mstart = Instant::now();
    while mstart.elapsed() < measure || samples.len() < 10 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let result = BenchResult {
        iters: n as u64,
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        p50_ns: samples[n / 2],
        p95_ns: samples[(n * 95 / 100).min(n - 1)],
        p99_ns: samples[(n * 99 / 100).min(n - 1)],
        min_ns: samples[0],
    };
    println!(
        "{name:<44} {:>10} {:>10} {:>10}  ({} iters)",
        fmt_ns(result.mean_ns),
        fmt_ns(result.p50_ns),
        fmt_ns(result.p99_ns),
        result.iters
    );
    result
}

/// Print the standard bench table header.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!("{:<44} {:>10} {:>10} {:>10}", "benchmark", "mean", "p50", "p99");
}

/// Quick defaults used by the e*.rs benches.
pub fn quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, Duration::from_millis(100), Duration::from_millis(400), f)
}

/// Machine-readable experiment report. Collects named scalar metrics
/// and writes `BENCH_<name>.json` into the working directory (the repo
/// root under `cargo bench`), alongside the human-readable table:
///
/// ```json
/// {"bench": "e14_microbatch", "metrics": {"batch_tier_speedup": 2.6}}
/// ```
pub struct Report {
    name: String,
    metrics: Vec<(String, f64)>,
}

impl Report {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), metrics: Vec::new() }
    }

    /// Record one scalar. Non-finite values are skipped (they would
    /// break the JSON) — absent keys are the "could not measure" signal.
    pub fn add(&mut self, metric: impl Into<String>, value: f64) -> &mut Self {
        if value.is_finite() {
            self.metrics.push((metric.into(), value));
        }
        self
    }

    /// Record a [`BenchResult`]'s headline numbers under
    /// `<prefix>.{mean_ns,p50_ns,p99_ns,ops_per_sec}`.
    pub fn add_result(&mut self, prefix: &str, r: &BenchResult) -> &mut Self {
        self.add(format!("{prefix}.mean_ns"), r.mean_ns)
            .add(format!("{prefix}.p50_ns"), r.p50_ns)
            .add(format!("{prefix}.p99_ns"), r.p99_ns)
            .add(format!("{prefix}.ops_per_sec"), r.ops_per_sec())
    }

    /// The report as a JSON document.
    pub fn to_json(&self) -> Json {
        let metrics: std::collections::BTreeMap<String, Json> = self
            .metrics
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect();
        let mut root = std::collections::BTreeMap::new();
        root.insert("bench".to_string(), Json::Str(self.name.clone()));
        root.insert("metrics".to_string(), Json::Obj(metrics));
        Json::Obj(root)
    }

    /// Write `BENCH_<name>.json` and print where it went. Benches call
    /// this last; an unwritable working directory fails the bench (a
    /// silently missing perf record is worse than a loud one).
    pub fn write(&self) {
        let path = format!("BENCH_{}.json", self.name);
        std::fs::write(&path, self.to_json().to_string_compact() + "\n")
            .unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("\nmachine-readable results: {path}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench(
            "noop-spin",
            Duration::from_millis(1),
            Duration::from_millis(10),
            || {
                std::hint::black_box(1 + 1);
            },
        );
        assert!(r.iters >= 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns);
        assert!(r.min_ns <= r.p50_ns);
    }

    #[test]
    fn report_serializes_and_skips_non_finite() {
        let mut r = Report::new("unit");
        r.add("a", 1.5).add("nan", f64::NAN).add("inf", f64::INFINITY);
        r.add_result(
            "b",
            &BenchResult {
                iters: 1,
                mean_ns: 2e6,
                p50_ns: 2e6,
                p95_ns: 2e6,
                p99_ns: 3e6,
                min_ns: 1e6,
            },
        );
        let j = r.to_json();
        let back = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(back.get("bench").and_then(Json::as_str), Some("unit"));
        let m = back.get("metrics").unwrap();
        assert_eq!(m.get("a").and_then(Json::as_f64), Some(1.5));
        assert_eq!(m.get("b.p99_ns").and_then(Json::as_f64), Some(3e6));
        assert!((m.get("b.ops_per_sec").and_then(Json::as_f64).unwrap() - 500.0).abs() < 1e-9);
        assert!(m.get("nan").is_none(), "non-finite values are dropped");
        assert!(m.get("inf").is_none());
    }

    #[test]
    fn ops_per_sec_inverse_of_mean() {
        let r = BenchResult {
            iters: 1,
            mean_ns: 1e6,
            p50_ns: 1e6,
            p95_ns: 1e6,
            p99_ns: 1e6,
            min_ns: 1e6,
        };
        assert!((r.ops_per_sec() - 1000.0).abs() < 1e-9);
    }
}
