//! Minimal benchmarking harness (the offline build has no criterion).
//!
//! Provides warmup + timed iterations with mean/p50/p95/p99 reporting,
//! and a tabular reporter the `benches/e*.rs` binaries share so every
//! experiment prints paper-style rows. Wall-clock based; for modelled
//! results (fabric latency) the benches read simulated-ns counters
//! instead.

use std::time::{Duration, Instant};

/// Result of one timed benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    /// Throughput in ops/second at the mean.
    pub fn ops_per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` repeatedly: warm up for `warmup`, then time individual
/// iterations for at least `measure` (and at least 10 samples).
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, mut f: F) -> BenchResult {
    let wstart = Instant::now();
    while wstart.elapsed() < warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let mstart = Instant::now();
    while mstart.elapsed() < measure || samples.len() < 10 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let result = BenchResult {
        iters: n as u64,
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        p50_ns: samples[n / 2],
        p95_ns: samples[(n * 95 / 100).min(n - 1)],
        p99_ns: samples[(n * 99 / 100).min(n - 1)],
        min_ns: samples[0],
    };
    println!(
        "{name:<44} {:>10} {:>10} {:>10}  ({} iters)",
        fmt_ns(result.mean_ns),
        fmt_ns(result.p50_ns),
        fmt_ns(result.p99_ns),
        result.iters
    );
    result
}

/// Print the standard bench table header.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!("{:<44} {:>10} {:>10} {:>10}", "benchmark", "mean", "p50", "p99");
}

/// Quick defaults used by the e*.rs benches.
pub fn quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, Duration::from_millis(100), Duration::from_millis(400), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench(
            "noop-spin",
            Duration::from_millis(1),
            Duration::from_millis(10),
            || {
                std::hint::black_box(1 + 1);
            },
        );
        assert!(r.iters >= 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns);
        assert!(r.min_ns <= r.p50_ns);
    }

    #[test]
    fn ops_per_sec_inverse_of_mean() {
        let r = BenchResult {
            iters: 1,
            mean_ns: 1e6,
            p50_ns: 1e6,
            p95_ns: 1e6,
            p99_ns: 1e6,
            min_ns: 1e6,
        };
        assert!((r.ops_per_sec() - 1000.0).abs() < 1e-9);
    }
}
