//! Stage executors: the compute plug-in for TaskWorkers.

use super::PjrtRuntime;
use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

/// A tensor argument for stage execution.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorValue {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            TensorValue::F32(v) => v.len(),
            TensorValue::I32(v) => v.len(),
        }
    }

    /// True if no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Build an xla literal with the manifest shape.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let expected: usize = shape.iter().product();
        anyhow::ensure!(
            self.len() == expected,
            "shape {:?} wants {} elems, got {}",
            shape,
            expected,
            self.len()
        );
        let lit = match self {
            TensorValue::F32(v) => xla::Literal::vec1(v),
            TensorValue::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }
}

/// How a TaskWorker executes its stage.
#[derive(Clone)]
pub enum StageExecutor {
    /// Real compute: a stage executable in a shared PJRT runtime.
    Pjrt { runtime: Arc<PjrtRuntime>, stage: String },
    /// Calibrated busy-wait (resource-scale sims; models a GPU being
    /// occupied without doing the math).
    Simulated { busy: Duration },
}

impl StageExecutor {
    /// Run once over the inputs; returns the output tensor (empty for
    /// simulated executors).
    pub fn run(&self, inputs: &[TensorValue]) -> Result<Vec<f32>> {
        match self {
            StageExecutor::Pjrt { runtime, stage } => runtime.execute(stage, inputs),
            StageExecutor::Simulated { busy } => {
                // Sleep, not spin: a simulated executor models the *GPU*
                // being occupied while the host CPU is free — exactly the
                // paper's execution model — and lets hundreds of logical
                // GPUs coexist on few host cores.
                if !busy.is_zero() {
                    std::thread::sleep(*busy);
                }
                Ok(Vec::new())
            }
        }
    }

    /// True for the calibrated busy-sleep executor.
    pub fn is_simulated(&self) -> bool {
        matches!(self, StageExecutor::Simulated { .. })
    }

    /// Execute a micro-batch of `batch` requests in **one** invocation
    /// under the amortized cost model: `fixed_frac` of the per-request
    /// cost is per-invocation overhead (weight streaming, kernel launch,
    /// dispatch) paid once per batch, and the remainder scales per
    /// member — `cost(n) = busy × (fixed_frac + (1 − fixed_frac) × n)`,
    /// so a full batch approaches a `1 / (1 − fixed_frac)` speed-up over
    /// per-request execution. Simulated executors sleep the amortized
    /// duration; PJRT stage artifacts are traced at batch = 1 and have
    /// no batched entry point, so callers fall back to per-member
    /// [`StageExecutor::run`] there.
    pub fn run_amortized(&self, batch: usize, fixed_frac: f64) -> Result<()> {
        match self {
            StageExecutor::Simulated { busy } => {
                let frac = fixed_frac.clamp(0.0, 1.0);
                let scale = frac + (1.0 - frac) * batch.max(1) as f64;
                let d = busy.mul_f64(scale);
                if !d.is_zero() {
                    std::thread::sleep(d);
                }
                Ok(())
            }
            StageExecutor::Pjrt { stage, .. } => anyhow::bail!(
                "stage {stage}: PJRT artifacts execute per request (batch=1 traces)"
            ),
        }
    }

    /// Human-readable description.
    pub fn describe(&self) -> String {
        match self {
            StageExecutor::Pjrt { stage, .. } => format!("pjrt:{stage}"),
            StageExecutor::Simulated { busy } => format!("sim:{}us", busy.as_micros()),
        }
    }
}

/// Shared pool mapping stage names to executors; instances look up their
/// assignment here when the NM (re)assigns them (§8.2 "the instance
/// initializes the corresponding models").
#[derive(Clone, Default)]
pub struct ExecutorPool {
    entries: std::collections::HashMap<String, StageExecutor>,
}

impl ExecutorPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an executor under a stage name.
    pub fn insert(&mut self, stage: impl Into<String>, exec: StageExecutor) {
        self.entries.insert(stage.into(), exec);
    }

    /// Look up by stage name.
    pub fn get(&self, stage: &str) -> Option<&StageExecutor> {
        self.entries.get(stage)
    }

    /// All registered stage names.
    pub fn stages(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_executor_takes_its_time() {
        let e = StageExecutor::Simulated { busy: Duration::from_millis(5) };
        let t0 = std::time::Instant::now();
        e.run(&[]).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn amortized_batch_beats_sequential() {
        let e = StageExecutor::Simulated { busy: Duration::from_millis(4) };
        // Batch of 8 at 70% fixed cost: 4 ms × (0.7 + 0.3×8) = 12.4 ms —
        // well under the 32 ms of eight sequential runs.
        let t0 = std::time::Instant::now();
        e.run_amortized(8, 0.7).unwrap();
        let d = t0.elapsed();
        assert!(d >= Duration::from_micros(12_400), "amortized floor: {d:?}");
        assert!(d < Duration::from_millis(32), "must beat 8 sequential runs: {d:?}");
        // Batch of 1 degenerates to the plain per-request cost.
        let t0 = std::time::Instant::now();
        e.run_amortized(1, 0.7).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn tensor_value_shape_mismatch() {
        let tv = TensorValue::F32(vec![0.0; 4]);
        assert!(tv.to_literal(&[2, 2]).is_ok());
        assert!(tv.to_literal(&[3, 2]).is_err());
    }

    #[test]
    fn pool_lookup() {
        let mut pool = ExecutorPool::new();
        pool.insert("a", StageExecutor::Simulated { busy: Duration::ZERO });
        assert!(pool.get("a").is_some());
        assert!(pool.get("b").is_none());
    }
}
