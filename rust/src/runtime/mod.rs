//! PJRT runtime bridge (L2→L3): loads the AOT artifacts
//! (`artifacts/*.hlo.txt` + `manifest.json`) produced once by
//! `make artifacts` and executes them from the rust hot path. Python
//! never runs here.
//!
//! One compiled executable per stage model; the interchange format is HLO
//! *text* (see `python/compile/aot.py` for why). Stage executors are the
//! compute plug-in point for TaskWorkers: [`StageExecutor::Pjrt`] runs
//! real tensors through the XLA CPU client, [`StageExecutor::Simulated`]
//! sleeps a calibrated duration (used by the resource-scale experiments
//! where thousands of logical GPUs are modelled).
//!
//! ## The `pjrt` feature
//!
//! Real execution needs the `xla` crate (a PJRT binding), which the
//! offline build environment cannot fetch. The crate therefore gates all
//! XLA calls behind the off-by-default `pjrt` cargo feature: without it,
//! [`PjrtRuntime::load`] still parses manifests but refuses to build a
//! client, and every code path falls back to simulated executors. All
//! experiments except the real-tensor serving demo run fully without it.

mod executor;
mod manifest;

pub use executor::{ExecutorPool, StageExecutor, TensorValue};
pub use manifest::{Manifest, StageSpec, TensorSpec};

use anyhow::{Context, Result};
use std::path::Path;

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

/// Loaded PJRT runtime: client + one compiled executable per stage.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, Mutex<xla::PjRtLoadedExecutable>>,
    manifest: Manifest,
}

// The PJRT CPU client and loaded executables are internally thread-safe
// C++ objects; the crate's wrappers just don't declare it. Executions are
// additionally serialized per-executable through the Mutex above.
#[cfg(feature = "pjrt")]
unsafe impl Send for PjrtRuntime {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for PjrtRuntime {}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Load every stage in the manifest and compile it on the CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))
            .context("loading artifacts manifest (run `make artifacts`)")?;
        Self::load_manifest(artifacts_dir, manifest)
    }

    /// Load only a subset of stages (faster tests / per-role instances:
    /// a workflow instance compiles only the stage it was assigned, the
    /// paper's fine-grained resource story).
    pub fn load_stages(artifacts_dir: &Path, stages: &[&str]) -> Result<Self> {
        let mut manifest = Manifest::load(&artifacts_dir.join("manifest.json"))?;
        manifest.stages.retain(|k, _| stages.contains(&k.as_str()));
        Self::load_manifest(artifacts_dir, manifest)
    }

    fn load_manifest(artifacts_dir: &Path, manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        for (name, spec) in &manifest.stages {
            let path = artifacts_dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling stage {name}: {e}"))?;
            executables.insert(name.clone(), Mutex::new(exe));
        }
        Ok(Self { client, executables, manifest })
    }

    /// The manifest (shapes for marshalling).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Stage names available.
    pub fn stage_names(&self) -> Vec<String> {
        self.executables.keys().cloned().collect()
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute a stage with the given inputs. Inputs must match the
    /// manifest order/shapes; outputs are returned as a flat f32 vector
    /// (row-major, shape per manifest).
    pub fn execute(&self, stage: &str, inputs: &[TensorValue]) -> Result<Vec<f32>> {
        let spec = self
            .manifest
            .stages
            .get(stage)
            .with_context(|| format!("unknown stage {stage}"))?;
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "stage {stage}: expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (tv, ispec) in inputs.iter().zip(&spec.inputs) {
            literals.push(tv.to_literal(&ispec.shape).with_context(|| {
                format!("marshalling input {} of {stage}", ispec.name)
            })?);
        }
        let exe = self.executables.get(stage).unwrap().lock().unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        drop(exe);
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Stub runtime for builds without the `pjrt` feature: manifests load,
/// execution is refused with an actionable error. Keeping the type (and
/// its full method surface) lets every caller compile unchanged.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    fn unavailable<T>() -> Result<T> {
        anyhow::bail!(
            "this build has no PJRT backend: rebuild with `--features pjrt` \
             (requires the `xla` crate) or run with simulated executors (`--sim`)"
        )
    }

    /// Parse the manifest, then fail: there is no XLA client to compile
    /// stages with in a non-`pjrt` build.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let _manifest = Manifest::load(&artifacts_dir.join("manifest.json"))
            .context("loading artifacts manifest (run `make artifacts`)")?;
        Self::unavailable()
    }

    /// See [`PjrtRuntime::load`].
    pub fn load_stages(artifacts_dir: &Path, _stages: &[&str]) -> Result<Self> {
        let _manifest = Manifest::load(&artifacts_dir.join("manifest.json"))?;
        Self::unavailable()
    }

    /// The manifest (shapes for marshalling).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Stage names available.
    pub fn stage_names(&self) -> Vec<String> {
        self.manifest.stages.keys().cloned().collect()
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable (built without the `pjrt` feature)".to_string()
    }

    /// Always an error in non-`pjrt` builds.
    pub fn execute(&self, _stage: &str, _inputs: &[TensorValue]) -> Result<Vec<f32>> {
        Self::unavailable()
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    pub(crate) fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn load_and_run_vae_encode() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = PjrtRuntime::load_stages(&artifacts_dir(), &["vae_encode"]).unwrap();
        let image: Vec<f32> = (0..32 * 32 * 3).map(|i| (i % 7) as f32 / 7.0).collect();
        let out = rt
            .execute("vae_encode", &[TensorValue::F32(image)])
            .unwrap();
        assert_eq!(out.len(), 64 * 16);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn execute_rejects_wrong_arity() {
        if !have_artifacts() {
            return;
        }
        let rt = PjrtRuntime::load_stages(&artifacts_dir(), &["vae_encode"]).unwrap();
        assert!(rt.execute("vae_encode", &[]).is_err());
    }

    #[test]
    fn unknown_stage_errors() {
        if !have_artifacts() {
            return;
        }
        let rt = PjrtRuntime::load_stages(&artifacts_dir(), &["vae_encode"]).unwrap();
        assert!(rt.execute("nope", &[]).is_err());
    }

    #[test]
    fn deterministic_across_calls() {
        if !have_artifacts() {
            return;
        }
        let rt = PjrtRuntime::load_stages(&artifacts_dir(), &["vae_encode"]).unwrap();
        let image: Vec<f32> = vec![0.25; 32 * 32 * 3];
        let a = rt.execute("vae_encode", &[TensorValue::F32(image.clone())]).unwrap();
        let b = rt.execute("vae_encode", &[TensorValue::F32(image)]).unwrap();
        assert_eq!(a, b);
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_load_fails_actionably() {
        let err = PjrtRuntime::load(Path::new("definitely-missing-dir")).unwrap_err();
        // Missing manifest is reported first; with a manifest present the
        // error would name the `pjrt` feature instead.
        assert!(format!("{err:?}").contains("manifest"));
    }
}
