//! Artifact manifest (`artifacts/manifest.json`): shape/dtype contracts
//! for every stage executable, written by `python/compile/aot.py`.

use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Tensor shape/dtype descriptor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    /// "float32" or "int32".
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One stage executable's contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageSpec {
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
    pub file: String,
}

/// The full manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Model dimensions (d_model, vid_tokens, ...) for driver code.
    pub dims: BTreeMap<String, u64>,
    pub stages: BTreeMap<String, StageSpec>,
}

fn tensor_spec(j: &Json, name_default: &str) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor missing shape"))?
        .iter()
        .map(|x| x.as_u64().map(|v| v as usize))
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| anyhow!("non-integer shape"))?;
    Ok(TensorSpec {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or(name_default)
            .to_string(),
        dtype: j
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("float32")
            .to_string(),
        shape,
    })
}

impl Manifest {
    /// Parse from a JSON string.
    pub fn parse(s: &str) -> Result<Self> {
        let j = Json::parse(s).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let dims = j
            .get("dims")
            .and_then(Json::as_obj)
            .map(|m| {
                m.iter()
                    .filter_map(|(k, v)| v.as_u64().map(|n| (k.clone(), n)))
                    .collect()
            })
            .unwrap_or_default();
        let mut stages = BTreeMap::new();
        let stage_obj = j
            .get("stages")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing stages"))?;
        for (name, sj) in stage_obj {
            let inputs = sj
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("stage {name} missing inputs"))?
                .iter()
                .map(|i| tensor_spec(i, "input"))
                .collect::<Result<Vec<_>>>()?;
            let output = tensor_spec(
                sj.get("output").ok_or_else(|| anyhow!("stage {name} missing output"))?,
                "output",
            )?;
            let file = sj
                .get("file")
                .and_then(Json::as_str)
                .unwrap_or(&format!("{name}.hlo.txt"))
                .to_string();
            stages.insert(name.clone(), StageSpec { inputs, output, file });
        }
        Ok(Self { dims, stages })
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<Self> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&s)
    }

    /// Named dimension lookup.
    pub fn dim(&self, name: &str) -> Option<u64> {
        self.dims.get(name).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "dims": {"d_latent": 16, "vid_tokens": 256},
      "stages": {
        "vae_encode": {
          "inputs": [{"name": "image", "dtype": "float32", "shape": [32, 32, 3]}],
          "output": {"dtype": "float32", "shape": [64, 16]},
          "file": "vae_encode.hlo.txt"
        },
        "diffusion_step": {
          "inputs": [
            {"name": "x", "dtype": "float32", "shape": [256, 16]},
            {"name": "t", "dtype": "float32", "shape": [1]}
          ],
          "output": {"dtype": "float32", "shape": [256, 16]},
          "file": "diffusion_step.hlo.txt"
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.dim("d_latent"), Some(16));
        let s = &m.stages["vae_encode"];
        assert_eq!(s.inputs[0].shape, vec![32, 32, 3]);
        assert_eq!(s.inputs[0].elems(), 3072);
        assert_eq!(s.output.shape, vec![64, 16]);
        assert_eq!(s.file, "vae_encode.hlo.txt");
    }

    #[test]
    fn missing_stages_rejected() {
        assert!(Manifest::parse(r#"{"dims": {}}"#).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if !p.exists() {
            return;
        }
        let m = Manifest::load(&p).unwrap();
        assert!(m.stages.contains_key("diffusion_step"));
        assert_eq!(m.stages["diffusion_step"].inputs.len(), 5);
        assert_eq!(m.dim("vid_tokens"), Some(256));
    }
}
