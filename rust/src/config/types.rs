//! Typed configuration structures + JSON (de)serialization + validation.

use crate::util::Json;
use std::collections::BTreeMap;
use std::fmt;

/// Configuration error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

fn err(msg: impl Into<String>) -> ConfigError {
    ConfigError(msg.into())
}

/// Which latency model the simulated fabric applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricKind {
    /// No modelled latency (functional runs, unit tests).
    Ideal,
    /// 100 Gb/s InfiniBand model (the paper's deployment).
    Infiniband100g,
    /// Kernel-TCP model (baseline comparisons).
    TcpDatacenter,
}

impl FabricKind {
    fn as_str(&self) -> &'static str {
        match self {
            FabricKind::Ideal => "ideal",
            FabricKind::Infiniband100g => "infiniband_100g",
            FabricKind::TcpDatacenter => "tcp_datacenter",
        }
    }

    fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "ideal" => Ok(FabricKind::Ideal),
            "infiniband_100g" => Ok(FabricKind::Infiniband100g),
            "tcp_datacenter" => Ok(FabricKind::TcpDatacenter),
            other => Err(err(format!("unknown fabric kind {other:?}"))),
        }
    }
}

/// Request scheduling mode within an instance (§4.3, Figure 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    /// Individual Mode: workers pull from a shared queue, one GPU each.
    Individual,
    /// Collaboration Mode: the request is broadcast to all workers
    /// (TP/PP across the instance's GPUs).
    Collaboration,
}

impl SchedMode {
    fn as_str(&self) -> &'static str {
        match self {
            SchedMode::Individual => "individual",
            SchedMode::Collaboration => "collaboration",
        }
    }

    fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "individual" | "im" => Ok(SchedMode::Individual),
            "collaboration" | "cm" => Ok(SchedMode::Collaboration),
            other => Err(err(format!("unknown sched mode {other:?}"))),
        }
    }
}

/// How a stage's compute executes.
#[derive(Debug, Clone, PartialEq)]
pub enum ExecModel {
    /// Run a PJRT executable loaded from `artifacts/<name>.hlo.txt`.
    Artifact(String),
    /// Calibrated busy-sleep of the given duration (resource-scale sims
    /// where thousands of logical GPUs are modelled).
    Simulated { ms: f64 },
}

impl ExecModel {
    fn to_json(&self) -> Json {
        match self {
            ExecModel::Artifact(name) => Json::Str(format!("artifact:{name}")),
            ExecModel::Simulated { ms } => Json::Str(format!("sim:{ms}ms")),
        }
    }

    fn parse(s: &str) -> Result<Self, ConfigError> {
        if let Some(name) = s.strip_prefix("artifact:") {
            return Ok(ExecModel::Artifact(name.to_string()));
        }
        if let Some(rest) = s.strip_prefix("sim:") {
            let num = rest.strip_suffix("ms").unwrap_or(rest);
            return num
                .parse::<f64>()
                .map(|ms| ExecModel::Simulated { ms })
                .map_err(|_| err(format!("bad sim duration {rest:?}")));
        }
        Err(err(format!("unknown exec model {s:?}")))
    }
}

/// Micro-batching settings for the stage data plane (the adaptive
/// engine in [`crate::batch`]). **Absent = batching off**: without a
/// `batch` block the single-request path is taken unchanged.
///
/// Appears in two places: a top-level `batch` block supplies the default
/// for every Individual-mode stage, and a per-stage `batch` block
/// overrides it (Collaboration-mode stages never batch — collective
/// execution broadcasts one request to all ranks).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchSettings {
    /// Upper bound on members per micro-batch (>= 1; 1 = batching
    /// effectively off for the stage).
    pub max_batch: usize,
    /// Batch-formation window: how long the assembler waits for more
    /// compatible members after the first, µs. The adaptive controller
    /// shrinks/grows the *effective* window below this cap.
    pub max_wait_us: u64,
    /// Resize the window from observed arrival rate / utilization
    /// (low load → shrink for latency, backlog → grow toward
    /// `max_batch`).
    pub adaptive: bool,
    /// Interactive-class requests bypass batching entirely (fetched and
    /// executed one at a time, ahead of forming batches).
    pub interactive_bypass: bool,
    /// SchedQueue aging guard: a queued message older than this is
    /// promoted past higher priority bands, so sustained Interactive
    /// load cannot starve the Batch band forever. 0 = off (strict
    /// highest-band-first, the pre-batching behaviour).
    pub max_starvation_ms: u64,
}

impl Default for BatchSettings {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait_us: 2_000,
            adaptive: true,
            interactive_bypass: true,
            max_starvation_ms: 0,
        }
    }
}

/// One workflow stage (§3.3, §4).
#[derive(Debug, Clone, PartialEq)]
pub struct StageConfig {
    pub name: String,
    pub exec: ExecModel,
    /// Nominal per-request execution time (ms) — drives Theorem-1 sizing
    /// and the proxy's admission rate; measured values refine it at run
    /// time.
    pub exec_ms: f64,
    pub gpus_per_instance: usize,
    pub workers: usize,
    pub mode: SchedMode,
    /// Per-stage micro-batching override (None = inherit the top-level
    /// `batch` block, or no batching when that is absent too).
    pub batch: Option<BatchSettings>,
}

/// One application workflow (§4.5: the app id routes messages).
#[derive(Debug, Clone, PartialEq)]
pub struct AppConfig {
    pub id: u32,
    pub name: String,
    pub stages: Vec<StageConfig>,
}

/// Ring-buffer geometry (transport endpoints).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RingSettings {
    pub nslots: usize,
    pub cap_bytes: usize,
    pub lock_timeout_us: u64,
}

/// NodeManager tuning (§8).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NmSettings {
    /// Scale-up threshold on windowed stage utilization (paper: 85%).
    pub util_threshold: f64,
    /// Utilization averaging window, ms (paper example: 5 minutes).
    pub util_window_ms: u64,
    /// Heartbeat period, ms.
    pub heartbeat_ms: u64,
    /// Missed-heartbeat threshold before an election, ms.
    pub heartbeat_timeout_ms: u64,
    /// NM replica count (primary + backups).
    pub replicas: usize,
    /// Run the §8.2 rebalance pass on the housekeeping timer. Off by
    /// default so demos/tests drive rescheduling explicitly.
    pub auto_rebalance: bool,
    /// Worker-instance failure detector: declare an instance dead when
    /// its last heartbeat (piggybacked on the utilization report) is
    /// older than this. 0 = detector off (the default — like
    /// `auto_rebalance`, fault handling is opt-in so functional runs
    /// keep deterministic instance sets).
    pub instance_timeout_ms: u64,
}

/// Chaos / fault-injection settings (crash testing, E13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChaosSettings {
    /// Kill one randomly chosen assigned instance every this many ms
    /// (driven by the set's housekeeping timer). 0 = chaos off.
    pub kill_every_ms: u64,
    /// RNG seed for victim selection.
    pub seed: u64,
}

impl Default for ChaosSettings {
    fn default() -> Self {
        Self { kill_every_ms: 0, seed: 7 }
    }
}

/// RDMA data-plane tuning (DESIGN.md §2, large-payload plane).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RdmaSettings {
    /// Eager/rendezvous cutover: encoded messages of at least this many
    /// bytes are staged in a registered slab and announced through the
    /// ring by a fixed 40-byte descriptor frame, which the receiver
    /// resolves with one one-sided READ. 0 (the default) keeps every
    /// message eager — inline in the ring, exactly the pre-rendezvous
    /// data plane.
    pub rendezvous_threshold_bytes: usize,
}

/// Content-addressed artifact-cache settings ([`crate::cache`]).
/// **Absent = cache off**: without a `cache` block no `ArtifactCache`
/// is constructed and the request path is byte-identical to an
/// uncached build.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheSettings {
    /// In-process hot tier budget (LRU of `Arc<[u8]>` handles).
    pub hot_capacity_bytes: usize,
    /// Warm tier budget: bytes staged in registered slabs readable by
    /// one one-sided READ from any instance. Warm eviction removes the
    /// entry entirely.
    pub warm_capacity_bytes: usize,
    /// Entry time-to-live, ms; 0 = entries never expire.
    pub ttl_ms: u64,
    /// Deployment salt folded into every key: bump it on a model
    /// revision / sampler change to invalidate the whole cache without
    /// a flush protocol.
    pub salt: String,
    /// Stage names the per-stage tier engages for; empty = every stage.
    /// List only deterministic stages (a seed-randomized diffusion stage
    /// must stay off the list or repeats would replay one sample).
    pub stages: Vec<String>,
    /// Enable the full-workflow admission tier (proxy-side hit returns
    /// the terminal result without entering the pipeline).
    pub workflow: bool,
}

impl Default for CacheSettings {
    fn default() -> Self {
        Self {
            hot_capacity_bytes: 8 << 20,
            warm_capacity_bytes: 64 << 20,
            ttl_ms: 600_000,
            salt: String::new(),
            stages: Vec::new(),
            workflow: true,
        }
    }
}

/// Distributed-tracing settings ([`crate::trace`]). **Absent = tracing
/// off**: without a `trace` block no `Tracer` or flight recorder is
/// constructed, no `trace_*` counters are registered, and the request
/// path is byte-identical to an untraced build.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceSettings {
    /// Fraction of completed requests whose stitched trace is retained
    /// (deterministic per-UID hash, so every component agrees). 1.0 =
    /// keep everything (tests/demos), 0.01 = production-style sampling.
    pub sample_rate: f64,
    /// Flight-recorder capacity per component, in events (each slot is
    /// 48 bytes). Overwrite-oldest on overflow.
    pub buffer_events: usize,
    /// Tail rule: a completed request slower than this is force-kept
    /// even when the sample-rate hash says drop — the slow tail always
    /// has exemplar traces. 0 = tail rule off.
    pub always_sample_slow_ms: u64,
}

impl Default for TraceSettings {
    fn default() -> Self {
        Self {
            sample_rate: 1.0,
            buffer_events: 4096,
            always_sample_slow_ms: 0,
        }
    }
}

/// Fabric fault-plane settings (DESIGN.md §7; mirrors
/// [`crate::rdma::FaultPlan`]). **Absent = fault plane off**: without a
/// `faults` block no fault state is allocated in the fabric, no
/// `verbs_lost_total`-family counters are registered, and every verb
/// takes the byte-identical pre-fault path — the same off-by-default
/// discipline as `batch`/`cache`/`trace`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSettings {
    /// Probability any verb's completion is lost (sender sees
    /// `VerbLost` and must retry or strand).
    pub verb_loss_prob: f64,
    /// Probability a verb completes late.
    pub delay_prob: f64,
    /// Extra modelled ns charged to each delayed completion.
    pub delay_ns: u64,
    /// Probability of a transient `UnknownRegion` flap.
    pub flap_prob: f64,
    /// Scheduled directed partition: start after this many fabric ops
    /// (active only when `partition_ops > 0`).
    pub partition_after_ops: u64,
    /// Partition duration in fabric ops; 0 = no scheduled partition.
    pub partition_ops: u64,
    /// Victim selector: regions with `id % partition_group ==
    /// partition_victim` are cut while partitioned.
    pub partition_group: u64,
    /// See `partition_group`.
    pub partition_victim: u64,
    /// Deterministic seed for the fault stream.
    pub seed: u64,
}

impl Default for FaultSettings {
    fn default() -> Self {
        Self {
            verb_loss_prob: 0.0,
            delay_prob: 0.0,
            delay_ns: 20_000,
            flap_prob: 0.0,
            partition_after_ops: 0,
            partition_ops: 0,
            partition_group: 4,
            partition_victim: 1,
            seed: 0xFA17,
        }
    }
}

/// Database tuning (§3.4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DbSettings {
    pub replicas: usize,
    pub ttl_ms: u64,
}

/// Proxy / request-monitor tuning (§5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProxySettings {
    /// Arrival-rate estimation window, ms.
    pub monitor_window_ms: u64,
    /// Admission headroom: admit while rate < capacity * headroom.
    pub headroom: f64,
    /// Fraction of the admission budget reserved for Interactive-class
    /// traffic (see [`crate::client::Priority`]): under overload,
    /// Standard/Batch submissions are shed first while user-facing
    /// requests still find headroom. **Opt-in** (default 0.0): with a
    /// reserve, non-interactive goodput plateaus below the Theorem-1
    /// rate by design, so deployments without SLO tiers keep the paper's
    /// plateau-at-capacity behaviour.
    pub interactive_reserve: f64,
}

/// Top-level deployment config for one or more Workflow Sets.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of regionally-autonomous workflow sets (§3.1).
    pub sets: usize,
    pub fabric: FabricKind,
    pub ring: RingSettings,
    pub nm: NmSettings,
    pub db: DbSettings,
    pub proxy: ProxySettings,
    pub apps: Vec<AppConfig>,
    /// Idle-instance pool size per set (§8.2).
    pub idle_pool: usize,
    /// Crash injection (off unless enabled).
    pub chaos: ChaosSettings,
    /// RDMA data-plane tuning (eager/rendezvous cutover).
    pub rdma: RdmaSettings,
    /// Adaptive micro-batching default for every Individual-mode stage
    /// (per-stage `batch` blocks override it). **None = batching off**;
    /// the data plane then runs the paper's one-request-per-invocation
    /// path unchanged.
    pub batch: Option<BatchSettings>,
    /// Content-addressed artifact cache. **None = cache off**; the
    /// proxy and workers never consult a cache and no slab memory is
    /// registered for it.
    pub cache: Option<CacheSettings>,
    /// Per-request distributed tracing. **None = tracing off**; no
    /// recorder memory, no `trace_*` counters, no hot-path writes.
    pub trace: Option<TraceSettings>,
    /// Fabric fault injection. **None = fault plane off**; no fault
    /// state in the fabric, no fault counters, byte-identical verbs.
    pub faults: Option<FaultSettings>,
}

impl ClusterConfig {
    /// The Wan2.1-style image-to-video deployment the examples use. Stage
    /// times reflect the measured relative costs of the four PJRT stage
    /// executables (diffusion runs `steps` times per request, dominating).
    pub fn i2v_default() -> Self {
        Self {
            sets: 1,
            fabric: FabricKind::Infiniband100g,
            ring: RingSettings { nslots: 256, cap_bytes: 8 << 20, lock_timeout_us: 50 },
            nm: NmSettings {
                util_threshold: 0.85,
                util_window_ms: 2_000,
                heartbeat_ms: 100,
                heartbeat_timeout_ms: 400,
                replicas: 3,
                auto_rebalance: false,
                instance_timeout_ms: 0,
            },
            db: DbSettings { replicas: 2, ttl_ms: 60_000 },
            proxy: ProxySettings {
                monitor_window_ms: 2_000,
                headroom: 1.0,
                interactive_reserve: 0.0,
            },
            apps: vec![AppConfig {
                id: 1,
                name: "i2v".into(),
                stages: vec![
                    StageConfig {
                        name: "text_encoder".into(),
                        exec: ExecModel::Artifact("text_encoder".into()),
                        exec_ms: 4.0,
                        gpus_per_instance: 1,
                        workers: 1,
                        mode: SchedMode::Individual,
                        batch: None,
                    },
                    StageConfig {
                        name: "vae_encode".into(),
                        exec: ExecModel::Artifact("vae_encode".into()),
                        exec_ms: 2.0,
                        gpus_per_instance: 1,
                        workers: 1,
                        mode: SchedMode::Individual,
                        batch: None,
                    },
                    StageConfig {
                        name: "diffusion".into(),
                        exec: ExecModel::Artifact("diffusion_step".into()),
                        exec_ms: 40.0, // per request: steps × per-step cost
                        gpus_per_instance: 1,
                        workers: 1,
                        mode: SchedMode::Collaboration,
                        batch: None,
                    },
                    StageConfig {
                        name: "vae_decode".into(),
                        exec: ExecModel::Artifact("vae_decode".into()),
                        exec_ms: 2.0,
                        gpus_per_instance: 1,
                        workers: 1,
                        mode: SchedMode::Individual,
                        batch: None,
                    },
                ],
            }],
            idle_pool: 2,
            chaos: ChaosSettings::default(),
            rdma: RdmaSettings::default(),
            batch: None,
            cache: None,
            trace: None,
            faults: None,
        }
    }

    /// Effective micro-batching settings for one stage: the per-stage
    /// `batch` block wins, else the top-level default. Collaboration-mode
    /// stages never batch (one broadcast request occupies every rank),
    /// so they resolve to `None` regardless.
    pub fn stage_batch(&self, stage: &StageConfig) -> Option<BatchSettings> {
        if stage.mode == SchedMode::Collaboration {
            return None;
        }
        stage.batch.or(self.batch)
    }

    /// The SchedQueue aging bound instances run with: the smallest
    /// **non-zero** `max_starvation_ms` across the top-level `batch`
    /// block and every per-stage override. The queue is instance-wide
    /// and instances are reassigned across stages over their lifetime,
    /// so the strongest anti-starvation guarantee any stage asks for
    /// wins. Returns 0 (guard off) when no block sets it.
    pub fn effective_max_starvation_ms(&self) -> u64 {
        self.apps
            .iter()
            .flat_map(|a| a.stages.iter())
            .filter_map(|s| self.stage_batch(s))
            .map(|b| b.max_starvation_ms)
            .chain(self.batch.map(|b| b.max_starvation_ms))
            .filter(|&ms| ms > 0)
            .min()
            .unwrap_or(0)
    }

    /// The app list with each stage's `batch` field materialized to its
    /// *effective* settings (per-stage override, else the top-level
    /// default, never for Collaboration stages) — what the NodeManager
    /// is handed so assignments carry a ready [`BatchSettings`] without
    /// re-consulting the top-level block.
    pub fn apps_with_effective_batch(&self) -> Vec<AppConfig> {
        let mut apps = self.apps.clone();
        for app in &mut apps {
            for s in &mut app.stages {
                s.batch = self.stage_batch(s);
            }
        }
        apps
    }

    /// Validate invariants the rest of the system assumes.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.sets == 0 {
            return Err(err("sets must be >= 1"));
        }
        if self.apps.is_empty() {
            return Err(err("at least one app required"));
        }
        if self.ring.cap_bytes % 8 != 0 || self.ring.nslots < 2 {
            return Err(err("ring: cap_bytes must be 8-aligned, nslots >= 2"));
        }
        if !(0.0..=1.0).contains(&self.nm.util_threshold) {
            return Err(err("nm.util_threshold must be in [0,1]"));
        }
        if self.nm.replicas == 0 || self.db.replicas == 0 {
            return Err(err("nm/db replicas must be >= 1"));
        }
        if !(0.0..=1.0).contains(&self.proxy.interactive_reserve) {
            return Err(err("proxy.interactive_reserve must be in [0,1]"));
        }
        if self.chaos.kill_every_ms > 0 && self.nm.instance_timeout_ms == 0 {
            return Err(err(
                "chaos.kill_every_ms requires nm.instance_timeout_ms > 0 \
                 (killed instances would never be detected or repaired)",
            ));
        }
        if let Some(b) = &self.batch {
            if b.max_batch == 0 {
                return Err(err("batch.max_batch must be >= 1"));
            }
        }
        if let Some(c) = &self.cache {
            if c.hot_capacity_bytes == 0 || c.warm_capacity_bytes == 0 {
                return Err(err("cache: capacities must be >= 1 byte"));
            }
            if c.hot_capacity_bytes > c.warm_capacity_bytes {
                return Err(err(
                    "cache: hot_capacity_bytes must not exceed warm_capacity_bytes \
                     (every hot entry is also staged warm)",
                ));
            }
        }
        if let Some(t) = &self.trace {
            if !t.sample_rate.is_finite() || !(0.0..=1.0).contains(&t.sample_rate) {
                return Err(err("trace.sample_rate must be in [0,1]"));
            }
            if t.buffer_events < 64 {
                return Err(err("trace.buffer_events must be >= 64"));
            }
        }
        if let Some(f) = &self.faults {
            for (name, p) in [
                ("verb_loss_prob", f.verb_loss_prob),
                ("delay_prob", f.delay_prob),
                ("flap_prob", f.flap_prob),
            ] {
                if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                    return Err(err(format!("faults.{name} must be in [0,1]")));
                }
            }
            if f.partition_group == 0 {
                return Err(err("faults.partition_group must be >= 1"));
            }
            if f.partition_victim >= f.partition_group {
                return Err(err(
                    "faults.partition_victim must be < partition_group \
                     (otherwise the partition cuts no region)",
                ));
            }
        }
        let mut ids = std::collections::HashSet::new();
        for app in &self.apps {
            if !ids.insert(app.id) {
                return Err(err(format!("duplicate app id {}", app.id)));
            }
            if app.stages.is_empty() {
                return Err(err(format!("app {} has no stages", app.name)));
            }
            for s in &app.stages {
                if s.exec_ms <= 0.0 {
                    return Err(err(format!("stage {} exec_ms must be > 0", s.name)));
                }
                if s.workers == 0 || s.gpus_per_instance == 0 {
                    return Err(err(format!(
                        "stage {}: workers and gpus_per_instance must be >= 1",
                        s.name
                    )));
                }
                if let Some(b) = &s.batch {
                    if b.max_batch == 0 {
                        return Err(err(format!(
                            "stage {}: batch.max_batch must be >= 1",
                            s.name
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("sets".into(), Json::Num(self.sets as f64));
        root.insert("fabric".into(), Json::Str(self.fabric.as_str().into()));
        root.insert("idle_pool".into(), Json::Num(self.idle_pool as f64));
        root.insert(
            "ring".into(),
            obj(vec![
                ("nslots", Json::Num(self.ring.nslots as f64)),
                ("cap_bytes", Json::Num(self.ring.cap_bytes as f64)),
                ("lock_timeout_us", Json::Num(self.ring.lock_timeout_us as f64)),
            ]),
        );
        root.insert(
            "nm".into(),
            obj(vec![
                ("util_threshold", Json::Num(self.nm.util_threshold)),
                ("util_window_ms", Json::Num(self.nm.util_window_ms as f64)),
                ("heartbeat_ms", Json::Num(self.nm.heartbeat_ms as f64)),
                (
                    "heartbeat_timeout_ms",
                    Json::Num(self.nm.heartbeat_timeout_ms as f64),
                ),
                ("replicas", Json::Num(self.nm.replicas as f64)),
                (
                    "instance_timeout_ms",
                    Json::Num(self.nm.instance_timeout_ms as f64),
                ),
            ]),
        );
        root.insert(
            "chaos".into(),
            obj(vec![
                ("kill_every_ms", Json::Num(self.chaos.kill_every_ms as f64)),
                ("seed", Json::Num(self.chaos.seed as f64)),
            ]),
        );
        root.insert(
            "rdma".into(),
            obj(vec![(
                "rendezvous_threshold_bytes",
                Json::Num(self.rdma.rendezvous_threshold_bytes as f64),
            )]),
        );
        if let Some(b) = &self.batch {
            root.insert("batch".into(), batch_to_json(b));
        }
        if let Some(c) = &self.cache {
            root.insert("cache".into(), cache_to_json(c));
        }
        if let Some(t) = &self.trace {
            root.insert("trace".into(), trace_to_json(t));
        }
        if let Some(f) = &self.faults {
            root.insert("faults".into(), faults_to_json(f));
        }
        root.insert(
            "db".into(),
            obj(vec![
                ("replicas", Json::Num(self.db.replicas as f64)),
                ("ttl_ms", Json::Num(self.db.ttl_ms as f64)),
            ]),
        );
        root.insert(
            "proxy".into(),
            obj(vec![
                (
                    "monitor_window_ms",
                    Json::Num(self.proxy.monitor_window_ms as f64),
                ),
                ("headroom", Json::Num(self.proxy.headroom)),
                (
                    "interactive_reserve",
                    Json::Num(self.proxy.interactive_reserve),
                ),
            ]),
        );
        root.insert(
            "apps".into(),
            Json::Arr(
                self.apps
                    .iter()
                    .map(|a| {
                        obj(vec![
                            ("id", Json::Num(a.id as f64)),
                            ("name", Json::Str(a.name.clone())),
                            (
                                "stages",
                                Json::Arr(
                                    a.stages
                                        .iter()
                                        .map(|s| {
                                            let mut fields = vec![
                                                ("name", Json::Str(s.name.clone())),
                                                ("exec", s.exec.to_json()),
                                                ("exec_ms", Json::Num(s.exec_ms)),
                                                (
                                                    "gpus_per_instance",
                                                    Json::Num(s.gpus_per_instance as f64),
                                                ),
                                                ("workers", Json::Num(s.workers as f64)),
                                                ("mode", Json::Str(s.mode.as_str().into())),
                                            ];
                                            if let Some(b) = &s.batch {
                                                fields.push(("batch", batch_to_json(b)));
                                            }
                                            obj(fields)
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        );
        Json::Obj(root)
    }

    /// Parse from a JSON string and validate.
    pub fn from_json_str(s: &str) -> Result<Self, ConfigError> {
        let j = Json::parse(s).map_err(|e| err(format!("parse: {e}")))?;
        let cfg = Self::from_json(&j)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from a parsed JSON document.
    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        let base = Self::i2v_default(); // missing sections inherit defaults
        let get_u = |o: &Json, k: &str, d: u64| -> u64 {
            o.get(k).and_then(Json::as_u64).unwrap_or(d)
        };
        let get_f = |o: &Json, k: &str, d: f64| -> f64 {
            o.get(k).and_then(Json::as_f64).unwrap_or(d)
        };

        let ring = match j.get("ring") {
            Some(r) => RingSettings {
                nslots: get_u(r, "nslots", base.ring.nslots as u64) as usize,
                cap_bytes: get_u(r, "cap_bytes", base.ring.cap_bytes as u64) as usize,
                lock_timeout_us: get_u(r, "lock_timeout_us", base.ring.lock_timeout_us),
            },
            None => base.ring,
        };
        let nm = match j.get("nm") {
            Some(n) => NmSettings {
                util_threshold: get_f(n, "util_threshold", base.nm.util_threshold),
                util_window_ms: get_u(n, "util_window_ms", base.nm.util_window_ms),
                heartbeat_ms: get_u(n, "heartbeat_ms", base.nm.heartbeat_ms),
                heartbeat_timeout_ms: get_u(
                    n,
                    "heartbeat_timeout_ms",
                    base.nm.heartbeat_timeout_ms,
                ),
                replicas: get_u(n, "replicas", base.nm.replicas as u64) as usize,
                auto_rebalance: n
                    .get("auto_rebalance")
                    .and_then(Json::as_bool)
                    .unwrap_or(base.nm.auto_rebalance),
                instance_timeout_ms: get_u(
                    n,
                    "instance_timeout_ms",
                    base.nm.instance_timeout_ms,
                ),
            },
            None => base.nm,
        };
        let chaos = match j.get("chaos") {
            Some(c) => ChaosSettings {
                kill_every_ms: get_u(c, "kill_every_ms", base.chaos.kill_every_ms),
                seed: get_u(c, "seed", base.chaos.seed),
            },
            None => base.chaos,
        };
        let rdma = match j.get("rdma") {
            Some(r) => RdmaSettings {
                rendezvous_threshold_bytes: get_u(
                    r,
                    "rendezvous_threshold_bytes",
                    base.rdma.rendezvous_threshold_bytes as u64,
                ) as usize,
            },
            None => base.rdma,
        };
        let db = match j.get("db") {
            Some(d) => DbSettings {
                replicas: get_u(d, "replicas", base.db.replicas as u64) as usize,
                ttl_ms: get_u(d, "ttl_ms", base.db.ttl_ms),
            },
            None => base.db,
        };
        let proxy = match j.get("proxy") {
            Some(p) => ProxySettings {
                monitor_window_ms: get_u(
                    p,
                    "monitor_window_ms",
                    base.proxy.monitor_window_ms,
                ),
                headroom: get_f(p, "headroom", base.proxy.headroom),
                interactive_reserve: get_f(
                    p,
                    "interactive_reserve",
                    base.proxy.interactive_reserve,
                ),
            },
            None => base.proxy,
        };

        let apps = match j.get("apps") {
            Some(Json::Arr(items)) => {
                let mut apps = Vec::new();
                for a in items {
                    let stages_json = a
                        .get("stages")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| err("app missing stages"))?;
                    let mut stages = Vec::new();
                    for s in stages_json {
                        stages.push(StageConfig {
                            name: s
                                .get("name")
                                .and_then(Json::as_str)
                                .ok_or_else(|| err("stage missing name"))?
                                .to_string(),
                            exec: ExecModel::parse(
                                s.get("exec")
                                    .and_then(Json::as_str)
                                    .ok_or_else(|| err("stage missing exec"))?,
                            )?,
                            exec_ms: get_f(s, "exec_ms", 1.0),
                            gpus_per_instance: get_u(s, "gpus_per_instance", 1) as usize,
                            workers: get_u(s, "workers", 1) as usize,
                            mode: SchedMode::parse(
                                s.get("mode").and_then(Json::as_str).unwrap_or("individual"),
                            )?,
                            batch: s.get("batch").map(parse_batch),
                        });
                    }
                    apps.push(AppConfig {
                        id: a
                            .get("id")
                            .and_then(Json::as_u64)
                            .ok_or_else(|| err("app missing id"))? as u32,
                        name: a
                            .get("name")
                            .and_then(Json::as_str)
                            .unwrap_or("app")
                            .to_string(),
                        stages,
                    });
                }
                apps
            }
            _ => base.apps,
        };

        Ok(Self {
            sets: j.get("sets").and_then(Json::as_u64).unwrap_or(base.sets as u64)
                as usize,
            fabric: match j.get("fabric").and_then(Json::as_str) {
                Some(s) => FabricKind::parse(s)?,
                None => base.fabric,
            },
            ring,
            nm,
            db,
            proxy,
            apps,
            idle_pool: j
                .get("idle_pool")
                .and_then(Json::as_u64)
                .unwrap_or(base.idle_pool as u64) as usize,
            chaos,
            rdma,
            batch: j.get("batch").map(parse_batch),
            cache: j.get("cache").map(parse_cache),
            trace: j.get("trace").map(parse_trace),
            faults: j.get("faults").map(parse_faults),
        })
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<Self, ConfigError> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| err(format!("read {}: {e}", path.display())))?;
        Self::from_json_str(&s)
    }
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn batch_to_json(b: &BatchSettings) -> Json {
    obj(vec![
        ("max_batch", Json::Num(b.max_batch as f64)),
        ("max_wait_us", Json::Num(b.max_wait_us as f64)),
        ("adaptive", Json::Bool(b.adaptive)),
        ("interactive_bypass", Json::Bool(b.interactive_bypass)),
        ("max_starvation_ms", Json::Num(b.max_starvation_ms as f64)),
    ])
}

/// Parse a `batch` block; missing fields inherit [`BatchSettings`]
/// defaults (so `{"max_batch": 16}` is a complete override).
fn parse_batch(j: &Json) -> BatchSettings {
    let d = BatchSettings::default();
    BatchSettings {
        max_batch: j
            .get("max_batch")
            .and_then(Json::as_u64)
            .unwrap_or(d.max_batch as u64) as usize,
        max_wait_us: j.get("max_wait_us").and_then(Json::as_u64).unwrap_or(d.max_wait_us),
        adaptive: j.get("adaptive").and_then(Json::as_bool).unwrap_or(d.adaptive),
        interactive_bypass: j
            .get("interactive_bypass")
            .and_then(Json::as_bool)
            .unwrap_or(d.interactive_bypass),
        max_starvation_ms: j
            .get("max_starvation_ms")
            .and_then(Json::as_u64)
            .unwrap_or(d.max_starvation_ms),
    }
}

fn cache_to_json(c: &CacheSettings) -> Json {
    obj(vec![
        ("hot_capacity_bytes", Json::Num(c.hot_capacity_bytes as f64)),
        ("warm_capacity_bytes", Json::Num(c.warm_capacity_bytes as f64)),
        ("ttl_ms", Json::Num(c.ttl_ms as f64)),
        ("salt", Json::Str(c.salt.clone())),
        (
            "stages",
            Json::Arr(c.stages.iter().map(|s| Json::Str(s.clone())).collect()),
        ),
        ("workflow", Json::Bool(c.workflow)),
    ])
}

/// Parse a `cache` block; missing fields inherit [`CacheSettings`]
/// defaults (so `{"stages": ["vae_decode"]}` is a complete override).
fn parse_cache(j: &Json) -> CacheSettings {
    let d = CacheSettings::default();
    CacheSettings {
        hot_capacity_bytes: j
            .get("hot_capacity_bytes")
            .and_then(Json::as_u64)
            .unwrap_or(d.hot_capacity_bytes as u64) as usize,
        warm_capacity_bytes: j
            .get("warm_capacity_bytes")
            .and_then(Json::as_u64)
            .unwrap_or(d.warm_capacity_bytes as u64) as usize,
        ttl_ms: j.get("ttl_ms").and_then(Json::as_u64).unwrap_or(d.ttl_ms),
        salt: j
            .get("salt")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or(d.salt),
        stages: j
            .get("stages")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(Json::as_str)
                    .map(str::to_string)
                    .collect()
            })
            .unwrap_or(d.stages),
        workflow: j.get("workflow").and_then(Json::as_bool).unwrap_or(d.workflow),
    }
}

fn trace_to_json(t: &TraceSettings) -> Json {
    obj(vec![
        ("sample_rate", Json::Num(t.sample_rate)),
        ("buffer_events", Json::Num(t.buffer_events as f64)),
        (
            "always_sample_slow_ms",
            Json::Num(t.always_sample_slow_ms as f64),
        ),
    ])
}

/// Parse a `trace` block; missing fields inherit [`TraceSettings`]
/// defaults (so `{"sample_rate": 0.01}` is a complete override).
fn parse_trace(j: &Json) -> TraceSettings {
    let d = TraceSettings::default();
    TraceSettings {
        sample_rate: j
            .get("sample_rate")
            .and_then(Json::as_f64)
            .unwrap_or(d.sample_rate),
        buffer_events: j
            .get("buffer_events")
            .and_then(Json::as_u64)
            .unwrap_or(d.buffer_events as u64) as usize,
        always_sample_slow_ms: j
            .get("always_sample_slow_ms")
            .and_then(Json::as_u64)
            .unwrap_or(d.always_sample_slow_ms),
    }
}

fn faults_to_json(f: &FaultSettings) -> Json {
    obj(vec![
        ("verb_loss_prob", Json::Num(f.verb_loss_prob)),
        ("delay_prob", Json::Num(f.delay_prob)),
        ("delay_ns", Json::Num(f.delay_ns as f64)),
        ("flap_prob", Json::Num(f.flap_prob)),
        ("partition_after_ops", Json::Num(f.partition_after_ops as f64)),
        ("partition_ops", Json::Num(f.partition_ops as f64)),
        ("partition_group", Json::Num(f.partition_group as f64)),
        ("partition_victim", Json::Num(f.partition_victim as f64)),
        ("seed", Json::Num(f.seed as f64)),
    ])
}

/// Parse a `faults` block; missing fields inherit [`FaultSettings`]
/// defaults (so `{"verb_loss_prob": 0.01}` is a complete override).
fn parse_faults(j: &Json) -> FaultSettings {
    let d = FaultSettings::default();
    FaultSettings {
        verb_loss_prob: j
            .get("verb_loss_prob")
            .and_then(Json::as_f64)
            .unwrap_or(d.verb_loss_prob),
        delay_prob: j.get("delay_prob").and_then(Json::as_f64).unwrap_or(d.delay_prob),
        delay_ns: j.get("delay_ns").and_then(Json::as_u64).unwrap_or(d.delay_ns),
        flap_prob: j.get("flap_prob").and_then(Json::as_f64).unwrap_or(d.flap_prob),
        partition_after_ops: j
            .get("partition_after_ops")
            .and_then(Json::as_u64)
            .unwrap_or(d.partition_after_ops),
        partition_ops: j
            .get("partition_ops")
            .and_then(Json::as_u64)
            .unwrap_or(d.partition_ops),
        partition_group: j
            .get("partition_group")
            .and_then(Json::as_u64)
            .unwrap_or(d.partition_group),
        partition_victim: j
            .get("partition_victim")
            .and_then(Json::as_u64)
            .unwrap_or(d.partition_victim),
        seed: j.get("seed").and_then(Json::as_u64).unwrap_or(d.seed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_model_parse() {
        assert_eq!(
            ExecModel::parse("artifact:diffusion_step").unwrap(),
            ExecModel::Artifact("diffusion_step".into())
        );
        assert_eq!(
            ExecModel::parse("sim:12.5ms").unwrap(),
            ExecModel::Simulated { ms: 12.5 }
        );
        assert!(ExecModel::parse("gpu:nope").is_err());
    }

    #[test]
    fn partial_json_inherits_defaults() {
        let cfg = ClusterConfig::from_json_str(r#"{"sets": 3}"#).unwrap();
        assert_eq!(cfg.sets, 3);
        assert_eq!(cfg.apps.len(), 1); // inherited i2v app
        assert_eq!(cfg.nm.replicas, 3);
    }

    #[test]
    fn sched_mode_aliases() {
        assert_eq!(SchedMode::parse("im").unwrap(), SchedMode::Individual);
        assert_eq!(SchedMode::parse("cm").unwrap(), SchedMode::Collaboration);
    }

    #[test]
    fn duplicate_app_ids_rejected() {
        let mut cfg = ClusterConfig::i2v_default();
        let mut dup = cfg.apps[0].clone();
        dup.name = "copy".into();
        cfg.apps.push(dup);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn i2v_default_is_valid() {
        ClusterConfig::i2v_default().validate().unwrap();
    }

    #[test]
    fn batch_block_parses_inherits_and_resolves_per_stage() {
        // Top-level block with partial fields: the rest inherit defaults.
        let cfg = ClusterConfig::from_json_str(
            r#"{"batch": {"max_batch": 16, "max_starvation_ms": 250}}"#,
        )
        .unwrap();
        let b = cfg.batch.unwrap();
        assert_eq!(b.max_batch, 16);
        assert_eq!(b.max_starvation_ms, 250);
        assert_eq!(b.max_wait_us, BatchSettings::default().max_wait_us);
        assert!(b.interactive_bypass && b.adaptive);
        // Resolution: IM stages inherit the global block; the CM
        // diffusion stage never batches.
        let stages = &cfg.apps[0].stages;
        assert_eq!(cfg.stage_batch(&stages[0]).unwrap().max_batch, 16);
        assert!(cfg.stage_batch(&stages[2]).is_none(), "CM stages never batch");
        let eff = cfg.apps_with_effective_batch();
        assert_eq!(eff[0].stages[0].batch.unwrap().max_batch, 16);
        assert!(eff[0].stages[2].batch.is_none());
        // Round-trip keeps both block levels.
        let mut cfg2 = cfg.clone();
        cfg2.apps[0].stages[0].batch =
            Some(BatchSettings { max_batch: 4, ..BatchSettings::default() });
        let back = ClusterConfig::from_json(&cfg2.to_json()).unwrap();
        assert_eq!(back.batch, cfg2.batch);
        assert_eq!(back.apps[0].stages[0].batch.unwrap().max_batch, 4);
        // Per-stage override beats the global block.
        assert_eq!(back.stage_batch(&back.apps[0].stages[0]).unwrap().max_batch, 4);
        // Zero max_batch is a misconfiguration.
        assert!(
            ClusterConfig::from_json_str(r#"{"batch": {"max_batch": 0}}"#).is_err()
        );
    }

    #[test]
    fn absent_batch_block_means_batching_off() {
        let cfg = ClusterConfig::i2v_default();
        assert!(cfg.batch.is_none());
        for s in &cfg.apps[0].stages {
            assert!(cfg.stage_batch(s).is_none());
        }
        assert_eq!(cfg.effective_max_starvation_ms(), 0);
    }

    #[test]
    fn per_stage_starvation_guard_reaches_the_effective_bound() {
        // A per-stage block alone (no top-level one) must still arm the
        // aging guard — the satellite failure this knob exists for.
        let mut cfg = ClusterConfig::i2v_default();
        cfg.apps[0].stages[0].batch = Some(BatchSettings {
            max_starvation_ms: 250,
            ..BatchSettings::default()
        });
        assert_eq!(cfg.effective_max_starvation_ms(), 250);
        // With a top-level block too, the smallest non-zero bound wins;
        // zero entries (guard off for that block) are ignored.
        cfg.batch = Some(BatchSettings { max_starvation_ms: 0, ..BatchSettings::default() });
        assert_eq!(cfg.effective_max_starvation_ms(), 250);
        cfg.batch = Some(BatchSettings { max_starvation_ms: 100, ..BatchSettings::default() });
        assert_eq!(cfg.effective_max_starvation_ms(), 100);
    }

    #[test]
    fn cache_block_parses_inherits_and_round_trips() {
        let cfg = ClusterConfig::from_json_str(
            r#"{"cache": {"ttl_ms": 5000, "salt": "wan21-v3",
                          "stages": ["text_encoder", "vae_decode"]}}"#,
        )
        .unwrap();
        let c = cfg.cache.as_ref().unwrap();
        assert_eq!(c.ttl_ms, 5_000);
        assert_eq!(c.salt, "wan21-v3");
        assert_eq!(c.stages, vec!["text_encoder", "vae_decode"]);
        // Unset fields inherit the defaults.
        let d = CacheSettings::default();
        assert_eq!(c.hot_capacity_bytes, d.hot_capacity_bytes);
        assert_eq!(c.warm_capacity_bytes, d.warm_capacity_bytes);
        assert!(c.workflow);
        // Round-trip preserves the block.
        let back = ClusterConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.cache, cfg.cache);
        // Misconfigurations are rejected.
        assert!(ClusterConfig::from_json_str(
            r#"{"cache": {"hot_capacity_bytes": 0}}"#
        )
        .is_err());
        assert!(ClusterConfig::from_json_str(
            r#"{"cache": {"hot_capacity_bytes": 100, "warm_capacity_bytes": 50}}"#
        )
        .is_err());
    }

    #[test]
    fn absent_cache_block_means_cache_off() {
        assert!(ClusterConfig::i2v_default().cache.is_none());
        assert!(ClusterConfig::from_json_str("{}").unwrap().cache.is_none());
    }

    #[test]
    fn trace_block_parses_inherits_and_round_trips() {
        let cfg =
            ClusterConfig::from_json_str(r#"{"trace": {"sample_rate": 0.01}}"#).unwrap();
        let t = cfg.trace.unwrap();
        assert_eq!(t.sample_rate, 0.01);
        // Unset fields inherit the defaults.
        let d = TraceSettings::default();
        assert_eq!(t.buffer_events, d.buffer_events);
        assert_eq!(t.always_sample_slow_ms, d.always_sample_slow_ms);
        // Round-trip preserves the block.
        let back = ClusterConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.trace, cfg.trace);
        // Misconfigurations are rejected.
        assert!(
            ClusterConfig::from_json_str(r#"{"trace": {"sample_rate": 1.5}}"#).is_err()
        );
        assert!(
            ClusterConfig::from_json_str(r#"{"trace": {"buffer_events": 8}}"#).is_err()
        );
    }

    #[test]
    fn absent_trace_block_means_tracing_off() {
        assert!(ClusterConfig::i2v_default().trace.is_none());
        assert!(ClusterConfig::from_json_str("{}").unwrap().trace.is_none());
    }

    #[test]
    fn faults_block_parses_inherits_and_round_trips() {
        let cfg = ClusterConfig::from_json_str(
            r#"{"faults": {"verb_loss_prob": 0.05, "partition_ops": 200}}"#,
        )
        .unwrap();
        let f = cfg.faults.unwrap();
        assert_eq!(f.verb_loss_prob, 0.05);
        assert_eq!(f.partition_ops, 200);
        // Unset fields inherit the defaults.
        let d = FaultSettings::default();
        assert_eq!(f.delay_ns, d.delay_ns);
        assert_eq!(f.partition_group, d.partition_group);
        assert_eq!(f.seed, d.seed);
        // Round-trip preserves the block.
        let back = ClusterConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.faults, cfg.faults);
        // Misconfigurations are rejected.
        assert!(ClusterConfig::from_json_str(
            r#"{"faults": {"verb_loss_prob": 1.5}}"#
        )
        .is_err());
        assert!(ClusterConfig::from_json_str(
            r#"{"faults": {"partition_group": 0}}"#
        )
        .is_err());
        assert!(ClusterConfig::from_json_str(
            r#"{"faults": {"partition_group": 2, "partition_victim": 2}}"#
        )
        .is_err());
    }

    #[test]
    fn absent_faults_block_means_fault_plane_off() {
        assert!(ClusterConfig::i2v_default().faults.is_none());
        assert!(ClusterConfig::from_json_str("{}").unwrap().faults.is_none());
    }

    #[test]
    fn rdma_block_parses_and_round_trips() {
        let cfg = ClusterConfig::from_json_str(
            r#"{"rdma": {"rendezvous_threshold_bytes": 65536}}"#,
        )
        .unwrap();
        assert_eq!(cfg.rdma.rendezvous_threshold_bytes, 65_536);
        let back = ClusterConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.rdma, cfg.rdma);
        // Absent block: eager-only default.
        let d = ClusterConfig::from_json_str("{}").unwrap();
        assert_eq!(d.rdma.rendezvous_threshold_bytes, 0);
    }

    #[test]
    fn chaos_block_parses_and_requires_detector() {
        let cfg = ClusterConfig::from_json_str(
            r#"{"nm": {"instance_timeout_ms": 500},
                "chaos": {"kill_every_ms": 1000, "seed": 3}}"#,
        )
        .unwrap();
        assert_eq!(cfg.nm.instance_timeout_ms, 500);
        assert_eq!(cfg.chaos.kill_every_ms, 1_000);
        assert_eq!(cfg.chaos.seed, 3);
        // Chaos without the failure detector is a misconfiguration.
        assert!(ClusterConfig::from_json_str(r#"{"chaos": {"kill_every_ms": 1000}}"#)
            .is_err());
        // Round-trip keeps the new fields.
        let back = ClusterConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.nm.instance_timeout_ms, 500);
        assert_eq!(back.chaos, cfg.chaos);
    }
}
