//! Cluster / workflow configuration.
//!
//! Configs are JSON documents (parsed with the in-tree
//! [`crate::util::Json`] parser — the offline build has no serde/toml)
//! validated into typed structs.
//! [`ClusterConfig::i2v_default`] is the Wan2.1-style image-to-video
//! deployment used by the examples; `examples/configs/` has the same
//! shapes as files.

mod types;

pub use types::{
    AppConfig, BatchSettings, CacheSettings, ChaosSettings, ClusterConfig, ConfigError,
    DbSettings, ExecModel, FabricKind, FaultSettings, NmSettings, ProxySettings, RdmaSettings,
    RingSettings, SchedMode, StageConfig, TraceSettings,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_json() {
        let cfg = ClusterConfig::i2v_default();
        let json = cfg.to_json();
        let back = ClusterConfig::from_json_str(&json.to_string_compact()).unwrap();
        assert_eq!(back.apps.len(), cfg.apps.len());
        assert_eq!(back.apps[0].stages.len(), cfg.apps[0].stages.len());
        assert_eq!(back.nm.util_threshold, cfg.nm.util_threshold);
    }

    #[test]
    fn validation_rejects_empty_apps() {
        let mut cfg = ClusterConfig::i2v_default();
        cfg.apps.clear();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_rejects_zero_exec() {
        let mut cfg = ClusterConfig::i2v_default();
        cfg.apps[0].stages[0].exec_ms = 0.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn cached_example_config_parses() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("examples/configs/cached_i2v.json");
        let cfg = ClusterConfig::from_file(&path).unwrap();
        let cache = cfg.cache.expect("cached_i2v.json must carry a cache block");
        assert_eq!(cache.salt, "wan2.1-v1");
        assert_eq!(cache.stages, vec!["text_encoder", "vae_encode", "vae_decode"]);
        assert!(cache.workflow);
        assert_eq!(cache.ttl_ms, 300_000);
        assert_eq!(cache.hot_capacity_bytes, 4 << 20);
    }

    #[test]
    fn example_config_file_parses() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("examples/configs/i2v_cluster.json");
        let cfg = ClusterConfig::from_file(&path).unwrap();
        assert_eq!(cfg.sets, 2);
        assert_eq!(cfg.apps.len(), 2);
        assert_eq!(cfg.apps[1].name, "t2v");
        assert!(cfg.nm.auto_rebalance);
        assert_eq!(cfg.apps[0].stages[2].mode, SchedMode::Collaboration);
        assert_eq!(
            cfg.apps[0].stages[2].exec,
            ExecModel::Artifact("diffusion_step".into())
        );
    }
}
