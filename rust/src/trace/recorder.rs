//! The per-component flight recorder: a fixed-capacity MPSC event ring
//! with overwrite-oldest semantics and a lock-free hot path.
//!
//! `record` is a head `fetch_add` plus seven atomic stores into one
//! slot (a seqlock generation word bracketing five payload words) — no
//! locks, no allocation, no branches on the drain side's state. The
//! collector drains with a cursor: slots the writers have lapped are
//! counted as overwritten (newest events win, per flight-recorder
//! convention), torn reads are detected by the generation word and
//! retried on the next drain.
//!
//! Memory bound: `capacity * 48 bytes` per recorder, fixed at
//! construction from `trace.buffer_events` — a recorder can never grow,
//! so tracing at any traffic level has a constant footprint.

use super::TraceEvent;
use crate::metrics::Counter;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Payload words per slot (packed [`TraceEvent`]).
pub(crate) const EVENT_WORDS: usize = 5;

struct Slot {
    /// Generation word: `2*idx + 1` while the writer of global index
    /// `idx` is mid-write, `2*idx + 2` once its words are published.
    /// Monotone across laps, so a drain can tell "not yet written",
    /// "torn / in progress", and "overwritten by a later lap" apart.
    seq: AtomicU64,
    words: [AtomicU64; EVENT_WORDS],
}

impl Slot {
    fn empty() -> Self {
        Self {
            seq: AtomicU64::new(0),
            words: [const { AtomicU64::new(0) }; EVENT_WORDS],
        }
    }
}

/// Bounded MPSC trace-event ring. Writers never block and never
/// allocate; the single drain side (the collector, under its own lock)
/// advances a cursor it owns.
pub struct FlightRecorder {
    slots: Box<[Slot]>,
    /// Next global write index (monotone; slot = index % capacity).
    head: AtomicU64,
    /// Events recorded (shared `trace_events_total` handle).
    events: Arc<Counter>,
}

impl FlightRecorder {
    /// Fixed capacity ring; `cap` is clamped to at least 16 slots.
    pub fn new(cap: usize, events: Arc<Counter>) -> Self {
        let cap = cap.max(16);
        let slots: Vec<Slot> = (0..cap).map(|_| Slot::empty()).collect();
        Self {
            slots: slots.into_boxed_slice(),
            head: AtomicU64::new(0),
            events,
        }
    }

    /// Slot capacity (the memory bound divided by the slot size).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever recorded (monotone; `head`).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Record one event: claim a global index, stamp the slot's
    /// generation odd (write in progress), store the five packed words,
    /// stamp it even. A reader that races any step sees a generation
    /// mismatch and discards the torn read; a writer that laps a slow
    /// reader simply overwrites — oldest events go first.
    pub fn record(&self, ev: TraceEvent) {
        let idx = self.head.fetch_add(1, Ordering::AcqRel);
        let slot = &self.slots[(idx % self.slots.len() as u64) as usize];
        slot.seq.store(2 * idx + 1, Ordering::Release);
        let w = ev.pack();
        for (dst, src) in slot.words.iter().zip(w) {
            dst.store(src, Ordering::Relaxed);
        }
        slot.seq.store(2 * idx + 2, Ordering::Release);
        self.events.inc();
    }

    /// Drain events with global index in `[cursor, head)` into `out`.
    /// Returns `(new_cursor, lost)` where `lost` counts events
    /// overwritten before this drain reached them (writers lapped the
    /// cursor) plus generations that vanished mid-read. A slot still
    /// being written stops the drain early (its index is re-offered
    /// next time), so no event is skipped while its writer is active.
    pub fn drain_from(&self, cursor: u64, out: &mut Vec<TraceEvent>) -> (u64, u64) {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let start = cursor.max(head.saturating_sub(cap));
        let mut lost = start - cursor;
        let mut idx = start;
        while idx < head {
            let slot = &self.slots[(idx % cap) as usize];
            let want = 2 * idx + 2;
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 < want {
                // This index's writer has claimed but not finished (or
                // the claim raced our head read): stop here and retry
                // on the next drain rather than lose an in-flight event.
                break;
            }
            if s1 == want {
                let mut w = [0u64; EVENT_WORDS];
                for (dst, src) in w.iter_mut().zip(&slot.words) {
                    *dst = src.load(Ordering::Relaxed);
                }
                if slot.seq.load(Ordering::Acquire) == want {
                    if let Some(ev) = TraceEvent::unpack(w) {
                        out.push(ev);
                    } else {
                        lost += 1;
                    }
                } else {
                    lost += 1; // lapped mid-read: the newer event wins
                }
            } else {
                lost += 1; // already overwritten by a later lap
            }
            idx += 1;
        }
        (idx, lost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{EventKind, Verdict};
    use crate::util::Uid;

    fn ev(i: u64) -> TraceEvent {
        TraceEvent {
            uid: Uid(i as u128),
            t_ns: i,
            kind: EventKind::Enqueued,
            stage: Some(1),
            set: 0,
            node: 3,
        }
    }

    #[test]
    fn record_drain_roundtrip() {
        let rec = FlightRecorder::new(64, Arc::new(Counter::default()));
        for i in 0..10 {
            rec.record(ev(i));
        }
        let mut out = Vec::new();
        let (cur, lost) = rec.drain_from(0, &mut out);
        assert_eq!((cur, lost), (10, 0));
        assert_eq!(out.len(), 10);
        for (i, e) in out.iter().enumerate() {
            assert_eq!(e.uid.0, i as u128);
        }
        // Idempotent past the cursor.
        let (cur2, lost2) = rec.drain_from(cur, &mut out);
        assert_eq!((cur2, lost2), (10, 0));
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn overflow_keeps_newest() {
        let rec = FlightRecorder::new(16, Arc::new(Counter::default()));
        for i in 0..100 {
            rec.record(ev(i));
        }
        let mut out = Vec::new();
        let (cur, lost) = rec.drain_from(0, &mut out);
        assert_eq!(cur, 100);
        assert_eq!(lost, 84, "all but the newest `cap` are overwritten");
        assert_eq!(out.len(), 16);
        let uids: Vec<u128> = out.iter().map(|e| e.uid.0).collect();
        assert_eq!(uids, (84..100).collect::<Vec<u128>>(), "newest survive");
    }

    #[test]
    fn terminal_event_packs_roundtrip() {
        for v in [
            Verdict::Done,
            Verdict::Cancelled,
            Verdict::DeadlineExceeded,
            Verdict::Failed,
        ] {
            let e = TraceEvent {
                uid: Uid(u128::MAX - 7),
                t_ns: u64::MAX / 3,
                kind: EventKind::Terminal { verdict: v },
                stage: None,
                set: 2,
                node: 65000,
            };
            assert_eq!(TraceEvent::unpack(e.pack()), Some(e));
        }
    }

    #[test]
    fn concurrent_writers_all_events_land() {
        let rec = Arc::new(FlightRecorder::new(4096, Arc::new(Counter::default())));
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let r = rec.clone();
                std::thread::spawn(move || {
                    for i in 0..256u64 {
                        r.record(ev(t * 1000 + i));
                    }
                })
            })
            .collect();
        for t in threads {
            if t.join().is_err() {
                panic!("writer thread panicked");
            }
        }
        let mut out = Vec::new();
        let (cur, lost) = rec.drain_from(0, &mut out);
        assert_eq!(cur, 1024);
        assert_eq!(lost, 0, "ring larger than the write volume loses nothing");
        assert_eq!(out.len(), 1024);
        let set: std::collections::HashSet<u128> = out.iter().map(|e| e.uid.0).collect();
        assert_eq!(set.len(), 1024, "every event distinct and present");
    }
}
