//! Drain-time stitching: per-component event streams → per-UID traces.
//!
//! The collector owns no locks of its own — it lives behind the
//! [`super::Tracer`]'s single witness mutex (rank `RANK_TRACE`) and is
//! only touched at drain time, never on the record path. Both of its
//! stores are bounded: in-flight requests beyond `MAX_PENDING` evict
//! the oldest-started (a leak guard against requests whose terminal
//! event was overwritten), and kept traces beyond `MAX_KEPT` evict
//! FIFO, so tracing memory is constant regardless of traffic.

use super::{EventKind, TraceEvent, Verdict};
use crate::util::Uid;
use std::collections::{HashMap, VecDeque};

/// In-flight UIDs tracked before their terminal event arrives.
const MAX_PENDING: usize = 8192;
/// Completed traces retained for `trace_of` / reports.
const MAX_KEPT: usize = 512;

/// Per-stage latency attribution for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageBreakdown {
    pub stage: u32,
    /// Enqueued → Dequeued on this stage's scheduler queue.
    pub queue_ns: u64,
    /// ExecBegin → ExecEnd on this stage's worker.
    pub exec_ns: u64,
    /// Previous hop's handoff (Delivered, or Admitted for the first
    /// stage) → this stage's Enqueued: ring + fabric + descriptor time.
    pub transit_ns: u64,
}

/// One stitched request trace: every surviving event, time-ordered.
#[derive(Debug, Clone)]
pub struct Trace {
    pub uid: Uid,
    pub events: Vec<TraceEvent>,
    /// First event → terminal event.
    pub total_ns: u64,
    /// Terminal outcome (`None` only if the terminal event itself was
    /// overwritten — the trace is then a partial record).
    pub verdict: Option<Verdict>,
}

impl Trace {
    fn from_events(uid: Uid, mut events: Vec<TraceEvent>) -> Self {
        events.sort_by_key(|e| e.t_ns);
        let total_ns = match (events.first(), events.last()) {
            (Some(a), Some(b)) => b.t_ns - a.t_ns,
            _ => 0,
        };
        let verdict = events.iter().rev().find_map(|e| match e.kind {
            EventKind::Terminal { verdict } => Some(verdict),
            _ => None,
        });
        Self {
            uid,
            events,
            total_ns,
            verdict,
        }
    }

    /// Ordered distinct stages the request visited (first touch wins).
    pub fn stage_path(&self) -> Vec<u32> {
        let mut path = Vec::new();
        for e in &self.events {
            if let Some(s) = e.stage {
                if matches!(
                    e.kind,
                    EventKind::Enqueued | EventKind::Dequeued | EventKind::ExecBegin
                ) && !path.contains(&s)
                {
                    path.push(s);
                }
            }
        }
        path
    }

    /// First timestamp of `kind` at `stage` (events are time-sorted).
    fn first(&self, stage: u32, kind: &EventKind) -> Option<u64> {
        self.events
            .iter()
            .find(|e| e.stage == Some(stage) && e.kind.label() == kind.label())
            .map(|e| e.t_ns)
    }

    /// Queue-wait vs execute vs transit per visited stage, in path
    /// order. Missing sub-spans (an event lost to overwrite) report 0
    /// rather than poisoning the rest of the breakdown.
    pub fn breakdown(&self) -> Vec<StageBreakdown> {
        let mut out = Vec::new();
        // Handoff = when the previous hop released the request.
        let mut handoff = self
            .events
            .iter()
            .find(|e| matches!(e.kind, EventKind::Admitted))
            .map(|e| e.t_ns);
        for stage in self.stage_path() {
            let enq = self.first(stage, &EventKind::Enqueued);
            let deq = self.first(stage, &EventKind::Dequeued);
            let begin = self.first(stage, &EventKind::ExecBegin);
            let end = self.first(stage, &EventKind::ExecEnd);
            let sub = |a: Option<u64>, b: Option<u64>| match (a, b) {
                (Some(a), Some(b)) => b.saturating_sub(a),
                _ => 0,
            };
            out.push(StageBreakdown {
                stage,
                queue_ns: sub(enq, deq),
                exec_ns: sub(begin, end),
                transit_ns: sub(handoff, enq),
            });
            handoff = self.first(stage, &EventKind::Delivered).or(end).or(handoff);
        }
        out
    }

    /// The critical path: time-ordered labelled segments summing to
    /// `total_ns`. Time not attributed to a queue/exec/transit span
    /// (final delivery, tracker settling) lands in a closing
    /// `delivery/other` segment.
    pub fn critical_path(&self) -> Vec<(String, u64)> {
        let mut segs: Vec<(String, u64)> = Vec::new();
        for b in self.breakdown() {
            if b.transit_ns > 0 {
                segs.push((format!("transit→s{}", b.stage), b.transit_ns));
            }
            if b.queue_ns > 0 {
                segs.push((format!("s{} queue", b.stage), b.queue_ns));
            }
            if b.exec_ns > 0 {
                segs.push((format!("s{} exec", b.stage), b.exec_ns));
            }
        }
        let attributed: u64 = segs.iter().map(|(_, ns)| ns).sum();
        let tail = self.total_ns.saturating_sub(attributed);
        if tail > 0 || segs.is_empty() {
            segs.push(("delivery/other".to_string(), tail));
        }
        segs
    }
}

struct Pending {
    events: Vec<TraceEvent>,
    /// Earliest timestamp seen — eviction order under `MAX_PENDING`.
    first_ns: u64,
}

/// Bounded per-UID assembly state. See the module docs for the bounds.
pub(super) struct Collector {
    pending: HashMap<u128, Pending>,
    kept: VecDeque<Trace>,
}

impl Collector {
    pub(super) fn new() -> Self {
        Self {
            pending: HashMap::new(),
            kept: VecDeque::new(),
        }
    }

    /// Append one drained event to its UID's pending record.
    pub(super) fn absorb(&mut self, ev: TraceEvent) {
        if self.pending.len() >= MAX_PENDING && !self.pending.contains_key(&ev.uid.0) {
            // Evict the oldest-started in-flight UID (its terminal
            // event was probably overwritten; keep memory bounded).
            if let Some(&oldest) = self
                .pending
                .iter()
                .min_by_key(|(_, p)| p.first_ns)
                .map(|(uid, _)| uid)
            {
                self.pending.remove(&oldest);
            }
        }
        let entry = self.pending.entry(ev.uid.0).or_insert(Pending {
            events: Vec::new(),
            first_ns: ev.t_ns,
        });
        entry.first_ns = entry.first_ns.min(ev.t_ns);
        entry.events.push(ev);
    }

    /// Span of the pending record (terminal just absorbed): the slow-
    /// request tail rule compares this against its threshold.
    pub(super) fn pending_duration_ns(&self, uid: Uid) -> u64 {
        self.pending
            .get(&uid.0)
            .map(|p| {
                let max = p.events.iter().map(|e| e.t_ns).max().unwrap_or(p.first_ns);
                max - p.first_ns
            })
            .unwrap_or(0)
    }

    /// Close out a UID whose terminal event arrived. `keep == true`
    /// stitches and retains the trace (FIFO-evicting past `MAX_KEPT`);
    /// `false` discards the events. Returns `keep`.
    pub(super) fn finalize(&mut self, uid: Uid, keep: bool) -> bool {
        let Some(p) = self.pending.remove(&uid.0) else {
            return false;
        };
        if keep {
            if self.kept.len() >= MAX_KEPT {
                self.kept.pop_front();
            }
            self.kept.push_back(Trace::from_events(uid, p.events));
        }
        keep
    }

    /// The kept trace for `uid`, if retained (newest wins on replay).
    pub(super) fn kept(&self, uid: Uid) -> Option<Trace> {
        self.kept.iter().rev().find(|t| t.uid == uid).cloned()
    }

    /// All kept traces, oldest first.
    pub(super) fn all_kept(&self) -> Vec<Trace> {
        self.kept.iter().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(uid: u128, t_ns: u64, stage: Option<u32>, kind: EventKind) -> TraceEvent {
        TraceEvent {
            uid: Uid(uid),
            t_ns,
            kind,
            stage,
            set: 0,
            node: 1,
        }
    }

    /// A two-stage request with known span widths.
    fn two_stage_events(uid: u128) -> Vec<TraceEvent> {
        vec![
            ev(uid, 0, None, EventKind::Admitted),
            ev(uid, 100, Some(0), EventKind::Enqueued), // transit 100
            ev(uid, 150, Some(0), EventKind::Dequeued), // queue 50
            ev(uid, 160, Some(0), EventKind::ExecBegin),
            ev(uid, 460, Some(0), EventKind::ExecEnd), // exec 300
            ev(uid, 500, Some(0), EventKind::Delivered),
            ev(uid, 700, Some(1), EventKind::Enqueued), // transit 200
            ev(uid, 710, Some(1), EventKind::Dequeued), // queue 10
            ev(uid, 720, Some(1), EventKind::ExecBegin),
            ev(uid, 1120, Some(1), EventKind::ExecEnd), // exec 400
            ev(uid, 1150, Some(1), EventKind::Delivered),
            ev(uid, 1200, None, EventKind::Terminal { verdict: Verdict::Done }),
        ]
    }

    fn stitched(uid: u128) -> Trace {
        let mut c = Collector::new();
        for e in two_stage_events(uid) {
            c.absorb(e);
        }
        assert_eq!(c.pending_duration_ns(Uid(uid)), 1200);
        assert!(c.finalize(Uid(uid), true));
        c.kept(Uid(uid)).expect("kept")
    }

    #[test]
    fn breakdown_attributes_queue_exec_transit() {
        let t = stitched(9);
        assert_eq!(t.total_ns, 1200);
        assert_eq!(t.verdict, Some(Verdict::Done));
        assert_eq!(t.stage_path(), vec![0, 1]);
        let b = t.breakdown();
        assert_eq!(
            b,
            vec![
                StageBreakdown { stage: 0, queue_ns: 50, exec_ns: 300, transit_ns: 100 },
                StageBreakdown { stage: 1, queue_ns: 10, exec_ns: 400, transit_ns: 200 },
            ]
        );
    }

    #[test]
    fn critical_path_sums_to_total() {
        let t = stitched(9);
        let cp = t.critical_path();
        let sum: u64 = cp.iter().map(|(_, ns)| ns).sum();
        assert_eq!(sum, t.total_ns, "segments cover the full span: {cp:?}");
        assert_eq!(cp.last().map(|(n, _)| n.as_str()), Some("delivery/other"));
    }

    #[test]
    fn out_of_order_absorption_still_stitches() {
        let mut c = Collector::new();
        let mut evs = two_stage_events(4);
        evs.reverse(); // recorders drain in arbitrary interleavings
        for e in evs {
            c.absorb(e);
        }
        c.finalize(Uid(4), true);
        let t = c.kept(Uid(4)).expect("kept");
        assert_eq!(t.stage_path(), vec![0, 1]);
        assert_eq!(t.total_ns, 1200);
    }

    #[test]
    fn discarded_finalize_drops_events() {
        let mut c = Collector::new();
        for e in two_stage_events(7) {
            c.absorb(e);
        }
        assert!(!c.finalize(Uid(7), false));
        assert!(c.kept(Uid(7)).is_none());
        assert_eq!(c.pending_duration_ns(Uid(7)), 0, "pending cleared");
    }

    #[test]
    fn kept_store_evicts_fifo() {
        let mut c = Collector::new();
        for uid in 0..(MAX_KEPT as u128 + 10) {
            c.absorb(ev(uid, uid as u64, None, EventKind::Admitted));
            c.absorb(ev(
                uid,
                uid as u64 + 1,
                None,
                EventKind::Terminal { verdict: Verdict::Done },
            ));
            c.finalize(Uid(uid), true);
        }
        assert_eq!(c.all_kept().len(), MAX_KEPT);
        assert!(c.kept(Uid(0)).is_none(), "oldest evicted");
        assert!(c.kept(Uid(MAX_KEPT as u128 + 9)).is_some(), "newest kept");
    }

    #[test]
    fn pending_store_evicts_oldest_started() {
        let mut c = Collector::new();
        for uid in 0..(MAX_PENDING as u128 + 5) {
            c.absorb(ev(uid, uid as u64, None, EventKind::Admitted));
        }
        assert_eq!(c.pending.len(), MAX_PENDING);
        assert!(!c.pending.contains_key(&0), "oldest-started evicted");
        assert!(c.pending.contains_key(&(MAX_PENDING as u128 + 4)));
    }
}
