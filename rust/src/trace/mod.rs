//! Distributed request tracing: per-UID spans across admission →
//! schedule → ring hops → batch → execute → delivery.
//!
//! Off by default and lock-light by construction (DESIGN.md
//! "Observability"):
//!
//! - **Event model** — [`TraceEvent`]: one request UID, a monotonic
//!   timestamp from the set [`crate::util::Clock`], stage / instance /
//!   set attribution, and a typed [`EventKind`] (Admitted, Enqueued,
//!   Dequeued, BatchFormed, ExecBegin/End, RingPush, RendezvousRead,
//!   CacheHit/Miss, Checkpoint, Delivered, Replayed, Routed,
//!   Terminal{verdict}). Events pack into five `u64` words so the
//!   recorder slots are fixed-size and allocation-free.
//! - **Flight recorder** — [`FlightRecorder`]: a bounded per-component
//!   MPSC ring, overwrite-oldest; `record` is a few atomics and a slot
//!   write (see `recorder.rs`).
//! - **Collector** — [`Tracer::drain`] stitches per-component buffers
//!   into per-UID [`Trace`]s at drain time, reconstructs the stage
//!   path, and computes queue-wait vs execute vs transit breakdowns
//!   plus the critical path (see `collector.rs`).
//! - **Sampling** — head sampling by UID hash at
//!   `trace.sample_rate` decides which *completed* traces are kept;
//!   `trace.always_sample_slow_ms` force-keeps any completed trace
//!   slower than the threshold regardless of the rate (tail-based
//!   exemplars for the slow tail).
//!
//! When the deployment has no `trace` config block, no [`Tracer`] is
//! ever constructed: components carry a `None` hook, no `trace_*`
//! counters are registered, and the request path is byte-identical to
//! the untraced build (asserted in `tests/trace_semantics.rs`).

mod collector;
mod recorder;

pub use collector::{StageBreakdown, Trace};
pub use recorder::FlightRecorder;

use crate::config::TraceSettings;
use crate::lint::runtime::{WitnessMutex, RANK_TRACE};
use crate::metrics::{Counter, Registry};
use crate::util::{Clock, Uid};
use std::sync::Arc;

/// Terminal request outcome carried by [`EventKind::Terminal`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Done,
    Cancelled,
    DeadlineExceeded,
    Failed,
}

impl Verdict {
    /// Stable label for reports.
    pub fn label(self) -> &'static str {
        match self {
            Verdict::Done => "done",
            Verdict::Cancelled => "cancelled",
            Verdict::DeadlineExceeded => "deadline_exceeded",
            Verdict::Failed => "failed",
        }
    }
}

/// What happened, typed. Kinds with data keep it small enough to pack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Proxy admission accepted the request.
    Admitted,
    /// The RS thread queued the message for a stage's workers.
    Enqueued,
    /// A worker pulled the message from the scheduler queue.
    Dequeued,
    /// Batch assembly closed around this request's message.
    BatchFormed { size: u16, bypassed: bool },
    /// Stage execution started / finished (batch-amortized spans cover
    /// every member).
    ExecBegin,
    ExecEnd,
    /// The message crossed a ring (entrance forward or stage hop).
    RingPush,
    /// The consumer resolved this request's payload by a one-sided
    /// rendezvous READ.
    RendezvousRead,
    /// Artifact-cache outcome for a stage (or the whole-workflow tier
    /// at admission, stage = None).
    CacheHit,
    CacheMiss,
    /// A recovery checkpoint was written for this hop.
    Checkpoint,
    /// ResultDeliver forwarded this stage's output downstream.
    Delivered,
    /// The recovery sweep replayed the request from a checkpoint.
    Replayed,
    /// The federation router placed the request on a set.
    Routed { to_set: u16 },
    /// The request reached a terminal state.
    Terminal { verdict: Verdict },
}

impl EventKind {
    /// Stable label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::Admitted => "admitted",
            EventKind::Enqueued => "enqueued",
            EventKind::Dequeued => "dequeued",
            EventKind::BatchFormed { .. } => "batch_formed",
            EventKind::ExecBegin => "exec_begin",
            EventKind::ExecEnd => "exec_end",
            EventKind::RingPush => "ring_push",
            EventKind::RendezvousRead => "rendezvous_read",
            EventKind::CacheHit => "cache_hit",
            EventKind::CacheMiss => "cache_miss",
            EventKind::Checkpoint => "checkpoint",
            EventKind::Delivered => "delivered",
            EventKind::Replayed => "replayed",
            EventKind::Routed { .. } => "routed",
            EventKind::Terminal { .. } => "terminal",
        }
    }
}

/// One trace event: fixed-size, `Copy`, packs to five `u64` words.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub uid: Uid,
    /// Monotonic timestamp ([`Clock::now_ns`], not wall clock).
    pub t_ns: u64,
    pub kind: EventKind,
    /// Stage attribution (`None` for request-level events).
    pub stage: Option<u32>,
    /// Workflow-set index.
    pub set: u32,
    /// Node id of the recording component (proxy/instance).
    pub node: u32,
}

const STAGE_NONE: u16 = u16::MAX;

impl TraceEvent {
    /// Pack into the recorder's five slot words:
    /// `[uid_hi, uid_lo, t_ns, kind|code|aux|stage, set|node]`.
    pub(crate) fn pack(&self) -> [u64; recorder::EVENT_WORDS] {
        let (tag, code, aux): (u8, u8, u16) = match self.kind {
            EventKind::Admitted => (0, 0, 0),
            EventKind::Enqueued => (1, 0, 0),
            EventKind::Dequeued => (2, 0, 0),
            EventKind::BatchFormed { size, bypassed } => (3, bypassed as u8, size),
            EventKind::ExecBegin => (4, 0, 0),
            EventKind::ExecEnd => (5, 0, 0),
            EventKind::RingPush => (6, 0, 0),
            EventKind::RendezvousRead => (7, 0, 0),
            EventKind::CacheHit => (8, 0, 0),
            EventKind::CacheMiss => (9, 0, 0),
            EventKind::Checkpoint => (10, 0, 0),
            EventKind::Delivered => (11, 0, 0),
            EventKind::Replayed => (12, 0, 0),
            EventKind::Routed { to_set } => (13, 0, to_set),
            EventKind::Terminal { verdict } => (14, verdict as u8, 0),
        };
        let stage = self
            .stage
            .map_or(STAGE_NONE, |s| s.min(STAGE_NONE as u32 - 1) as u16);
        [
            (self.uid.0 >> 64) as u64,
            self.uid.0 as u64,
            self.t_ns,
            (tag as u64) << 40 | (code as u64) << 32 | (aux as u64) << 16 | stage as u64,
            (self.set as u64) << 32 | self.node as u64,
        ]
    }

    /// Inverse of [`TraceEvent::pack`]; `None` on an unknown kind tag
    /// (a torn slot that happened to pass the generation check).
    pub(crate) fn unpack(w: [u64; recorder::EVENT_WORDS]) -> Option<Self> {
        let tag = (w[3] >> 40) as u8;
        let code = (w[3] >> 32) as u8;
        let aux = (w[3] >> 16) as u16;
        let stage16 = w[3] as u16;
        let kind = match tag {
            0 => EventKind::Admitted,
            1 => EventKind::Enqueued,
            2 => EventKind::Dequeued,
            3 => EventKind::BatchFormed { size: aux, bypassed: code != 0 },
            4 => EventKind::ExecBegin,
            5 => EventKind::ExecEnd,
            6 => EventKind::RingPush,
            7 => EventKind::RendezvousRead,
            8 => EventKind::CacheHit,
            9 => EventKind::CacheMiss,
            10 => EventKind::Checkpoint,
            11 => EventKind::Delivered,
            12 => EventKind::Replayed,
            13 => EventKind::Routed { to_set: aux },
            14 => EventKind::Terminal {
                verdict: match code {
                    0 => Verdict::Done,
                    1 => Verdict::Cancelled,
                    2 => Verdict::DeadlineExceeded,
                    3 => Verdict::Failed,
                    _ => return None,
                },
            },
            _ => return None,
        };
        Some(Self {
            uid: Uid((w[0] as u128) << 64 | w[1] as u128),
            t_ns: w[2],
            kind,
            stage: (stage16 != STAGE_NONE).then_some(stage16 as u32),
            set: (w[4] >> 32) as u32,
            node: w[4] as u32,
        })
    }
}

/// The hot-path handle a component holds (cheap `Clone`): its flight
/// recorder, the set clock, and its attribution. Recording through a
/// hook is lock-free; a component without a hook (`None`) pays nothing.
#[derive(Clone)]
pub struct TraceHook {
    recorder: Arc<FlightRecorder>,
    clock: Arc<dyn Clock>,
    set: u32,
    node: u32,
}

impl TraceHook {
    /// Record one event now, attributed to this hook's component.
    pub fn record(&self, uid: Uid, stage: Option<u32>, kind: EventKind) {
        self.recorder.record(TraceEvent {
            uid,
            t_ns: self.clock.now_ns(),
            kind,
            stage,
            set: self.set,
            node: self.node,
        });
    }

    /// This hook re-attributed to another node id (an instance cloning
    /// the set-level hook for its own recorder would instead call
    /// [`Tracer::hook`]; this variant shares the recorder).
    pub fn for_node(&self, node: u32) -> TraceHook {
        TraceHook { node, ..self.clone() }
    }
}

/// Collector state behind the tracer's single (drain-time-only) lock.
struct TracerInner {
    recorders: Vec<(Arc<FlightRecorder>, u64)>,
    collector: collector::Collector,
}

/// The per-set tracing facade: owns every component recorder, the
/// stitching collector, and the sampling rules. Constructed only when
/// the deployment has a `trace` config block.
pub struct Tracer {
    sample_rate: f64,
    slow_ns: u64,
    buffer_events: usize,
    set: u32,
    clock: Arc<dyn Clock>,
    events_total: Arc<Counter>,
    overwritten_total: Arc<Counter>,
    kept_total: Arc<Counter>,
    sampled_out_total: Arc<Counter>,
    // Held only by drain/registration, never on the record path.
    inner: WitnessMutex<TracerInner>, // lint: lock-rank(trace, 85)
}

impl Tracer {
    /// Build a tracer for set `set`. Registers the `trace_*` counters —
    /// this is the only place they are created, so a disabled
    /// deployment's registry never shows them.
    pub fn new(
        settings: &TraceSettings,
        clock: Arc<dyn Clock>,
        set: u32,
        metrics: &Registry,
    ) -> Arc<Self> {
        Arc::new(Self {
            sample_rate: settings.sample_rate,
            slow_ns: settings.always_sample_slow_ms.saturating_mul(1_000_000),
            buffer_events: settings.buffer_events,
            set,
            clock,
            events_total: metrics.counter("trace_events_total"),
            overwritten_total: metrics.counter("trace_events_overwritten_total"),
            kept_total: metrics.counter("trace_traces_kept_total"),
            sampled_out_total: metrics.counter("trace_traces_sampled_out_total"),
            inner: WitnessMutex::new(
                "trace",
                RANK_TRACE,
                TracerInner {
                    recorders: Vec::new(),
                    collector: collector::Collector::new(),
                },
            ),
        })
    }

    /// Register a fresh flight recorder for one component and return
    /// its hot-path hook. Called at component construction (locks the
    /// collector once); the returned hook never locks.
    pub fn hook(&self, node: u32) -> TraceHook {
        let recorder = Arc::new(FlightRecorder::new(
            self.buffer_events,
            self.events_total.clone(),
        ));
        self.inner
            .lock()
            .unwrap()
            .recorders
            .push((recorder.clone(), 0));
        TraceHook {
            recorder,
            clock: self.clock.clone(),
            set: self.set,
            node,
        }
    }

    /// Head-sampling decision for one UID (deterministic hash → [0,1)
    /// against `sample_rate`, so every component agrees without
    /// coordination).
    fn sampled(&self, uid: Uid) -> bool {
        if self.sample_rate >= 1.0 {
            return true;
        }
        if self.sample_rate <= 0.0 {
            return false;
        }
        // splitmix64 over the folded UID.
        let mut z = (uid.0 as u64) ^ ((uid.0 >> 64) as u64) ^ 0x9e37_79b9_7f4a_7c15;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^= z >> 31;
        ((z >> 11) as f64 / (1u64 << 53) as f64) < self.sample_rate
    }

    /// Drain every component recorder and stitch completed requests
    /// into kept traces. Runs on the housekeeper timer and on demand
    /// from [`Tracer::trace_of`]; holds the collector lock only while
    /// stitching (the record path never contends with it).
    pub fn drain(&self) {
        let mut scratch = Vec::new();
        let mut g = self.inner.lock().unwrap();
        let mut lost = 0u64;
        for (rec, cursor) in g.recorders.iter_mut() {
            let (next, l) = rec.drain_from(*cursor, &mut scratch);
            *cursor = next;
            lost += l;
        }
        if lost > 0 {
            self.overwritten_total.add(lost);
        }
        // Events from different recorders interleave arbitrarily; the
        // collector orders per-UID by timestamp at finalization.
        let mut kept = 0u64;
        let mut dropped = 0u64;
        for ev in scratch.drain(..) {
            let uid = ev.uid;
            let terminal = matches!(ev.kind, EventKind::Terminal { .. });
            g.collector.absorb(ev);
            if terminal {
                let keep = self.sampled(uid)
                    || (self.slow_ns > 0
                        && g.collector.pending_duration_ns(uid) >= self.slow_ns);
                if g.collector.finalize(uid, keep) {
                    kept += 1;
                } else {
                    dropped += 1;
                }
            }
        }
        if kept > 0 {
            self.kept_total.add(kept);
        }
        if dropped > 0 {
            self.sampled_out_total.add(dropped);
        }
    }

    /// The stitched trace for one completed request, if it was kept
    /// (sampled in, or slow enough for the tail rule). Drains first so
    /// freshly completed requests are visible immediately.
    pub fn trace_of(&self, uid: Uid) -> Option<Trace> {
        self.drain();
        self.inner.lock().unwrap().collector.kept(uid)
    }

    /// All kept traces, oldest first (report/CLI surface). Drains first.
    pub fn completed(&self) -> Vec<Trace> {
        self.drain();
        self.inner.lock().unwrap().collector.all_kept()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ManualClock;

    fn tracer(rate: f64, slow_ms: u64, clock: Arc<ManualClock>) -> Arc<Tracer> {
        Tracer::new(
            &TraceSettings {
                sample_rate: rate,
                buffer_events: 256,
                always_sample_slow_ms: slow_ms,
            },
            clock,
            0,
            &Registry::new(),
        )
    }

    fn run_request(hook: &TraceHook, clock: &ManualClock, uid: Uid, dur_ns: u64) {
        hook.record(uid, None, EventKind::Admitted);
        clock.advance(dur_ns);
        hook.record(uid, None, EventKind::Terminal { verdict: Verdict::Done });
    }

    #[test]
    fn sample_rate_one_keeps_everything() {
        let clock = Arc::new(ManualClock::new());
        let t = tracer(1.0, 0, clock.clone());
        let hook = t.hook(1);
        for i in 0..20 {
            run_request(&hook, &clock, Uid(i), 1_000);
        }
        t.drain();
        assert_eq!(t.completed().len(), 20);
        assert!(t.trace_of(Uid(7)).is_some());
    }

    #[test]
    fn sample_rate_zero_drops_fast_requests() {
        let clock = Arc::new(ManualClock::new());
        let t = tracer(0.0, 0, clock.clone());
        let hook = t.hook(1);
        run_request(&hook, &clock, Uid(1), 1_000);
        assert!(t.trace_of(Uid(1)).is_none());
    }

    #[test]
    fn tail_rule_force_keeps_slow_requests() {
        let clock = Arc::new(ManualClock::new());
        let t = tracer(0.0, 5, clock.clone()); // keep ≥ 5 ms
        let hook = t.hook(1);
        run_request(&hook, &clock, Uid(1), 1_000_000); // 1 ms: dropped
        run_request(&hook, &clock, Uid(2), 9_000_000); // 9 ms: kept
        assert!(t.trace_of(Uid(1)).is_none(), "fast request sampled out");
        let slow = t.trace_of(Uid(2)).expect("slow request force-kept");
        assert_eq!(slow.total_ns, 9_000_000);
        assert_eq!(slow.verdict, Some(Verdict::Done));
    }

    #[test]
    fn fractional_rate_is_deterministic_and_roughly_proportional() {
        let clock = Arc::new(ManualClock::new());
        let t = tracer(0.5, 0, clock.clone());
        let hook = t.hook(1);
        for i in 0..400 {
            run_request(&hook, &clock, Uid(i), 100);
        }
        let kept = t.completed().len();
        assert!(
            (100..300).contains(&kept),
            "~50% of 400 expected, got {kept}"
        );
        // Deterministic: the same UID always decides the same way.
        let first = t.trace_of(Uid(3)).is_some();
        assert_eq!(t.trace_of(Uid(3)).is_some(), first);
    }
}
