//! Discrete-event model of multi-set federation (E11): N Workflow Sets
//! with window-budget fast-reject admission (§5), client preference skew,
//! cross-set spill, and optional elastic capacity donation (the
//! federation analogue of §8.2 idle-pool scaling).
//!
//! The model answers the deployment questions the real-stack
//! `onepiece federate` driver is too slow to sweep: how reject rate,
//! spill volume, and tail latency move as the routing policy changes from
//! the paper's client-side random retry (§3.2) to the
//! [`crate::federation::FederationRouter`]'s load-aware-plus-spill
//! policy, and as elastic donation is switched on.

use super::{percentile, ArrivalProcess};
use crate::util::Rng;
use std::collections::VecDeque;

/// How requests pick a Workflow Set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FedPolicy {
    /// §3.2 client-side policy: submit to the (preference-weighted)
    /// random set, then retry the others in ring order on fast-reject.
    RandomSpill,
    /// Federation policy: least-loaded set first, spill in
    /// ascending-load order.
    LoadAware,
}

/// Federation model parameters.
#[derive(Debug, Clone)]
pub struct FedSimConfig {
    /// Number of Workflow Sets.
    pub sets: usize,
    /// Per-set sustainable admission rate `K/T_X` (§5).
    pub capacity_rps: f64,
    /// End-to-end service time of an admitted request (normalized
    /// pipeline latency).
    pub service_s: f64,
    /// Admission monitor window.
    pub window_s: f64,
    pub duration_s: f64,
    /// Client regional affinity: preference weight of set `i` is
    /// `1 / (1 + skew·i)`; `0.0` = uniform.
    pub skew: f64,
    pub policy: FedPolicy,
    /// Move capacity between sets on a timer (cross-set donation).
    pub elastic: bool,
    pub rebalance_period_s: f64,
    /// Worker-failure model: mean time between instance crashes across
    /// the fleet (0 = no crashes). Each crash strands the victim
    /// server's in-flight/queued requests; they replay after
    /// `detect_s` (the E13 detect → repair → replay loop).
    pub mtbf_s: f64,
    /// Failure-detector delay: heartbeat-silence timeout + one
    /// housekeeper sweep, paid once per crash by every stranded
    /// request before its replay starts.
    pub detect_s: f64,
    /// Micro-batching model: member cap per batch (1 = batching off).
    /// When a server is backlogged, queued requests coalesce up to this
    /// and each pays the amortized service time
    /// `service_s × (α + (1−α)·n) / n`; an idle server serves at full
    /// `service_s` (there is nothing to coalesce with — mirrors the
    /// adaptive window collapsing at low load).
    pub batch_max: usize,
    /// Amortizable (batch-invariant) fraction α of the service time —
    /// the [`crate::workflow::I2V_BATCH_FIXED_FRAC`] analogue.
    pub batch_alpha: f64,
}

impl FedSimConfig {
    /// A balanced baseline: `sets` sets, uniform preference, load-aware
    /// routing, no elasticity.
    pub fn balanced(sets: usize, capacity_rps: f64, duration_s: f64) -> Self {
        Self {
            sets,
            capacity_rps,
            service_s: 1.0,
            window_s: 2.0,
            duration_s,
            skew: 0.0,
            policy: FedPolicy::LoadAware,
            elastic: false,
            rebalance_period_s: 5.0,
            mtbf_s: 0.0,
            detect_s: 0.2,
            batch_max: 1,
            batch_alpha: 0.7,
        }
    }
}

/// Aggregate outcome of one federation simulation.
#[derive(Debug, Clone)]
pub struct FedSimOutcome {
    pub offered: usize,
    pub admitted: usize,
    pub rejected: usize,
    /// Admitted by a set other than the router's first choice.
    pub spilled: usize,
    /// Cross-set capacity moves (elastic mode).
    pub donations: usize,
    /// Instance crashes injected (fault model).
    pub crashes: usize,
    /// Requests stranded on a crashed server and replayed.
    pub replays: usize,
    /// Requests served at the amortized (batched) cost — backlogged
    /// arrivals that coalesced under the batching model.
    pub amortized: usize,
    /// Requests finishing within the simulated horizon.
    pub completed: usize,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub per_set_admitted: Vec<usize>,
}

impl FedSimOutcome {
    /// Fraction of offered requests rejected by every set.
    pub fn reject_rate(&self) -> f64 {
        self.rejected as f64 / (self.offered.max(1)) as f64
    }

    /// Spread of admitted traffic across sets (max − min), a balance
    /// measure.
    pub fn admitted_spread(&self) -> usize {
        let max = self.per_set_admitted.iter().copied().max().unwrap_or(0);
        let min = self.per_set_admitted.iter().copied().min().unwrap_or(0);
        max - min
    }
}

/// One modelled Workflow Set: window-budget admission + FIFO servers.
struct SimSet {
    capacity_rps: f64,
    /// Per-server next-free times; length tracks donated capacity quanta.
    servers: Vec<f64>,
    /// Admission timestamps inside the monitor window.
    window: VecDeque<f64>,
    admitted: usize,
}

impl SimSet {
    fn new(capacity_rps: f64, service_s: f64) -> Self {
        let n = (capacity_rps * service_s).ceil().max(1.0) as usize;
        Self {
            capacity_rps,
            servers: vec![0.0; n],
            window: VecDeque::new(),
            admitted: 0,
        }
    }

    fn evict(&mut self, t: f64, window_s: f64) {
        while self.window.front().is_some_and(|&x| x < t - window_s) {
            self.window.pop_front();
        }
    }

    /// Normalized admission load (∞ for a set with no capacity).
    fn load(&mut self, t: f64, window_s: f64) -> f64 {
        if self.capacity_rps <= 0.0 {
            return f64::INFINITY;
        }
        self.evict(t, window_s);
        (self.window.len() as f64 / window_s) / self.capacity_rps
    }

    /// The §5 fast-reject decision.
    fn try_admit(&mut self, t: f64, window_s: f64) -> bool {
        if self.capacity_rps <= 0.0 {
            return false;
        }
        self.evict(t, window_s);
        let budget = ((self.capacity_rps * window_s).floor() as usize).max(1);
        if self.window.len() >= budget {
            return false;
        }
        self.window.push_back(t);
        self.admitted += 1;
        true
    }

    /// FIFO dispatch onto the earliest-free server; returns the chosen
    /// server index, completion time, and whether the request was
    /// served at the amortized (batched) cost. A backlogged server
    /// coalesces queued requests up to `batch_max`, so each pays
    /// `service_s × (α + (1−α)·n) / n`; an idle server serves one
    /// request at full cost (nothing to coalesce with).
    fn serve(
        &mut self,
        t: f64,
        service_s: f64,
        batch_max: usize,
        batch_alpha: f64,
    ) -> (usize, f64, bool) {
        let (idx, earliest) = self
            .servers
            .iter()
            .copied()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let backlogged = t < earliest;
        let eff = if batch_max > 1 && backlogged {
            let n = batch_max as f64;
            service_s * (batch_alpha + (1.0 - batch_alpha) * n) / n
        } else {
            service_s
        };
        let end = t.max(earliest) + eff;
        self.servers[idx] = end;
        (idx, end, batch_max > 1 && backlogged)
    }
}

/// One admitted request's bookkeeping (needed so the fault model can
/// replay requests stranded on a crashed server).
struct Record {
    admit: f64,
    end: f64,
    set: usize,
    server: usize,
}

/// One instance crash at `tc`: everything in flight / queued on a
/// random server replays after the detector fires, re-executing
/// sequentially on the repaired server (the E13 loop). Nothing is lost
/// — the stranded requests just pay detection + requeue delay. Returns
/// how many requests were replayed.
fn crash_once(
    sets: &mut [SimSet],
    records: &mut [Record],
    rng: &mut Rng,
    tc: f64,
    detect_s: f64,
    service_s: f64,
) -> usize {
    let i = rng.below(sets.len() as u64) as usize;
    let j = rng.below(sets[i].servers.len() as u64) as usize;
    let mut restart = tc + detect_s;
    let mut affected: Vec<&mut Record> = records
        .iter_mut()
        .filter(|r| r.set == i && r.server == j && r.end > tc)
        .collect();
    affected.sort_by(|a, b| a.end.partial_cmp(&b.end).unwrap());
    let replayed = affected.len();
    for r in affected {
        restart += service_s;
        r.end = restart;
    }
    // The server is back (repaired) once detection + replays end.
    sets[i].servers[j] = restart;
    replayed
}

/// Run the federation model over one arrival trace.
pub fn simulate_federation(
    cfg: &FedSimConfig,
    process: &ArrivalProcess,
    seed: u64,
) -> FedSimOutcome {
    let arrivals = process.generate(seed, cfg.duration_s);
    let mut rng = Rng::new(seed ^ 0x5EED_F00D);
    let mut sets: Vec<SimSet> = (0..cfg.sets.max(1))
        .map(|_| SimSet::new(cfg.capacity_rps, cfg.service_s))
        .collect();
    let n = sets.len();
    let weights: Vec<f64> = (0..n).map(|i| 1.0 / (1.0 + cfg.skew * i as f64)).collect();
    let wsum: f64 = weights.iter().sum();
    // One server's worth of admission capacity moves per donation.
    let quantum_rps = 1.0 / cfg.service_s;

    let mut records: Vec<Record> = Vec::new();
    let mut rejected = 0usize;
    let mut spilled = 0usize;
    let mut donations = 0usize;
    let mut crashes = 0usize;
    let mut replays = 0usize;
    let mut amortized = 0usize;
    let mut next_rebalance = cfg.rebalance_period_s;
    let mut next_crash = if cfg.mtbf_s > 0.0 { cfg.mtbf_s } else { f64::INFINITY };

    for &t in &arrivals {
        // --- fault model: periodic instance crashes ---
        while t >= next_crash {
            crashes += 1;
            replays += crash_once(
                &mut sets,
                &mut records,
                &mut rng,
                next_crash,
                cfg.detect_s,
                cfg.service_s,
            );
            next_crash += cfg.mtbf_s;
        }
        // --- elastic donation timer ---
        while cfg.elastic && t >= next_rebalance {
            let loads: Vec<f64> = sets
                .iter_mut()
                .map(|s| s.load(next_rebalance, cfg.window_s))
                .collect();
            let hot = (0..n)
                .filter(|&i| loads[i].is_finite())
                .max_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap());
            let cold = (0..n).min_by(|&a, &b| {
                loads[a]
                    .partial_cmp(&loads[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            if let (Some(hot), Some(cold)) = (hot, cold) {
                if hot != cold
                    && loads[hot] >= 0.9
                    && loads[cold] <= 0.5
                    && sets[cold].servers.len() > 1
                {
                    // Retire records bound to the popped server slot:
                    // its identity disappears, so they must no longer be
                    // addressable by a later crash picking the same
                    // index (their completion times stay as scheduled).
                    let popped = sets[cold].servers.len() - 1;
                    for r in records
                        .iter_mut()
                        .filter(|r| r.set == cold && r.server == popped)
                    {
                        r.server = usize::MAX;
                    }
                    sets[cold].servers.pop();
                    sets[cold].capacity_rps =
                        (sets[cold].capacity_rps - quantum_rps).max(0.0);
                    sets[hot].servers.push(next_rebalance);
                    sets[hot].capacity_rps += quantum_rps;
                    donations += 1;
                }
            }
            next_rebalance += cfg.rebalance_period_s;
        }

        // --- preferred set (client regional affinity) ---
        let mut pick = rng.f64() * wsum;
        let mut pref = n - 1;
        for (i, w) in weights.iter().enumerate() {
            if pick < *w {
                pref = i;
                break;
            }
            pick -= w;
        }

        // --- routing order per policy ---
        let order: Vec<usize> = match cfg.policy {
            FedPolicy::RandomSpill => (0..n).map(|k| (pref + k) % n).collect(),
            FedPolicy::LoadAware => {
                let loads: Vec<f64> =
                    sets.iter_mut().map(|s| s.load(t, cfg.window_s)).collect();
                // Same ordering function the real router uses, so the
                // model predicts exactly the deployed policy.
                crate::federation::FederationRouter::route_order(&loads)
            }
        };

        // --- admit with spill, reject only when every set is full ---
        let mut landed = None;
        for (attempt, &i) in order.iter().enumerate() {
            if sets[i].try_admit(t, cfg.window_s) {
                landed = Some((attempt, i));
                break;
            }
        }
        match landed {
            Some((attempt, i)) => {
                if attempt > 0 {
                    spilled += 1;
                }
                let (server, end, batched) =
                    sets[i].serve(t, cfg.service_s, cfg.batch_max, cfg.batch_alpha);
                if batched {
                    amortized += 1;
                }
                records.push(Record { admit: t, end, set: i, server });
            }
            None => rejected += 1,
        }
    }

    // Crashes scheduled after the last arrival still strand the queued
    // backlog — the trace ends, the fleet keeps failing.
    while cfg.mtbf_s > 0.0 && next_crash <= cfg.duration_s {
        crashes += 1;
        replays += crash_once(
            &mut sets,
            &mut records,
            &mut rng,
            next_crash,
            cfg.detect_s,
            cfg.service_s,
        );
        next_crash += cfg.mtbf_s;
    }

    let mut latencies: Vec<f64> = records.iter().map(|r| r.end - r.admit).collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let completed = records.iter().filter(|r| r.end <= cfg.duration_s).count();
    FedSimOutcome {
        offered: arrivals.len(),
        admitted: records.len(),
        rejected,
        spilled,
        donations,
        crashes,
        replays,
        amortized,
        completed,
        p50_latency_s: percentile(&latencies, 0.5),
        p99_latency_s: percentile(&latencies, 0.99),
        per_set_admitted: sets.iter().map(|s| s.admitted).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn federation_rejects_less_than_one_set_at_same_offered_load() {
        // Acceptance shape for E11: identical offered load, 1 set vs 3
        // federated sets — the federation's reject rate must be lower.
        let offered = ArrivalProcess::Poisson { rate_rps: 15.0 };
        let single = simulate_federation(
            &FedSimConfig::balanced(1, 10.0, 300.0),
            &offered,
            11,
        );
        let fed = simulate_federation(
            &FedSimConfig::balanced(3, 10.0, 300.0),
            &offered,
            11,
        );
        assert!(
            single.reject_rate() > 0.2,
            "single set must be overloaded: {}",
            single.reject_rate()
        );
        assert!(
            fed.reject_rate() < single.reject_rate(),
            "federation {} vs single {}",
            fed.reject_rate(),
            single.reject_rate()
        );
        assert_eq!(fed.offered, single.offered, "identical offered load");
    }

    #[test]
    fn load_aware_routing_balances_and_spills_less_than_random() {
        let offered = ArrivalProcess::Poisson { rate_rps: 20.0 };
        let mut cfg = FedSimConfig::balanced(3, 10.0, 300.0);
        cfg.skew = 4.0; // clients strongly prefer set 0
        cfg.policy = FedPolicy::RandomSpill;
        let random = simulate_federation(&cfg, &offered, 7);
        cfg.policy = FedPolicy::LoadAware;
        let load_aware = simulate_federation(&cfg, &offered, 7);
        assert!(
            load_aware.spilled < random.spilled,
            "load-aware {} vs random {}",
            load_aware.spilled,
            random.spilled
        );
        assert!(
            load_aware.admitted_spread() < random.admitted_spread(),
            "load-aware spread {} vs random {}",
            load_aware.admitted_spread(),
            random.admitted_spread()
        );
        assert!(load_aware.rejected <= random.rejected);
    }

    #[test]
    fn elastic_donation_follows_skewed_demand() {
        let offered = ArrivalProcess::Poisson { rate_rps: 20.0 };
        let mut cfg = FedSimConfig::balanced(3, 10.0, 300.0);
        cfg.skew = 4.0;
        cfg.policy = FedPolicy::RandomSpill; // affinity-pinned clients
        let frozen = simulate_federation(&cfg, &offered, 13);
        cfg.elastic = true;
        let elastic = simulate_federation(&cfg, &offered, 13);
        assert!(elastic.donations > 0, "capacity must move toward the hot set");
        assert!(
            elastic.spilled < frozen.spilled,
            "donated capacity absorbs the hot set's overflow: {} vs {}",
            elastic.spilled,
            frozen.spilled
        );
    }

    #[test]
    fn crashes_replay_without_losing_requests() {
        // Fault model shape: crashes strand and replay requests — the
        // tail stretches by detection + re-service, but admitted counts
        // are identical and nothing disappears.
        let offered = ArrivalProcess::Poisson { rate_rps: 8.0 };
        let cfg = FedSimConfig::balanced(3, 5.0, 120.0);
        let healthy = simulate_federation(&cfg, &offered, 21);
        let mut faulty_cfg = cfg.clone();
        faulty_cfg.mtbf_s = 5.0;
        faulty_cfg.detect_s = 0.5;
        let faulty = simulate_federation(&faulty_cfg, &offered, 21);
        assert!(faulty.crashes > 0);
        assert!(faulty.replays > 0, "crashes must strand in-flight work");
        assert_eq!(faulty.admitted, healthy.admitted, "no request is lost");
        assert!(
            faulty.p99_latency_s >= healthy.p99_latency_s,
            "recovery delay must show up in the tail: {} vs {}",
            faulty.p99_latency_s,
            healthy.p99_latency_s
        );
        assert_eq!(healthy.crashes + healthy.replays, 0);
    }

    #[test]
    fn batch_amortization_cuts_the_backlog_tail() {
        // Same offered load slightly past capacity: with batching the
        // backlogged portion serves at the amortized cost, so the queue
        // drains faster — identical admissions, shorter tail, no fewer
        // completions.
        let offered = ArrivalProcess::Poisson { rate_rps: 12.0 };
        let plain_cfg = FedSimConfig::balanced(1, 10.0, 300.0);
        let plain = simulate_federation(&plain_cfg, &offered, 17);
        let mut batched_cfg = plain_cfg.clone();
        batched_cfg.batch_max = 8;
        batched_cfg.batch_alpha = 0.7;
        let batched = simulate_federation(&batched_cfg, &offered, 17);
        assert_eq!(plain.amortized, 0, "batch_max=1 never amortizes");
        assert!(batched.amortized > 0, "overload must trigger coalescing");
        assert_eq!(batched.admitted, plain.admitted, "admission is unchanged");
        assert!(
            batched.p99_latency_s < plain.p99_latency_s,
            "amortized service must shorten the backlog tail: {} vs {}",
            batched.p99_latency_s,
            plain.p99_latency_s
        );
        assert!(batched.completed >= plain.completed);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = FedSimConfig::balanced(3, 5.0, 60.0);
        let p = ArrivalProcess::Poisson { rate_rps: 8.0 };
        let a = simulate_federation(&cfg, &p, 3);
        let b = simulate_federation(&cfg, &p, 3);
        assert_eq!(a.per_set_admitted, b.per_set_admitted);
        assert_eq!(a.rejected, b.rejected);
        assert_eq!(a.spilled, b.spilled);
    }

    #[test]
    fn underload_admits_everything_without_spill_pressure() {
        let cfg = FedSimConfig::balanced(3, 10.0, 120.0);
        let out = simulate_federation(&cfg, &ArrivalProcess::Poisson { rate_rps: 3.0 }, 5);
        assert_eq!(out.rejected, 0);
        assert_eq!(out.admitted, out.offered);
        assert!(out.p50_latency_s >= cfg.service_s * 0.999);
    }
}
