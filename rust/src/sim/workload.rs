//! Synthetic arrival processes: Poisson (steady), MMPP (bursty) and
//! diurnal (the load pattern that motivates elastic allocation in §1:
//! "dynamic and often unpredictable nature of request patterns").

use crate::util::Rng;

/// A request arrival process over continuous time (seconds).
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson at `rate_rps`.
    Poisson { rate_rps: f64 },
    /// Markov-modulated Poisson: alternates LOW/HIGH phases with
    /// exponentially distributed dwell times (bursty traffic).
    Mmpp {
        low_rps: f64,
        high_rps: f64,
        mean_dwell_s: f64,
    },
    /// Sinusoidal diurnal pattern between `base_rps` and `peak_rps` with
    /// the given period.
    Diurnal {
        base_rps: f64,
        peak_rps: f64,
        period_s: f64,
    },
    /// Flash-crowd square wave: `peak_rps` for `duty` fraction of each
    /// period, `base_rps` otherwise. `duty = 1/16` gives the peak:mean ≈
    /// 16:1 regime behind the paper's headline comparison: a monolithic
    /// fleet must hold peak capacity through the whole period.
    Spike {
        base_rps: f64,
        peak_rps: f64,
        duty: f64,
        period_s: f64,
    },
}

impl ArrivalProcess {
    /// Generate arrival timestamps in `[0, duration_s)`.
    pub fn generate(&self, seed: u64, duration_s: f64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        match *self {
            ArrivalProcess::Poisson { rate_rps } => {
                let mut t = 0.0;
                loop {
                    t += rng.exp(rate_rps);
                    if t >= duration_s {
                        break;
                    }
                    out.push(t);
                }
            }
            ArrivalProcess::Mmpp { low_rps, high_rps, mean_dwell_s } => {
                let mut t = 0.0;
                let mut high = false;
                let mut phase_end = rng.exp(1.0 / mean_dwell_s);
                loop {
                    let rate = if high { high_rps } else { low_rps };
                    t += rng.exp(rate.max(1e-9));
                    while t > phase_end {
                        high = !high;
                        phase_end += rng.exp(1.0 / mean_dwell_s);
                    }
                    if t >= duration_s {
                        break;
                    }
                    out.push(t);
                }
            }
            ArrivalProcess::Diurnal { base_rps, peak_rps, period_s } => {
                // Thinning: dominate with peak rate, accept with
                // probability rate(t)/peak.
                let mut t = 0.0;
                loop {
                    t += rng.exp(peak_rps.max(1e-9));
                    if t >= duration_s {
                        break;
                    }
                    let phase = (t / period_s) * std::f64::consts::TAU;
                    let rate = base_rps
                        + (peak_rps - base_rps) * 0.5 * (1.0 - phase.cos());
                    if rng.f64() < rate / peak_rps {
                        out.push(t);
                    }
                }
            }
            ArrivalProcess::Spike { base_rps, peak_rps, duty, period_s } => {
                let mut t = 0.0;
                loop {
                    t += rng.exp(peak_rps.max(1e-9));
                    if t >= duration_s {
                        break;
                    }
                    let in_spike = (t % period_s) / period_s < duty;
                    let rate = if in_spike { peak_rps } else { base_rps };
                    if rng.f64() < rate / peak_rps {
                        out.push(t);
                    }
                }
            }
        }
        out
    }

    /// Instantaneous offered rate at time `t` (for plotting/provisioning).
    pub fn rate_at(&self, t: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_rps } => rate_rps,
            ArrivalProcess::Mmpp { low_rps, high_rps, .. } => 0.5 * (low_rps + high_rps),
            ArrivalProcess::Diurnal { base_rps, peak_rps, period_s } => {
                let phase = (t / period_s) * std::f64::consts::TAU;
                base_rps + (peak_rps - base_rps) * 0.5 * (1.0 - phase.cos())
            }
            ArrivalProcess::Spike { base_rps, peak_rps, duty, period_s } => {
                if (t % period_s) / period_s < duty {
                    peak_rps
                } else {
                    base_rps
                }
            }
        }
    }

    /// Peak rate (for monolithic static provisioning).
    pub fn peak_rps(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_rps } => rate_rps,
            ArrivalProcess::Mmpp { high_rps, .. } => high_rps,
            ArrivalProcess::Diurnal { peak_rps, .. } => peak_rps,
            ArrivalProcess::Spike { peak_rps, .. } => peak_rps,
        }
    }
}

/// Zipf-distributed popularity ranks (`P(k) ∝ 1/k^s` over `n` ranks) —
/// the request-content model behind cache experiments: AIGC prompt
/// streams are heavily repeated, and the skew `s` controls how much.
/// `s = 0` degenerates to uniform (no repetition benefit).
#[derive(Debug, Clone)]
pub struct Zipf {
    /// Cumulative probability per rank (ascending, last = 1.0).
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build the distribution over ranks `0..n` with skew `s`.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "Zipf needs at least one rank");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    /// Sample a rank in `0..n`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    /// Number of ranks.
    pub fn n(&self) -> usize {
        self.cdf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_skew_concentrates_on_low_ranks() {
        let z = Zipf::new(100, 1.0);
        let mut rng = Rng::new(42);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Harmonic weights: rank 0 carries 1/H(100) ≈ 19% of the mass.
        assert!(counts[0] > counts[10] * 5, "rank0={} rank10={}", counts[0], counts[10]);
        assert!(counts[0] > 2_500 && counts[0] < 5_500, "rank0={}", counts[0]);
    }

    #[test]
    fn zipf_zero_skew_is_uniform() {
        let z = Zipf::new(10, 0.0);
        let mut rng = Rng::new(7);
        let mut counts = vec![0usize; 10];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 5_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn zipf_samples_stay_in_range() {
        let z = Zipf::new(3, 2.0);
        assert_eq!(z.n(), 3);
        let mut rng = Rng::new(1);
        for _ in 0..1_000 {
            assert!(z.sample(&mut rng) < 3);
        }
    }

    #[test]
    fn poisson_rate_matches() {
        let p = ArrivalProcess::Poisson { rate_rps: 50.0 };
        let arr = p.generate(1, 100.0);
        let rate = arr.len() as f64 / 100.0;
        assert!((rate - 50.0).abs() < 3.0, "rate={rate}");
    }

    #[test]
    fn arrivals_sorted_and_bounded() {
        for proc in [
            ArrivalProcess::Poisson { rate_rps: 20.0 },
            ArrivalProcess::Mmpp { low_rps: 5.0, high_rps: 50.0, mean_dwell_s: 2.0 },
            ArrivalProcess::Diurnal { base_rps: 2.0, peak_rps: 40.0, period_s: 20.0 },
        ] {
            let arr = proc.generate(7, 30.0);
            assert!(arr.windows(2).all(|w| w[0] <= w[1]));
            assert!(arr.iter().all(|&t| (0.0..30.0).contains(&t)));
            assert!(!arr.is_empty());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let p = ArrivalProcess::Poisson { rate_rps: 10.0 };
        assert_eq!(p.generate(3, 10.0), p.generate(3, 10.0));
        assert_ne!(p.generate(3, 10.0), p.generate(4, 10.0));
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        // Index of dispersion (var/mean of per-second counts) > 1 for
        // MMPP, ≈ 1 for Poisson.
        fn dispersion(arr: &[f64], dur: usize) -> f64 {
            let mut counts = vec![0f64; dur];
            for &t in arr {
                counts[t as usize] += 1.0;
            }
            let mean = counts.iter().sum::<f64>() / dur as f64;
            let var =
                counts.iter().map(|c| (c - mean).powi(2)).sum::<f64>() / dur as f64;
            var / mean
        }
        let pois = ArrivalProcess::Poisson { rate_rps: 27.5 }.generate(11, 200.0);
        let mmpp = ArrivalProcess::Mmpp {
            low_rps: 5.0,
            high_rps: 50.0,
            mean_dwell_s: 5.0,
        }
        .generate(11, 200.0);
        assert!(dispersion(&mmpp, 200) > dispersion(&pois, 200) * 2.0);
    }

    #[test]
    fn spike_mean_matches_duty() {
        let p = ArrivalProcess::Spike {
            base_rps: 0.0,
            peak_rps: 32.0,
            duty: 1.0 / 16.0,
            period_s: 40.0,
        };
        let arr = p.generate(5, 400.0);
        let mean = arr.len() as f64 / 400.0;
        assert!((mean - 2.0).abs() < 0.4, "mean={mean} (expect peak*duty=2)");
        assert_eq!(p.peak_rps(), 32.0);
        assert_eq!(p.rate_at(0.1), 32.0);
        assert_eq!(p.rate_at(20.0), 0.0);
    }

    #[test]
    fn diurnal_rate_peaks_mid_period() {
        let d = ArrivalProcess::Diurnal { base_rps: 2.0, peak_rps: 20.0, period_s: 100.0 };
        assert!((d.rate_at(0.0) - 2.0).abs() < 1e-9);
        assert!((d.rate_at(50.0) - 20.0).abs() < 1e-9);
        assert_eq!(d.peak_rps(), 20.0);
    }
}
