//! Simulation substrate for the resource-scale experiments (E1/E8/E11):
//! synthetic arrival processes (no production traces are available — see
//! DESIGN.md substitutions) and a discrete-event GPU-fleet simulator
//! comparing the monolithic deployment with OnePiece's disaggregated,
//! NM-autoscaled deployment.

mod resources;
mod workload;

pub use resources::{
    simulate_disaggregated, simulate_monolithic, wan_stages, FleetOutcome,
    ResourceSimConfig,
};
pub use workload::ArrivalProcess;
