//! Simulation substrate for the resource-scale experiments (E1/E11):
//! synthetic arrival processes (no production traces are available — see
//! DESIGN.md substitutions), a discrete-event GPU-fleet simulator
//! comparing the monolithic deployment with OnePiece's disaggregated,
//! NM-autoscaled deployment, and a federation model sweeping routing
//! policies over N Workflow Sets ([`simulate_federation`]).

mod federation;
mod resources;
mod workload;

pub use federation::{simulate_federation, FedPolicy, FedSimConfig, FedSimOutcome};
pub use resources::{
    simulate_disaggregated, simulate_monolithic, wan_stages, FleetOutcome,
    ResourceSimConfig,
};
pub use workload::{ArrivalProcess, Zipf};

/// Empirical percentile of an ascending-sorted sample (shared by the
/// fleet and federation models and the CLI reporters). `p` in [0, 1];
/// returns 0.0 for an empty sample.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * p).ceil() as usize).clamp(1, sorted.len());
    sorted[idx - 1]
}

#[cfg(test)]
mod tests {
    use super::percentile;

    #[test]
    fn percentile_edges() {
        assert_eq!(percentile(&[], 0.99), 0.0);
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.5), 2.0);
        assert_eq!(percentile(&v, 0.99), 4.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
    }
}
