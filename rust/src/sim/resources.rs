//! Discrete-event GPU-fleet simulator for the headline experiment (E1):
//! the paper's claimed **16× reduction in GPU resource consumption** for
//! Wan2.1 I2V versus running the pipeline inside single (monolithic)
//! instances.
//!
//! The comparison, per the paper's framing (§1):
//!
//! - **Monolithic**: each replica pins `monolithic_gpus` (Wan2.1: 8) for
//!   the whole end-to-end pipeline of one request at a time; the fleet is
//!   statically provisioned for *peak* load (the only safe choice when
//!   scaling means spinning up 8-GPU replicas). Resource consumption =
//!   provisioned GPU-time.
//! - **OnePiece (disaggregated)**: each stage has its own instance pool
//!   sized by Theorem 1 for the *current* load, re-evaluated every
//!   `rescale_period_s` by the NM (§8.2); unassigned instances return to
//!   the shared idle pool where they serve lower-priority work (model
//!   training) and therefore don't count against inference consumption.
//!   Resource consumption = assigned GPU-time.

use super::{percentile, ArrivalProcess};
use crate::pipeline::StageReq;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct ResourceSimConfig {
    pub stages: Vec<StageReq>,
    /// GPUs a monolithic replica pins (Wan2.1: 8).
    pub monolithic_gpus: usize,
    /// NM rescale cadence for the disaggregated fleet.
    pub rescale_period_s: f64,
    /// Sliding window for demand estimation (matches NM's util window).
    pub demand_window_s: f64,
    pub duration_s: f64,
}

/// Outcome of one fleet simulation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetOutcome {
    pub requests: usize,
    pub completed: usize,
    /// GPU-seconds provisioned (the resource-consumption metric).
    pub gpu_s_provisioned: f64,
    /// GPU-seconds actually busy.
    pub gpu_s_busy: f64,
    /// Mean end-to-end latency of completed requests (s).
    pub mean_latency_s: f64,
    /// p99 latency (s).
    pub p99_latency_s: f64,
    /// Completed / duration.
    pub throughput_rps: f64,
    /// busy / provisioned.
    pub utilization: f64,
}

/// Multi-server FIFO queue simulation: `servers` parallel servers, each
/// serving one request for `service_s`. Returns per-request completion
/// times and total busy time.
fn msq(arrivals: &[f64], servers: usize, service_s: f64) -> (Vec<f64>, f64) {
    let mut free_at = vec![0.0f64; servers.max(1)];
    let mut completions = Vec::with_capacity(arrivals.len());
    let mut busy = 0.0;
    for &t in arrivals {
        // Earliest-free server.
        let (idx, &earliest) = free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let start = t.max(earliest);
        let end = start + service_s;
        free_at[idx] = end;
        completions.push(end);
        busy += service_s;
    }
    (completions, busy)
}

/// Monolithic fleet: statically provisioned for peak; each request holds
/// all `monolithic_gpus` for the summed pipeline time.
pub fn simulate_monolithic(
    cfg: &ResourceSimConfig,
    process: &ArrivalProcess,
    seed: u64,
) -> FleetOutcome {
    let arrivals = process.generate(seed, cfg.duration_s);
    let total_service: f64 = cfg.stages.iter().map(|s| s.exec_s).sum();
    // Provision for peak: enough replicas that peak-rate arrivals don't
    // queue unboundedly — Theorem-1 count plus one replica of headroom
    // (an M/D/k run at exactly ρ=1 has unbounded queues).
    let replicas = (process.peak_rps() * total_service).ceil().max(1.0) as usize + 1;
    let (completions, busy_req_s) = msq(&arrivals, replicas, total_service);

    let mut latencies: Vec<f64> = completions
        .iter()
        .zip(&arrivals)
        .map(|(c, a)| c - a)
        .collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let completed = completions.iter().filter(|&&c| c <= cfg.duration_s).count();
    let gpus = (replicas * cfg.monolithic_gpus) as f64;
    FleetOutcome {
        requests: arrivals.len(),
        completed,
        gpu_s_provisioned: gpus * cfg.duration_s,
        gpu_s_busy: busy_req_s * cfg.monolithic_gpus as f64,
        mean_latency_s: latencies.iter().sum::<f64>() / latencies.len().max(1) as f64,
        p99_latency_s: percentile(&latencies, 0.99),
        throughput_rps: completed as f64 / cfg.duration_s,
        utilization: (busy_req_s * cfg.monolithic_gpus as f64)
            / (gpus * cfg.duration_s).max(1e-9),
    }
}

/// Disaggregated fleet: per-stage pools, NM-rescaled to the observed
/// arrival rate every `rescale_period_s` (Theorem 1 sizing + one instance
/// of headroom per stage).
pub fn simulate_disaggregated(
    cfg: &ResourceSimConfig,
    process: &ArrivalProcess,
    seed: u64,
) -> FleetOutcome {
    let arrivals = process.generate(seed, cfg.duration_s);
    let nstages = cfg.stages.len();

    // --- provisioning trace: instances per stage per rescale epoch ---
    let epochs = (cfg.duration_s / cfg.rescale_period_s).ceil() as usize;
    let mut provisioned_gpu_s = 0.0;
    let mut stage_servers_per_epoch: Vec<Vec<usize>> = Vec::with_capacity(epochs);
    let mut ai = 0usize; // arrival index for windowed demand estimation
    let mut recent: std::collections::VecDeque<f64> = Default::default();
    for e in 0..epochs {
        let t = e as f64 * cfg.rescale_period_s;
        while ai < arrivals.len() && arrivals[ai] <= t {
            recent.push_back(arrivals[ai]);
            ai += 1;
        }
        while recent.front().is_some_and(|&x| x < t - cfg.demand_window_s) {
            recent.pop_front();
        }
        let window = cfg.demand_window_s.min(t.max(cfg.rescale_period_s));
        let rate = recent.len() as f64 / window;
        let mut servers = Vec::with_capacity(nstages);
        for s in &cfg.stages {
            // Theorem-1 sizing at the observed rate + 1 headroom instance.
            let parallel = (rate * s.exec_s).ceil() as usize + 1;
            let inst = parallel.div_ceil(s.workers.max(1)).max(1);
            provisioned_gpu_s +=
                (inst * s.gpus_per_instance) as f64 * cfg.rescale_period_s;
            servers.push(inst * s.workers.max(1));
        }
        stage_servers_per_epoch.push(servers);
    }

    // --- request flow: stage-by-stage multi-server queues whose server
    // count follows the provisioning trace (server count at the request's
    // stage-entry epoch) ---
    let mut ready = arrivals.clone();
    let mut busy_gpu_s = 0.0;
    for (si, s) in cfg.stages.iter().enumerate() {
        // Group requests by epoch to use epoch-local server counts while
        // preserving FIFO order (approximation: server pool resets per
        // epoch, warmed with the carried backlog via ready times).
        let max_servers = stage_servers_per_epoch
            .iter()
            .map(|v| v[si])
            .max()
            .unwrap_or(1);
        let mut free_at = vec![0.0f64; max_servers];
        let mut done = Vec::with_capacity(ready.len());
        let mut order: Vec<usize> = (0..ready.len()).collect();
        order.sort_by(|&a, &b| ready[a].partial_cmp(&ready[b]).unwrap());
        let mut done_map = vec![0.0; ready.len()];
        for &r in &order {
            let t = ready[r];
            let epoch = ((t / cfg.rescale_period_s) as usize).min(epochs - 1);
            let active = stage_servers_per_epoch[epoch][si].max(1);
            // Only the first `active` servers are usable this epoch.
            let (idx, &earliest) = free_at[..active]
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let start = t.max(earliest);
            let end = start + s.exec_s;
            free_at[idx] = end;
            done_map[r] = end;
            busy_gpu_s += s.exec_s * s.gpus_per_instance as f64
                / s.workers.max(1) as f64;
        }
        done.extend_from_slice(&done_map);
        ready = done;
    }

    let mut latencies: Vec<f64> = ready.iter().zip(&arrivals).map(|(c, a)| c - a).collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let completed = ready.iter().filter(|&&c| c <= cfg.duration_s).count();
    FleetOutcome {
        requests: arrivals.len(),
        completed,
        gpu_s_provisioned: provisioned_gpu_s,
        gpu_s_busy: busy_gpu_s,
        mean_latency_s: latencies.iter().sum::<f64>() / latencies.len().max(1) as f64,
        p99_latency_s: percentile(&latencies, 0.99),
        throughput_rps: completed as f64 / cfg.duration_s,
        utilization: busy_gpu_s / provisioned_gpu_s.max(1e-9),
    }
}

/// The Wan2.1-like stage profile used across E1 (relative costs from the
/// paper's pipeline: diffusion dominates; encoders are light).
pub fn wan_stages() -> Vec<StageReq> {
    vec![
        StageReq { name: "t5_clip".into(), exec_s: 1.0, gpus_per_instance: 1, workers: 1 },
        StageReq { name: "vae_encode".into(), exec_s: 0.5, gpus_per_instance: 1, workers: 1 },
        StageReq { name: "diffusion".into(), exec_s: 12.0, gpus_per_instance: 4, workers: 1 },
        StageReq { name: "vae_decode".into(), exec_s: 1.5, gpus_per_instance: 1, workers: 1 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ResourceSimConfig {
        ResourceSimConfig {
            stages: wan_stages(),
            monolithic_gpus: 8,
            rescale_period_s: 10.0,
            demand_window_s: 30.0,
            duration_s: 600.0,
        }
    }

    #[test]
    fn monolithic_serves_all_at_low_load() {
        let out = simulate_monolithic(
            &cfg(),
            &ArrivalProcess::Poisson { rate_rps: 0.2 },
            1,
        );
        assert!(out.completed as f64 >= out.requests as f64 * 0.9);
        // 15 s pipeline: mean latency ≈ service time at low load.
        assert!(out.mean_latency_s < 20.0);
    }

    #[test]
    fn disaggregated_uses_fewer_gpu_seconds_under_diurnal_load() {
        let process = ArrivalProcess::Diurnal {
            base_rps: 0.02,
            peak_rps: 1.0,
            period_s: 300.0,
        };
        let mono = simulate_monolithic(&cfg(), &process, 2);
        let dis = simulate_disaggregated(&cfg(), &process, 2);
        let ratio = mono.gpu_s_provisioned / dis.gpu_s_provisioned;
        assert!(
            ratio > 2.0,
            "disaggregation must save resources: ratio={ratio:.2} (mono={} dis={})",
            mono.gpu_s_provisioned,
            dis.gpu_s_provisioned
        );
        // Both serve comparable fractions of the offered load.
        assert!(dis.completed as f64 >= mono.completed as f64 * 0.8);
    }

    #[test]
    fn utilization_higher_when_disaggregated() {
        let process = ArrivalProcess::Diurnal {
            base_rps: 0.02,
            peak_rps: 1.0,
            period_s: 300.0,
        };
        let mono = simulate_monolithic(&cfg(), &process, 3);
        let dis = simulate_disaggregated(&cfg(), &process, 3);
        assert!(
            dis.utilization > mono.utilization,
            "dis={} mono={}",
            dis.utilization,
            mono.utilization
        );
    }

    #[test]
    fn steady_low_load_latency_reasonable() {
        let out = simulate_disaggregated(
            &cfg(),
            &ArrivalProcess::Poisson { rate_rps: 0.1 },
            4,
        );
        // Pipeline is 15 s; queueing should be modest with headroom.
        assert!(out.mean_latency_s < 60.0, "latency={}", out.mean_latency_s);
        assert!(out.completed > 0);
    }
}
