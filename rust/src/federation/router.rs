//! The [`FederationRouter`]: least-loaded-first admission with cross-set
//! spill and elastic instance donation (see the module docs in
//! [`crate::federation`]).

use crate::client::{Gateway, Priority, RequestHandle, SubmitError, SubmitOptions};
use crate::metrics::{Counter, Registry};
use crate::proxy::AdmissionSnapshot;
use crate::transport::{AppId, Payload};
use crate::util::NodeId;
use crate::wset::WorkflowSet;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Federation tuning.
#[derive(Debug, Clone, Copy)]
pub struct FederationConfig {
    /// Spill fast-rejected requests to sibling sets before giving up.
    pub spill: bool,
    /// Maximum age of the cached per-set load snapshot used for routing.
    /// Staleness is deliberate: refreshing on every request would turn
    /// the router into a global synchronization point; the proxy's own
    /// fast-reject stays authoritative and overflow spills instead.
    pub snapshot_max_age: Duration,
    /// A set is donation-eligible as a receiver above this pressure
    /// (max of admission load and peak stage utilization; paper §8.2
    /// uses 0.85 for the intra-set analogue).
    pub hot_pressure: f64,
    /// A set may donate idle capacity only below this pressure.
    pub donor_max_pressure: f64,
    /// Consecutive serve failures (`NoCapacity`: dead entrance, cut
    /// link) that trip a member set's circuit breaker open. `Overloaded`
    /// never counts — a full set is alive.
    pub breaker_threshold: u32,
    /// How long an open breaker blocks routing before it half-opens and
    /// admits a single probe.
    pub breaker_cooldown: Duration,
    /// Consecutive successful half-open probes required to close again
    /// (hysteresis: one lucky probe must not flood a healing set).
    pub breaker_close_after: u32,
}

impl Default for FederationConfig {
    fn default() -> Self {
        Self {
            spill: true,
            snapshot_max_age: Duration::from_millis(25),
            hot_pressure: 0.85,
            donor_max_pressure: 0.5,
            breaker_threshold: 8,
            breaker_cooldown: Duration::from_millis(250),
            breaker_close_after: 3,
        }
    }
}

/// Breaker states (`SetBreaker::state`).
const BREAKER_CLOSED: u8 = 0;
const BREAKER_OPEN: u8 = 1;
const BREAKER_HALF_OPEN: u8 = 2;

/// Per-set circuit breaker: closed → open after
/// [`FederationConfig::breaker_threshold`] consecutive serve failures,
/// open → half-open after [`FederationConfig::breaker_cooldown`] (one
/// probe at a time), half-open → closed after
/// [`FederationConfig::breaker_close_after`] consecutive probe successes
/// — a failed probe snaps back to open with a fresh cooldown. All
/// atomics: the admission walk consults it lock-free.
struct SetBreaker {
    state: AtomicU8,
    consecutive_failures: AtomicU32,
    half_open_successes: AtomicU32,
    /// Milliseconds since router construction at the last open.
    opened_at_ms: AtomicU64,
    /// A half-open probe is in flight (only one admission at a time may
    /// test a healing set).
    probing: AtomicBool,
}

impl SetBreaker {
    fn new() -> Self {
        Self {
            state: AtomicU8::new(BREAKER_CLOSED),
            consecutive_failures: AtomicU32::new(0),
            half_open_successes: AtomicU32::new(0),
            opened_at_ms: AtomicU64::new(0),
            probing: AtomicBool::new(false),
        }
    }

    /// Gate one admission attempt. Open breakers admit nothing until the
    /// cooldown elapses; the transition to half-open claims the probe
    /// slot for this caller.
    fn admits(&self, now_ms: u64, cooldown_ms: u64) -> bool {
        match self.state.load(Ordering::Acquire) {
            BREAKER_CLOSED => true,
            BREAKER_OPEN => {
                now_ms.saturating_sub(self.opened_at_ms.load(Ordering::Relaxed))
                    >= cooldown_ms
                    && self
                        .state
                        .compare_exchange(
                            BREAKER_OPEN,
                            BREAKER_HALF_OPEN,
                            Ordering::AcqRel,
                            Ordering::Relaxed,
                        )
                        .is_ok()
                    && {
                        self.half_open_successes.store(0, Ordering::Relaxed);
                        self.probing.store(true, Ordering::Release);
                        true
                    }
            }
            _ => !self.probing.swap(true, Ordering::AcqRel),
        }
    }

    /// The set served (or proved alive): reset the failure streak and,
    /// in half-open, bank one probe success toward closing.
    fn on_success(&self, close_after: u32) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        if self.state.load(Ordering::Acquire) == BREAKER_HALF_OPEN {
            self.probing.store(false, Ordering::Release);
            let ok = self.half_open_successes.fetch_add(1, Ordering::AcqRel) + 1;
            if ok >= close_after {
                self.state.store(BREAKER_CLOSED, Ordering::Release);
            }
        }
    }

    /// The set failed to serve. Returns `true` when this failure opened
    /// (or re-opened) the breaker, so the caller can count the
    /// transition.
    fn on_failure(&self, now_ms: u64, threshold: u32) -> bool {
        match self.state.load(Ordering::Acquire) {
            BREAKER_OPEN => false,
            BREAKER_HALF_OPEN => {
                // Failed probe: snap back to open with a fresh cooldown.
                self.opened_at_ms.store(now_ms, Ordering::Relaxed);
                self.state.store(BREAKER_OPEN, Ordering::Release);
                self.probing.store(false, Ordering::Release);
                true
            }
            _ => {
                let f = self.consecutive_failures.fetch_add(1, Ordering::AcqRel) + 1;
                if f >= threshold {
                    self.opened_at_ms.store(now_ms, Ordering::Relaxed);
                    self.state.store(BREAKER_OPEN, Ordering::Release);
                    true
                } else {
                    false
                }
            }
        }
    }

    fn state(&self) -> u8 {
        self.state.load(Ordering::Acquire)
    }
}

/// One cross-set donation (the federation analogue of
/// [`crate::nm::RebalanceAction`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DonationAction {
    pub from_set: usize,
    pub to_set: usize,
    /// Node retired from the donor's idle pool.
    pub retired: NodeId,
    /// Fresh node spawned into the receiver's idle pool.
    pub spawned: NodeId,
}

/// Point-in-time view of one member set (reporting / rebalancing input).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SetSnapshot {
    pub set: usize,
    pub admission: AdmissionSnapshot,
    /// Peak per-stage windowed utilization (§8.2 signal).
    pub max_stage_util: f64,
    pub idle_instances: usize,
}

impl SetSnapshot {
    /// Scale-up pressure: admission load or compute saturation, whichever
    /// is higher. A set with no entrance capacity exerts no pressure at
    /// all — it cannot admit requests, so it must never attract donated
    /// instances, even while a residual backlog keeps its stages busy.
    pub fn pressure(&self) -> f64 {
        if self.admission.capacity_rps <= 0.0 {
            return 0.0;
        }
        self.admission.load().max(self.max_stage_util)
    }
}

/// Admission-path counters, resolved once at construction so the hot
/// path never allocates metric names or takes the registry lock (same
/// pattern as the proxy's per-priority counter arrays).
struct AdmissionCounters {
    submitted: Arc<Counter>,
    accepted: Arc<Counter>,
    spilled: Arc<Counter>,
    submitted_prio: [Arc<Counter>; 3],
    accepted_prio: [Arc<Counter>; 3],
    rejected: Arc<Counter>,
    rejected_prio: [Arc<Counter>; 3],
    /// Per member set: `fed.set{i}.accepted` / `fed.set{i}.spill_in` /
    /// `fed.set{i}.breaker_open_total` (closed→open transitions).
    set_accepted: Vec<Arc<Counter>>,
    set_spill_in: Vec<Arc<Counter>>,
    set_breaker_open: Vec<Arc<Counter>>,
}

impl AdmissionCounters {
    fn new(metrics: &Registry, n_sets: usize) -> Self {
        let prio = |kind: &str| {
            Priority::ALL.map(|p| metrics.counter(&format!("fed.{kind}.{}", p.label())))
        };
        Self {
            submitted: metrics.counter("fed.submitted"),
            accepted: metrics.counter("fed.accepted"),
            spilled: metrics.counter("fed.spilled"),
            submitted_prio: prio("submitted"),
            accepted_prio: prio("accepted"),
            rejected: metrics.counter("fed.rejected"),
            rejected_prio: prio("rejected"),
            set_accepted: (0..n_sets)
                .map(|i| metrics.counter(&format!("fed.set{i}.accepted")))
                .collect(),
            set_spill_in: (0..n_sets)
                .map(|i| metrics.counter(&format!("fed.set{i}.spill_in")))
                .collect(),
            set_breaker_open: (0..n_sets)
                .map(|i| metrics.counter(&format!("fed.set{i}.breaker_open_total")))
                .collect(),
        }
    }
}

/// Global router over N Workflow Sets.
pub struct FederationRouter {
    sets: Vec<RwLock<WorkflowSet>>,
    cfg: FederationConfig,
    metrics: Registry,
    counters: AdmissionCounters,
    /// Cached per-app load vector + refresh stamp (see
    /// [`FederationConfig::snapshot_max_age`]).
    loads: Mutex<HashMap<AppId, (Instant, Vec<f64>)>>, // lint: lock-rank(federation_loads, 10)
    /// Serializes [`FederationRouter::rebalance`] passes: concurrent
    /// passes could otherwise pick the same donor and over-donate.
    rebalance_serial: Mutex<()>, // lint: lock-rank(federation_rebalance, 11)
    /// Per-set circuit breakers (parallel to `sets`).
    breakers: Vec<SetBreaker>,
    /// Construction instant — breaker cooldowns are measured in ms from
    /// here so the breaker state fits in atomics.
    t0: Instant,
}

impl FederationRouter {
    pub fn new(sets: Vec<WorkflowSet>, cfg: FederationConfig) -> Self {
        let metrics = Registry::new();
        let counters = AdmissionCounters::new(&metrics, sets.len());
        let breakers = (0..sets.len()).map(|_| SetBreaker::new()).collect();
        Self {
            sets: sets.into_iter().map(RwLock::new).collect(),
            cfg,
            metrics,
            counters,
            loads: Mutex::new(HashMap::new()),
            rebalance_serial: Mutex::new(()),
            breakers,
            t0: Instant::now(),
        }
    }

    /// Milliseconds since router construction (breaker clock).
    fn now_ms(&self) -> u64 {
        self.t0.elapsed().as_millis() as u64
    }

    /// Number of member sets.
    pub fn len(&self) -> usize {
        self.sets.len()
    }

    /// True when the federation has no member sets.
    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    /// The federation metrics registry (spill/reject/donation counters,
    /// per-set gauges, per-priority accept/reject).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Routing order for a load vector: ascending load, ties broken by
    /// set index (stable), capacity-less sets (infinite load) last. This
    /// is also the **spill order**: the first entry is the preferred set,
    /// the rest are tried in sequence on fast-reject.
    pub fn route_order(loads: &[f64]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..loads.len()).collect();
        order.sort_by(|&a, &b| {
            loads[a]
                .partial_cmp(&loads[b])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        order
    }

    /// Per-set admission load for `app`, cached up to `snapshot_max_age`.
    fn loads_for(&self, app: AppId) -> Vec<f64> {
        let mut cache = self.loads.lock().unwrap();
        if let Some((at, loads)) = cache.get(&app) {
            if at.elapsed() <= self.cfg.snapshot_max_age {
                return loads.clone();
            }
        }
        let loads: Vec<f64> = self
            .sets
            .iter()
            .map(|s| s.read().unwrap().admission_snapshot(app).load())
            .collect();
        cache.insert(app, (Instant::now(), loads.clone()));
        loads
    }

    /// Fresh (uncached) snapshots of every member set; also updates the
    /// per-set load/utilization gauges.
    pub fn snapshots(&self, app: AppId) -> Vec<SetSnapshot> {
        let snaps: Vec<SetSnapshot> = self
            .sets
            .iter()
            .enumerate()
            .map(|(i, lock)| {
                let set = lock.read().unwrap();
                SetSnapshot {
                    set: i,
                    admission: set.admission_snapshot(app),
                    max_stage_util: set.max_stage_utilization(app),
                    idle_instances: set.idle_count(),
                }
            })
            .collect();
        for s in &snaps {
            let load = s.admission.load();
            let permille = if load.is_finite() { (load * 1000.0) as i64 } else { -1 };
            self.metrics
                .gauge(&format!("fed.set{}.load_permille", s.set))
                .set(permille);
            self.metrics
                .gauge(&format!("fed.set{}.util_permille", s.set))
                .set((s.max_stage_util * 1000.0) as i64);
        }
        snaps
    }

    /// One elasticity pass (the federation analogue of the NM's §8.2
    /// timer). Escalation order mirrors the paper's intra-set policy:
    /// a hot set (pressure ≥ `hot_pressure`) first absorbs its **own**
    /// idle pool via its NM; only when that pool is empty does the
    /// federation move an instance from the idle pool of a sibling below
    /// `donor_max_pressure`. Returns the donation taken, if any (an
    /// intra-set assignment returns `None` — nothing crossed a set
    /// boundary).
    pub fn rebalance(&self, app: AppId) -> Option<DonationAction> {
        let _serial = self.rebalance_serial.lock().unwrap();
        let snaps = self.snapshots(app);
        let hot_snap = snaps
            .iter()
            .filter(|s| s.pressure() >= self.cfg.hot_pressure)
            .max_by(|a, b| {
                a.pressure()
                    .partial_cmp(&b.pressure())
                    .unwrap_or(std::cmp::Ordering::Equal)
            })?;
        let hot = hot_snap.set;
        // Intra-set first: the hot set's own idle instances are closer
        // than any donation.
        if hot_snap.idle_instances > 0
            && self.sets[hot].read().unwrap().rebalance().is_some()
        {
            return None;
        }
        let donor = snaps
            .iter()
            .filter(|s| {
                s.set != hot
                    && s.idle_instances > 0
                    && s.pressure() <= self.cfg.donor_max_pressure
            })
            .min_by(|a, b| {
                a.pressure()
                    .partial_cmp(&b.pressure())
                    .unwrap_or(std::cmp::Ordering::Equal)
            })?
            .set;
        let retired = self.sets[donor].write().unwrap().retire_idle_instance()?;
        let spawned = self.sets[hot].write().unwrap().add_idle_instance();
        // Let the receiving set's NM place the new capacity immediately
        // (its housekeeping timer would otherwise pick it up next sweep).
        let _ = self.sets[hot].read().unwrap().rebalance();
        self.metrics.counter("fed.donations").inc();
        self.metrics.counter(&format!("fed.set{donor}.donated_out")).inc();
        self.metrics.counter(&format!("fed.set{hot}.donated_in")).inc();
        Some(DonationAction { from_set: donor, to_set: hot, retired, spawned })
    }

    /// Current breaker state per member set (`"closed"` / `"open"` /
    /// `"half-open"`), for reporting and the `federate` summary line.
    pub fn breaker_states(&self) -> Vec<&'static str> {
        self.breakers
            .iter()
            .map(|b| match b.state() {
                BREAKER_OPEN => "open",
                BREAKER_HALF_OPEN => "half-open",
                _ => "closed",
            })
            .collect()
    }

    /// Recompute the brownout level from breaker health and push it to
    /// every member proxy: fewer than 3/4 of the breakers closed sheds
    /// Batch, fewer than 1/2 sheds Standard too — Interactive goodput
    /// survives a partitioned federation. Returns the level applied
    /// (also exported as the `fed.brownout_level` gauge). Call this on
    /// the same cadence as [`FederationRouter::rebalance`].
    pub fn refresh_brownout(&self) -> u8 {
        let n = self.breakers.len();
        if n == 0 {
            return crate::proxy::BROWNOUT_OFF;
        }
        let closed = self
            .breakers
            .iter()
            .filter(|b| b.state() == BREAKER_CLOSED)
            .count();
        let frac = closed as f64 / n as f64;
        let level = if frac < 0.5 {
            crate::proxy::BROWNOUT_SHED_STANDARD
        } else if frac < 0.75 {
            crate::proxy::BROWNOUT_SHED_BATCH
        } else {
            crate::proxy::BROWNOUT_OFF
        };
        for lock in &self.sets {
            lock.read().unwrap().proxy.set_brownout(level);
        }
        self.metrics.gauge("fed.brownout_level").set(level as i64);
        level
    }

    /// Run `f` against a member set (read access).
    pub fn with_set<R>(&self, set: usize, f: impl FnOnce(&WorkflowSet) -> R) -> R {
        f(&self.sets[set].read().unwrap())
    }

    /// Shut down every member set.
    pub fn shutdown(self) {
        for lock in self.sets {
            lock.into_inner().unwrap().shutdown();
        }
    }
}

impl Gateway for FederationRouter {
    /// Submit a request: least-loaded admitting set first, then spill in
    /// ascending-load order, rejecting only when every set is full. The
    /// payload moves through the spill chain **without cloning** — a
    /// rejecting proxy hands it back. The options' retry policy re-walks
    /// the whole spill order with backoff between rounds.
    fn submit_with(
        &self,
        app: AppId,
        payload: Payload,
        opts: SubmitOptions,
    ) -> Result<RequestHandle, SubmitError> {
        let c = &self.counters;
        c.submitted.inc();
        c.submitted_prio[opts.priority.index()].inc();
        let result = crate::client::retry_rounds(&opts, payload, |mut payload| {
            let loads = self.loads_for(app);
            let order = Self::route_order(&loads);
            // Breaker gate: skip open sets. If *every* breaker refuses
            // (federation-wide outage or all probes claimed), walk the
            // full order anyway — the breaker degrades routing, it never
            // blackholes a request the sets could still serve.
            let now_ms = self.now_ms();
            let cooldown_ms = self.cfg.breaker_cooldown.as_millis() as u64;
            let admitted: Vec<usize> = order
                .iter()
                .copied()
                .filter(|&i| self.breakers[i].admits(now_ms, cooldown_ms))
                .collect();
            let candidates = if admitted.is_empty() { order } else { admitted };
            let mut best: Option<Duration> = None;
            for (attempt, &idx) in candidates.iter().enumerate() {
                let set = self.sets[idx].read().unwrap();
                match set.submit_once(app, payload, &opts) {
                    Ok(uid) => {
                        self.breakers[idx].on_success(self.cfg.breaker_close_after);
                        c.accepted.inc();
                        c.accepted_prio[opts.priority.index()].inc();
                        c.set_accepted[idx].inc();
                        if attempt > 0 {
                            c.spilled.inc();
                            c.set_spill_in[idx].inc();
                        }
                        if let Some(t) = set.trace_hook() {
                            t.record(
                                uid,
                                None,
                                crate::trace::EventKind::Routed { to_set: idx as u16 },
                            );
                        }
                        return Ok(set.handle_for(uid, idx, &opts));
                    }
                    Err((e, p)) => {
                        // `NoCapacity` is a serve failure (dead entrance,
                        // cut link, dropped forward) and feeds the
                        // breaker; `Overloaded` proves the set alive.
                        match e {
                            SubmitError::NoCapacity => {
                                if self.breakers[idx].on_failure(now_ms, self.cfg.breaker_threshold)
                                {
                                    c.set_breaker_open[idx].inc();
                                }
                            }
                            _ => self.breakers[idx].on_success(self.cfg.breaker_close_after),
                        }
                        payload = p;
                        best = e.fold_hint(best);
                    }
                }
                if !self.cfg.spill {
                    break;
                }
            }
            Err((SubmitError::from_hint(best), payload))
        });
        if result.is_err() {
            c.rejected.inc();
            c.rejected_prio[opts.priority.index()].inc();
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::WaitOutcome;
    use crate::config::{ClusterConfig, ExecModel, FabricKind};
    use crate::workflow::EchoLogic;
    use crate::wset::WorkflowSet;
    use std::sync::Arc;

    /// A config whose entrance admission budget is exactly 2 requests
    /// per monitor window (capacity 1/32 rps × 64 s window), with
    /// instant simulated executors so shutdown never blocks.
    fn tiny_budget_config() -> ClusterConfig {
        let mut cfg = ClusterConfig::i2v_default();
        cfg.fabric = FabricKind::Ideal;
        for s in cfg.apps[0].stages.iter_mut() {
            s.exec = ExecModel::Simulated { ms: 0.0 };
            s.exec_ms = 1.0;
        }
        // Entrance: capacity = 1 worker / 32 s; budget = 1/32 × 64 = 2.
        cfg.apps[0].stages[0].exec_ms = 32_000.0;
        cfg.proxy.monitor_window_ms = 64_000;
        cfg.proxy.headroom = 1.0;
        cfg.idle_pool = 0;
        cfg
    }

    fn build_set(cfg: &ClusterConfig, counts: Vec<usize>) -> WorkflowSet {
        WorkflowSet::build_standalone(
            cfg.clone(),
            vec![counts],
            Arc::new(EchoLogic),
            None,
        )
    }

    /// Frozen-snapshot router: routing loads are computed once, so the
    /// spill order is deterministic for the whole test.
    fn frozen(sets: Vec<WorkflowSet>) -> FederationRouter {
        FederationRouter::new(
            sets,
            FederationConfig {
                snapshot_max_age: Duration::from_secs(3600),
                ..Default::default()
            },
        )
    }

    #[test]
    fn route_order_is_ascending_load_with_dead_sets_last() {
        let loads = [0.5, f64::INFINITY, 0.1, 0.3];
        assert_eq!(FederationRouter::route_order(&loads), vec![2, 3, 0, 1]);
        // Ties keep set-index order (stable sort).
        let tied = [0.2, 0.1, 0.2, 0.1];
        assert_eq!(FederationRouter::route_order(&tied), vec![1, 3, 0, 2]);
    }

    #[test]
    fn spills_before_rejecting_and_rejects_only_when_all_full() {
        let cfg = tiny_budget_config();
        let app = AppId(1);
        let sets = vec![
            build_set(&cfg, vec![1, 1, 1, 1]),
            build_set(&cfg, vec![1, 1, 1, 1]),
        ];
        let fed = frozen(sets);

        let payload = Payload::Bytes(vec![1]);
        // Budget 2 per set, frozen order [0, 1]: two land on set 0, the
        // next two spill to set 1, the fifth is rejected by everyone with
        // a structured retry hint.
        let mut results = Vec::new();
        for _ in 0..5 {
            results.push(fed.submit(app, payload.clone()));
        }
        for (i, expect_set) in [(0usize, 0usize), (1, 0), (2, 1), (3, 1)] {
            match &results[i] {
                Ok(handle) => assert_eq!(handle.set(), expect_set, "req {i}"),
                Err(e) => panic!("req {i}: expected acceptance, got {e:?}"),
            }
        }
        match &results[4] {
            Err(SubmitError::Overloaded { retry_after }) => {
                assert!(*retry_after > Duration::ZERO, "hint must be positive");
                assert!(*retry_after <= Duration::from_secs(64), "hint bounded by window");
            }
            other => panic!("all sets full must report Overloaded, got {other:?}"),
        }

        let counters: std::collections::HashMap<String, u64> =
            fed.metrics().counters_snapshot().into_iter().collect();
        assert_eq!(counters["fed.accepted"], 4);
        assert_eq!(counters["fed.spilled"], 2);
        assert_eq!(counters["fed.rejected"], 1);
        assert_eq!(counters["fed.set0.accepted"], 2);
        assert_eq!(counters["fed.set1.accepted"], 2);
        assert_eq!(counters["fed.set1.spill_in"], 2);
        assert_eq!(counters["fed.accepted.standard"], 4);
        assert_eq!(counters["fed.rejected.standard"], 1);
        fed.shutdown();
    }

    #[test]
    fn no_spill_mode_rejects_at_first_full_set() {
        let cfg = tiny_budget_config();
        let app = AppId(1);
        let sets = vec![
            build_set(&cfg, vec![1, 1, 1, 1]),
            build_set(&cfg, vec![1, 1, 1, 1]),
        ];
        let fed = FederationRouter::new(
            sets,
            FederationConfig {
                spill: false,
                snapshot_max_age: Duration::from_secs(3600),
                ..Default::default()
            },
        );
        let payload = Payload::Bytes(vec![2]);
        let mut accepted = 0;
        let mut rejected = 0;
        for _ in 0..4 {
            match fed.submit(app, payload.clone()) {
                Ok(_) => accepted += 1,
                Err(_) => rejected += 1,
            }
        }
        // Frozen order pins everything on set 0 (budget 2); without spill
        // the sibling's spare capacity is unreachable.
        assert_eq!((accepted, rejected), (2, 2));
        fed.shutdown();
    }

    #[test]
    fn dead_set_is_routed_around_without_counting_as_spill() {
        let cfg = tiny_budget_config();
        let app = AppId(1);
        // Set 0 has no entrance instances (regional failure): load = ∞.
        let sets = vec![
            build_set(&cfg, vec![0, 1, 1, 1]),
            build_set(&cfg, vec![1, 1, 1, 1]),
        ];
        let fed = frozen(sets);
        let handle = fed
            .submit(app, Payload::Bytes(vec![3]))
            .expect("healthy set must accept");
        assert_eq!(handle.set(), 1, "healthy set preferred");
        let counters: std::collections::HashMap<String, u64> =
            fed.metrics().counters_snapshot().into_iter().collect();
        assert_eq!(
            counters.get("fed.spilled").copied().unwrap_or(0),
            0,
            "routing around a dead set is not a spill"
        );
        fed.shutdown();
    }

    #[test]
    fn end_to_end_result_through_federation_handle() {
        let cfg = tiny_budget_config();
        let app = AppId(1);
        let fed = frozen(vec![build_set(&cfg, vec![1, 1, 1, 1])]);
        std::thread::sleep(Duration::from_millis(80));
        let handle = fed.submit(app, Payload::Bytes(vec![9])).expect("admit");
        let WaitOutcome::Done(bytes) = handle.wait(Duration::from_secs(10)) else {
            panic!("federated request must complete")
        };
        let msg = crate::transport::WorkflowMessage::decode(&bytes).unwrap();
        assert_eq!(msg.payload, Payload::Bytes(vec![9]));
        fed.shutdown();
    }

    #[test]
    fn breaker_state_machine_opens_half_opens_and_closes_with_hysteresis() {
        let b = SetBreaker::new();
        // Closed admits; failures below the threshold keep it closed.
        assert!(b.admits(0, 100));
        assert!(!b.on_failure(0, 3));
        assert!(!b.on_failure(0, 3));
        assert_eq!(b.state(), BREAKER_CLOSED);
        // Third consecutive failure opens it (the transition is reported
        // exactly once).
        assert!(b.on_failure(0, 3));
        assert_eq!(b.state(), BREAKER_OPEN);
        assert!(!b.on_failure(0, 3), "already open: no second transition");
        // Open blocks until the cooldown elapses...
        assert!(!b.admits(50, 100));
        // ...then half-opens and admits exactly one probe.
        assert!(b.admits(100, 100));
        assert_eq!(b.state(), BREAKER_HALF_OPEN);
        assert!(!b.admits(100, 100), "second concurrent probe refused");
        // A failed probe snaps back to open with a fresh cooldown.
        assert!(b.on_failure(100, 3));
        assert_eq!(b.state(), BREAKER_OPEN);
        assert!(!b.admits(150, 100), "cooldown restarted at re-open");
        // Heal: probe succeeds close_after times before closing.
        assert!(b.admits(200, 100));
        b.on_success(2);
        assert_eq!(b.state(), BREAKER_HALF_OPEN, "one success is not enough");
        assert!(b.admits(200, 100), "probe slot released by the success");
        b.on_success(2);
        assert_eq!(b.state(), BREAKER_CLOSED);
        // A success streak keeps the failure counter at zero.
        b.on_success(2);
        assert!(!b.on_failure(300, 3));
    }

    #[test]
    fn dead_federation_opens_breakers_and_brownout_sheds() {
        let cfg = tiny_budget_config();
        let app = AppId(1);
        // Both sets have no entrance instances: every submit is a serve
        // failure on every set.
        let sets = vec![
            build_set(&cfg, vec![0, 1, 1, 1]),
            build_set(&cfg, vec![0, 1, 1, 1]),
        ];
        let fed = FederationRouter::new(
            sets,
            FederationConfig {
                snapshot_max_age: Duration::from_secs(3600),
                breaker_threshold: 3,
                breaker_cooldown: Duration::from_secs(3600),
                ..Default::default()
            },
        );
        assert_eq!(fed.breaker_states(), vec!["closed", "closed"]);
        assert_eq!(fed.refresh_brownout(), crate::proxy::BROWNOUT_OFF);
        for _ in 0..3 {
            assert!(fed.submit(app, Payload::Bytes(vec![1])).is_err());
        }
        assert_eq!(fed.breaker_states(), vec!["open", "open"]);
        // Once open, further submissions still resolve to a typed error
        // through the all-open fallback walk — never a hang.
        assert!(matches!(
            fed.submit(app, Payload::Bytes(vec![2])),
            Err(SubmitError::NoCapacity)
        ));
        let counters: std::collections::HashMap<String, u64> =
            fed.metrics().counters_snapshot().into_iter().collect();
        assert!(counters["fed.set0.breaker_open_total"] >= 1);
        assert!(counters["fed.set1.breaker_open_total"] >= 1);
        // No breaker closed => full brownout, pushed to every proxy.
        assert_eq!(fed.refresh_brownout(), crate::proxy::BROWNOUT_SHED_STANDARD);
        for i in 0..2 {
            assert_eq!(
                fed.with_set(i, |s| s.proxy.brownout()),
                crate::proxy::BROWNOUT_SHED_STANDARD
            );
        }
        fed.shutdown();
    }

    #[test]
    fn donation_moves_idle_capacity_to_hot_set() {
        let mut cfg = tiny_budget_config();
        cfg.nm.util_window_ms = 2_000;
        let app = AppId(1);
        let mut hot_cfg = cfg.clone();
        hot_cfg.idle_pool = 0;
        let mut cold_cfg = cfg.clone();
        cold_cfg.idle_pool = 2;
        let sets = vec![
            build_set(&hot_cfg, vec![1, 1, 1, 1]),
            build_set(&cold_cfg, vec![1, 1, 1, 1]),
        ];
        let fed = frozen(sets);
        assert_eq!(fed.with_set(1, |s| s.idle_count()), 2);

        // Saturate set 0's diffusion stage. Instances self-report ~0
        // continuously, so re-assert until a rebalance pass observes the
        // hot reading (same idiom as the wset housekeeper test).
        let diffusion = crate::nm::StageKey { app, stage: 2 };
        let node = fed.with_set(0, |s| s.nm.stage_instances(diffusion)[0]);
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut action = None;
        while action.is_none() && Instant::now() < deadline {
            fed.with_set(0, |s| {
                use crate::workflow::ControlPlane;
                s.nm.report_utilization(node, 0.99);
            });
            action = fed.rebalance(app);
            std::thread::sleep(Duration::from_millis(5));
        }
        let action = action.expect("hot set must receive a donation");
        assert_eq!(action.from_set, 1);
        assert_eq!(action.to_set, 0);
        assert_eq!(fed.with_set(1, |s| s.idle_count()), 1, "donor shrank");
        let counters: std::collections::HashMap<String, u64> =
            fed.metrics().counters_snapshot().into_iter().collect();
        assert_eq!(counters["fed.donations"], 1);
        assert_eq!(counters["fed.set1.donated_out"], 1);
        assert_eq!(counters["fed.set0.donated_in"], 1);
        fed.shutdown();
    }
}
