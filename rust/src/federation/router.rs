//! The [`FederationRouter`]: least-loaded-first admission with cross-set
//! spill and elastic instance donation (see the module docs in
//! [`crate::federation`]).

use crate::client::{Gateway, Priority, RequestHandle, SubmitError, SubmitOptions};
use crate::metrics::{Counter, Registry};
use crate::proxy::AdmissionSnapshot;
use crate::transport::{AppId, Payload};
use crate::util::NodeId;
use crate::wset::WorkflowSet;
use std::collections::HashMap;
use std::sync::{Mutex, RwLock};
use std::time::{Duration, Instant};

/// Federation tuning.
#[derive(Debug, Clone, Copy)]
pub struct FederationConfig {
    /// Spill fast-rejected requests to sibling sets before giving up.
    pub spill: bool,
    /// Maximum age of the cached per-set load snapshot used for routing.
    /// Staleness is deliberate: refreshing on every request would turn
    /// the router into a global synchronization point; the proxy's own
    /// fast-reject stays authoritative and overflow spills instead.
    pub snapshot_max_age: Duration,
    /// A set is donation-eligible as a receiver above this pressure
    /// (max of admission load and peak stage utilization; paper §8.2
    /// uses 0.85 for the intra-set analogue).
    pub hot_pressure: f64,
    /// A set may donate idle capacity only below this pressure.
    pub donor_max_pressure: f64,
}

impl Default for FederationConfig {
    fn default() -> Self {
        Self {
            spill: true,
            snapshot_max_age: Duration::from_millis(25),
            hot_pressure: 0.85,
            donor_max_pressure: 0.5,
        }
    }
}

/// One cross-set donation (the federation analogue of
/// [`crate::nm::RebalanceAction`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DonationAction {
    pub from_set: usize,
    pub to_set: usize,
    /// Node retired from the donor's idle pool.
    pub retired: NodeId,
    /// Fresh node spawned into the receiver's idle pool.
    pub spawned: NodeId,
}

/// Point-in-time view of one member set (reporting / rebalancing input).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SetSnapshot {
    pub set: usize,
    pub admission: AdmissionSnapshot,
    /// Peak per-stage windowed utilization (§8.2 signal).
    pub max_stage_util: f64,
    pub idle_instances: usize,
}

impl SetSnapshot {
    /// Scale-up pressure: admission load or compute saturation, whichever
    /// is higher. A set with no entrance capacity exerts no pressure at
    /// all — it cannot admit requests, so it must never attract donated
    /// instances, even while a residual backlog keeps its stages busy.
    pub fn pressure(&self) -> f64 {
        if self.admission.capacity_rps <= 0.0 {
            return 0.0;
        }
        self.admission.load().max(self.max_stage_util)
    }
}

/// Admission-path counters, resolved once at construction so the hot
/// path never allocates metric names or takes the registry lock (same
/// pattern as the proxy's per-priority counter arrays).
struct AdmissionCounters {
    submitted: Arc<Counter>,
    accepted: Arc<Counter>,
    spilled: Arc<Counter>,
    submitted_prio: [Arc<Counter>; 3],
    accepted_prio: [Arc<Counter>; 3],
    rejected: Arc<Counter>,
    rejected_prio: [Arc<Counter>; 3],
    /// Per member set: `fed.set{i}.accepted` / `fed.set{i}.spill_in`.
    set_accepted: Vec<Arc<Counter>>,
    set_spill_in: Vec<Arc<Counter>>,
}

impl AdmissionCounters {
    fn new(metrics: &Registry, n_sets: usize) -> Self {
        let prio = |kind: &str| {
            Priority::ALL.map(|p| metrics.counter(&format!("fed.{kind}.{}", p.label())))
        };
        Self {
            submitted: metrics.counter("fed.submitted"),
            accepted: metrics.counter("fed.accepted"),
            spilled: metrics.counter("fed.spilled"),
            submitted_prio: prio("submitted"),
            accepted_prio: prio("accepted"),
            rejected: metrics.counter("fed.rejected"),
            rejected_prio: prio("rejected"),
            set_accepted: (0..n_sets)
                .map(|i| metrics.counter(&format!("fed.set{i}.accepted")))
                .collect(),
            set_spill_in: (0..n_sets)
                .map(|i| metrics.counter(&format!("fed.set{i}.spill_in")))
                .collect(),
        }
    }
}

/// Global router over N Workflow Sets.
pub struct FederationRouter {
    sets: Vec<RwLock<WorkflowSet>>,
    cfg: FederationConfig,
    metrics: Registry,
    counters: AdmissionCounters,
    /// Cached per-app load vector + refresh stamp (see
    /// [`FederationConfig::snapshot_max_age`]).
    loads: Mutex<HashMap<AppId, (Instant, Vec<f64>)>>, // lint: lock-rank(federation_loads, 10)
    /// Serializes [`FederationRouter::rebalance`] passes: concurrent
    /// passes could otherwise pick the same donor and over-donate.
    rebalance_serial: Mutex<()>, // lint: lock-rank(federation_rebalance, 11)
}

impl FederationRouter {
    pub fn new(sets: Vec<WorkflowSet>, cfg: FederationConfig) -> Self {
        let metrics = Registry::new();
        let counters = AdmissionCounters::new(&metrics, sets.len());
        Self {
            sets: sets.into_iter().map(RwLock::new).collect(),
            cfg,
            metrics,
            counters,
            loads: Mutex::new(HashMap::new()),
            rebalance_serial: Mutex::new(()),
        }
    }

    /// Number of member sets.
    pub fn len(&self) -> usize {
        self.sets.len()
    }

    /// True when the federation has no member sets.
    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    /// The federation metrics registry (spill/reject/donation counters,
    /// per-set gauges, per-priority accept/reject).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Routing order for a load vector: ascending load, ties broken by
    /// set index (stable), capacity-less sets (infinite load) last. This
    /// is also the **spill order**: the first entry is the preferred set,
    /// the rest are tried in sequence on fast-reject.
    pub fn route_order(loads: &[f64]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..loads.len()).collect();
        order.sort_by(|&a, &b| {
            loads[a]
                .partial_cmp(&loads[b])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        order
    }

    /// Per-set admission load for `app`, cached up to `snapshot_max_age`.
    fn loads_for(&self, app: AppId) -> Vec<f64> {
        let mut cache = self.loads.lock().unwrap();
        if let Some((at, loads)) = cache.get(&app) {
            if at.elapsed() <= self.cfg.snapshot_max_age {
                return loads.clone();
            }
        }
        let loads: Vec<f64> = self
            .sets
            .iter()
            .map(|s| s.read().unwrap().admission_snapshot(app).load())
            .collect();
        cache.insert(app, (Instant::now(), loads.clone()));
        loads
    }

    /// Fresh (uncached) snapshots of every member set; also updates the
    /// per-set load/utilization gauges.
    pub fn snapshots(&self, app: AppId) -> Vec<SetSnapshot> {
        let snaps: Vec<SetSnapshot> = self
            .sets
            .iter()
            .enumerate()
            .map(|(i, lock)| {
                let set = lock.read().unwrap();
                SetSnapshot {
                    set: i,
                    admission: set.admission_snapshot(app),
                    max_stage_util: set.max_stage_utilization(app),
                    idle_instances: set.idle_count(),
                }
            })
            .collect();
        for s in &snaps {
            let load = s.admission.load();
            let permille = if load.is_finite() { (load * 1000.0) as i64 } else { -1 };
            self.metrics
                .gauge(&format!("fed.set{}.load_permille", s.set))
                .set(permille);
            self.metrics
                .gauge(&format!("fed.set{}.util_permille", s.set))
                .set((s.max_stage_util * 1000.0) as i64);
        }
        snaps
    }

    /// One elasticity pass (the federation analogue of the NM's §8.2
    /// timer). Escalation order mirrors the paper's intra-set policy:
    /// a hot set (pressure ≥ `hot_pressure`) first absorbs its **own**
    /// idle pool via its NM; only when that pool is empty does the
    /// federation move an instance from the idle pool of a sibling below
    /// `donor_max_pressure`. Returns the donation taken, if any (an
    /// intra-set assignment returns `None` — nothing crossed a set
    /// boundary).
    pub fn rebalance(&self, app: AppId) -> Option<DonationAction> {
        let _serial = self.rebalance_serial.lock().unwrap();
        let snaps = self.snapshots(app);
        let hot_snap = snaps
            .iter()
            .filter(|s| s.pressure() >= self.cfg.hot_pressure)
            .max_by(|a, b| {
                a.pressure()
                    .partial_cmp(&b.pressure())
                    .unwrap_or(std::cmp::Ordering::Equal)
            })?;
        let hot = hot_snap.set;
        // Intra-set first: the hot set's own idle instances are closer
        // than any donation.
        if hot_snap.idle_instances > 0
            && self.sets[hot].read().unwrap().rebalance().is_some()
        {
            return None;
        }
        let donor = snaps
            .iter()
            .filter(|s| {
                s.set != hot
                    && s.idle_instances > 0
                    && s.pressure() <= self.cfg.donor_max_pressure
            })
            .min_by(|a, b| {
                a.pressure()
                    .partial_cmp(&b.pressure())
                    .unwrap_or(std::cmp::Ordering::Equal)
            })?
            .set;
        let retired = self.sets[donor].write().unwrap().retire_idle_instance()?;
        let spawned = self.sets[hot].write().unwrap().add_idle_instance();
        // Let the receiving set's NM place the new capacity immediately
        // (its housekeeping timer would otherwise pick it up next sweep).
        let _ = self.sets[hot].read().unwrap().rebalance();
        self.metrics.counter("fed.donations").inc();
        self.metrics.counter(&format!("fed.set{donor}.donated_out")).inc();
        self.metrics.counter(&format!("fed.set{hot}.donated_in")).inc();
        Some(DonationAction { from_set: donor, to_set: hot, retired, spawned })
    }

    /// Run `f` against a member set (read access).
    pub fn with_set<R>(&self, set: usize, f: impl FnOnce(&WorkflowSet) -> R) -> R {
        f(&self.sets[set].read().unwrap())
    }

    /// Shut down every member set.
    pub fn shutdown(self) {
        for lock in self.sets {
            lock.into_inner().unwrap().shutdown();
        }
    }
}

impl Gateway for FederationRouter {
    /// Submit a request: least-loaded admitting set first, then spill in
    /// ascending-load order, rejecting only when every set is full. The
    /// payload moves through the spill chain **without cloning** — a
    /// rejecting proxy hands it back. The options' retry policy re-walks
    /// the whole spill order with backoff between rounds.
    fn submit_with(
        &self,
        app: AppId,
        payload: Payload,
        opts: SubmitOptions,
    ) -> Result<RequestHandle, SubmitError> {
        let c = &self.counters;
        c.submitted.inc();
        c.submitted_prio[opts.priority.index()].inc();
        let result = crate::client::retry_rounds(&opts, payload, |mut payload| {
            let loads = self.loads_for(app);
            let order = Self::route_order(&loads);
            let mut best: Option<Duration> = None;
            for (attempt, &idx) in order.iter().enumerate() {
                let set = self.sets[idx].read().unwrap();
                match set.submit_once(app, payload, &opts) {
                    Ok(uid) => {
                        c.accepted.inc();
                        c.accepted_prio[opts.priority.index()].inc();
                        c.set_accepted[idx].inc();
                        if attempt > 0 {
                            c.spilled.inc();
                            c.set_spill_in[idx].inc();
                        }
                        if let Some(t) = set.trace_hook() {
                            t.record(
                                uid,
                                None,
                                crate::trace::EventKind::Routed { to_set: idx as u16 },
                            );
                        }
                        return Ok(set.handle_for(uid, idx, &opts));
                    }
                    Err((e, p)) => {
                        payload = p;
                        best = e.fold_hint(best);
                    }
                }
                if !self.cfg.spill {
                    break;
                }
            }
            Err((SubmitError::from_hint(best), payload))
        });
        if result.is_err() {
            c.rejected.inc();
            c.rejected_prio[opts.priority.index()].inc();
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::WaitOutcome;
    use crate::config::{ClusterConfig, ExecModel, FabricKind};
    use crate::workflow::EchoLogic;
    use crate::wset::WorkflowSet;
    use std::sync::Arc;

    /// A config whose entrance admission budget is exactly 2 requests
    /// per monitor window (capacity 1/32 rps × 64 s window), with
    /// instant simulated executors so shutdown never blocks.
    fn tiny_budget_config() -> ClusterConfig {
        let mut cfg = ClusterConfig::i2v_default();
        cfg.fabric = FabricKind::Ideal;
        for s in cfg.apps[0].stages.iter_mut() {
            s.exec = ExecModel::Simulated { ms: 0.0 };
            s.exec_ms = 1.0;
        }
        // Entrance: capacity = 1 worker / 32 s; budget = 1/32 × 64 = 2.
        cfg.apps[0].stages[0].exec_ms = 32_000.0;
        cfg.proxy.monitor_window_ms = 64_000;
        cfg.proxy.headroom = 1.0;
        cfg.idle_pool = 0;
        cfg
    }

    fn build_set(cfg: &ClusterConfig, counts: Vec<usize>) -> WorkflowSet {
        WorkflowSet::build_standalone(
            cfg.clone(),
            vec![counts],
            Arc::new(EchoLogic),
            None,
        )
    }

    /// Frozen-snapshot router: routing loads are computed once, so the
    /// spill order is deterministic for the whole test.
    fn frozen(sets: Vec<WorkflowSet>) -> FederationRouter {
        FederationRouter::new(
            sets,
            FederationConfig {
                snapshot_max_age: Duration::from_secs(3600),
                ..Default::default()
            },
        )
    }

    #[test]
    fn route_order_is_ascending_load_with_dead_sets_last() {
        let loads = [0.5, f64::INFINITY, 0.1, 0.3];
        assert_eq!(FederationRouter::route_order(&loads), vec![2, 3, 0, 1]);
        // Ties keep set-index order (stable sort).
        let tied = [0.2, 0.1, 0.2, 0.1];
        assert_eq!(FederationRouter::route_order(&tied), vec![1, 3, 0, 2]);
    }

    #[test]
    fn spills_before_rejecting_and_rejects_only_when_all_full() {
        let cfg = tiny_budget_config();
        let app = AppId(1);
        let sets = vec![
            build_set(&cfg, vec![1, 1, 1, 1]),
            build_set(&cfg, vec![1, 1, 1, 1]),
        ];
        let fed = frozen(sets);

        let payload = Payload::Bytes(vec![1]);
        // Budget 2 per set, frozen order [0, 1]: two land on set 0, the
        // next two spill to set 1, the fifth is rejected by everyone with
        // a structured retry hint.
        let mut results = Vec::new();
        for _ in 0..5 {
            results.push(fed.submit(app, payload.clone()));
        }
        for (i, expect_set) in [(0usize, 0usize), (1, 0), (2, 1), (3, 1)] {
            match &results[i] {
                Ok(handle) => assert_eq!(handle.set(), expect_set, "req {i}"),
                Err(e) => panic!("req {i}: expected acceptance, got {e:?}"),
            }
        }
        match &results[4] {
            Err(SubmitError::Overloaded { retry_after }) => {
                assert!(*retry_after > Duration::ZERO, "hint must be positive");
                assert!(*retry_after <= Duration::from_secs(64), "hint bounded by window");
            }
            other => panic!("all sets full must report Overloaded, got {other:?}"),
        }

        let counters: std::collections::HashMap<String, u64> =
            fed.metrics().counters_snapshot().into_iter().collect();
        assert_eq!(counters["fed.accepted"], 4);
        assert_eq!(counters["fed.spilled"], 2);
        assert_eq!(counters["fed.rejected"], 1);
        assert_eq!(counters["fed.set0.accepted"], 2);
        assert_eq!(counters["fed.set1.accepted"], 2);
        assert_eq!(counters["fed.set1.spill_in"], 2);
        assert_eq!(counters["fed.accepted.standard"], 4);
        assert_eq!(counters["fed.rejected.standard"], 1);
        fed.shutdown();
    }

    #[test]
    fn no_spill_mode_rejects_at_first_full_set() {
        let cfg = tiny_budget_config();
        let app = AppId(1);
        let sets = vec![
            build_set(&cfg, vec![1, 1, 1, 1]),
            build_set(&cfg, vec![1, 1, 1, 1]),
        ];
        let fed = FederationRouter::new(
            sets,
            FederationConfig {
                spill: false,
                snapshot_max_age: Duration::from_secs(3600),
                ..Default::default()
            },
        );
        let payload = Payload::Bytes(vec![2]);
        let mut accepted = 0;
        let mut rejected = 0;
        for _ in 0..4 {
            match fed.submit(app, payload.clone()) {
                Ok(_) => accepted += 1,
                Err(_) => rejected += 1,
            }
        }
        // Frozen order pins everything on set 0 (budget 2); without spill
        // the sibling's spare capacity is unreachable.
        assert_eq!((accepted, rejected), (2, 2));
        fed.shutdown();
    }

    #[test]
    fn dead_set_is_routed_around_without_counting_as_spill() {
        let cfg = tiny_budget_config();
        let app = AppId(1);
        // Set 0 has no entrance instances (regional failure): load = ∞.
        let sets = vec![
            build_set(&cfg, vec![0, 1, 1, 1]),
            build_set(&cfg, vec![1, 1, 1, 1]),
        ];
        let fed = frozen(sets);
        let handle = fed
            .submit(app, Payload::Bytes(vec![3]))
            .expect("healthy set must accept");
        assert_eq!(handle.set(), 1, "healthy set preferred");
        let counters: std::collections::HashMap<String, u64> =
            fed.metrics().counters_snapshot().into_iter().collect();
        assert_eq!(
            counters.get("fed.spilled").copied().unwrap_or(0),
            0,
            "routing around a dead set is not a spill"
        );
        fed.shutdown();
    }

    #[test]
    fn end_to_end_result_through_federation_handle() {
        let cfg = tiny_budget_config();
        let app = AppId(1);
        let fed = frozen(vec![build_set(&cfg, vec![1, 1, 1, 1])]);
        std::thread::sleep(Duration::from_millis(80));
        let handle = fed.submit(app, Payload::Bytes(vec![9])).expect("admit");
        let WaitOutcome::Done(bytes) = handle.wait(Duration::from_secs(10)) else {
            panic!("federated request must complete")
        };
        let msg = crate::transport::WorkflowMessage::decode(&bytes).unwrap();
        assert_eq!(msg.payload, Payload::Bytes(vec![9]));
        fed.shutdown();
    }

    #[test]
    fn donation_moves_idle_capacity_to_hot_set() {
        let mut cfg = tiny_budget_config();
        cfg.nm.util_window_ms = 2_000;
        let app = AppId(1);
        let mut hot_cfg = cfg.clone();
        hot_cfg.idle_pool = 0;
        let mut cold_cfg = cfg.clone();
        cold_cfg.idle_pool = 2;
        let sets = vec![
            build_set(&hot_cfg, vec![1, 1, 1, 1]),
            build_set(&cold_cfg, vec![1, 1, 1, 1]),
        ];
        let fed = frozen(sets);
        assert_eq!(fed.with_set(1, |s| s.idle_count()), 2);

        // Saturate set 0's diffusion stage. Instances self-report ~0
        // continuously, so re-assert until a rebalance pass observes the
        // hot reading (same idiom as the wset housekeeper test).
        let diffusion = crate::nm::StageKey { app, stage: 2 };
        let node = fed.with_set(0, |s| s.nm.stage_instances(diffusion)[0]);
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut action = None;
        while action.is_none() && Instant::now() < deadline {
            fed.with_set(0, |s| {
                use crate::workflow::ControlPlane;
                s.nm.report_utilization(node, 0.99);
            });
            action = fed.rebalance(app);
            std::thread::sleep(Duration::from_millis(5));
        }
        let action = action.expect("hot set must receive a donation");
        assert_eq!(action.from_set, 1);
        assert_eq!(action.to_set, 0);
        assert_eq!(fed.with_set(1, |s| s.idle_count()), 1, "donor shrank");
        let counters: std::collections::HashMap<String, u64> =
            fed.metrics().counters_snapshot().into_iter().collect();
        assert_eq!(counters["fed.donations"], 1);
        assert_eq!(counters["fed.set1.donated_out"], 1);
        assert_eq!(counters["fed.set0.donated_in"], 1);
        fed.shutdown();
    }
}
