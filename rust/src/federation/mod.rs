//! Multi-Workflow-Set federation: a global load-aware router over N
//! regionally-autonomous [`crate::wset::WorkflowSet`]s.
//!
//! The paper (§3.1–§3.2) deploys a *fleet* of Workflow Sets and lets
//! clients retry rejected requests against a different set — admission
//! pressure is resolved client-side and blindly. This module implements
//! the server-side alternative that the headline elasticity claims rest
//! on, combining three mechanisms:
//!
//! 1. **Load-aware routing** — every set's proxy exports its fast-reject
//!    state ([`crate::proxy::AdmissionSnapshot`], §5) and per-stage
//!    utilization window (§8.2); the [`FederationRouter`] sends each
//!    incoming request to the least-loaded admitting set.
//! 2. **Cross-set spill** — when the chosen set still fast-rejects (its
//!    snapshot was stale, or a burst landed between refreshes), the
//!    router spills the request to sibling sets in ascending-load order
//!    and only rejects when *every* set is at capacity. A federation of N
//!    sets therefore rejects strictly less traffic than any single set at
//!    the same offered load.
//! 3. **Elastic donation** — [`FederationRouter::rebalance`] extends the
//!    NodeManager's §8.2 idle-pool scaling across set boundaries: a cold
//!    set retires an idle-pool instance
//!    ([`crate::wset::WorkflowSet::retire_idle_instance`]) and the hot
//!    set registers fresh capacity in its place
//!    ([`crate::wset::WorkflowSet::add_idle_instance`]), which its own NM
//!    then assigns to the busiest stage.
//!
//! The router serves through the unified [`crate::client::Gateway`] API
//! (typed [`crate::client::RequestHandle`]s with priorities, deadlines,
//! and cancellation); spill, reject, donation, and per-priority counts
//! are published through a [`crate::metrics::Registry`] so the
//! `onepiece federate` driver and `benches/e11_federation.rs` can report
//! them per set.

mod router;

pub use router::{DonationAction, FederationConfig, FederationRouter, SetSnapshot};

use crate::config::ClusterConfig;
use crate::workflow::AppLogic;
use crate::wset::WorkflowSet;
use std::sync::Arc;

/// Build `config.sets` Workflow Sets — each with its **own** executor
/// pool, fabric, NodeManager, and database layer (the per-set deployment
/// shape) — behind a [`FederationRouter`].
pub fn build_federation(
    config: &ClusterConfig,
    entrance: usize,
    logic: Arc<dyn AppLogic>,
    fed: FederationConfig,
) -> FederationRouter {
    let sets: Vec<WorkflowSet> = (0..config.sets.max(1))
        .map(|_| {
            let counts: Vec<Vec<usize>> = config
                .apps
                .iter()
                .map(|app| WorkflowSet::theorem1_counts(app, entrance))
                .collect();
            WorkflowSet::build_standalone(config.clone(), counts, logic.clone(), None)
        })
        .collect();
    FederationRouter::new(sets, fed)
}
