//! Memory-centric result database (§3.4, §7).
//!
//! Design points from the paper, all implemented here:
//! - results live in RAM only (no disk path at all);
//! - keyed by the request UID; stored alongside it;
//! - purged on first successful client fetch **or** on TTL expiry
//!   ("once a client successfully fetches the result or after a
//!   predefined time-to-live expires, the data is automatically purged");
//! - replicated asynchronously to peers in the same Workflow Set with
//!   **no consensus** ("strong consistency consensus is not required");
//! - clients query one instance at a time and fall through to the next
//!   replica on miss or failure (§7).
//!
//! Extensions for the unified [`crate::client`] gateway API: stores are
//! signalled through a condvar so result waiters **block** instead of
//! busy-polling ([`MemDb::wait_signal`], [`DbClient::wait_entry`]), and
//! the workflow data plane publishes [`EntryKind`] **tombstones**
//! (deadline exceeded / cancelled / recovery failed) instead of results
//! for dropped in-flight work, stores per-UID recovery [`Checkpoint`]s
//! replayed after a worker-instance crash, and enforces
//! **first-writer-wins** on terminal entries so a replay and a late
//! original never double-publish.

mod client;
mod store;

pub use client::DbClient;
pub use store::{Checkpoint, DbStats, EntryKind, MemDb, StoredResult};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{ManualClock, NodeId, Uid};
    use std::sync::Arc;

    #[test]
    fn replication_group_end_to_end() {
        let clock = ManualClock::new();
        let dbs: Vec<Arc<MemDb>> = (0..3)
            .map(|_| Arc::new(MemDb::new(Arc::new(clock.clone()), 1_000_000)))
            .collect();
        let uid = Uid::fresh(NodeId(1));

        // Write to the first instance, replicate to the rest (async in
        // prod; direct here).
        dbs[0].put(uid, b"video bytes".to_vec());
        for peer in &dbs[1..] {
            for (u, r) in dbs[0].export_all() {
                peer.put_replica(u, r);
            }
        }

        // Client can read from any replica.
        let client = DbClient::new(dbs.clone());
        assert_eq!(client.fetch(uid).unwrap(), b"video bytes");
    }
}
