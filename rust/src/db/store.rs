//! One database instance: an in-memory UID-keyed store with TTL and
//! fetch-purge lifecycle, condvar waiters (blocking result waits without
//! busy-polling), request-lifecycle tombstones, and per-UID recovery
//! **checkpoints** (the last completed stage's output, replayed by the
//! worker-failure recovery sweep — see [`crate::wset`]).
//!
//! Terminal entries are **first-writer-wins**: while a result *or* a
//! tombstone for a UID is resident, later writes for that UID are
//! suppressed. This is the at-most-once publication guarantee the
//! recovery path leans on — a late original result racing its replayed
//! twin (or a `Failed` verdict racing a completion) can never
//! double-publish to a reader. A duplicate arriving *after* the client
//! consumed the entry (fetch purges) is inert — nothing reads that UID
//! again — and is reclaimed by the TTL sweep, exactly like the residual
//! copies on sibling replicas.

use crate::util::{Clock, Uid};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// What a stored entry represents. Besides real results the workflow
/// data plane publishes **tombstones**: terminal markers written instead
/// of a result when in-flight work was dropped (deadline passed,
/// request cancelled, recovery exhausted), so every result reader
/// observes the same terminal state the control plane decided.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EntryKind {
    /// A real generation result.
    Result,
    /// The request's deadline passed before completion.
    DeadlineExceeded,
    /// The request was cancelled in flight.
    Cancelled,
    /// The request was lost to an instance failure and its recovery
    /// retries are exhausted (or no checkpoint / no capacity remained
    /// to replay it).
    Failed,
}

/// A per-UID recovery checkpoint: the encoded [`WorkflowMessage`] as it
/// entered `stage` — exactly what a replay re-sends to that stage's
/// surviving (or freshly promoted) instances. The bytes are shared
/// (`Arc`) so replicating a checkpoint costs a refcount, not a copy.
///
/// [`WorkflowMessage`]: crate::transport::WorkflowMessage
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Stage the message was about to enter when checkpointed.
    pub stage: u32,
    /// Encoded message bytes.
    pub data: Arc<[u8]>,
    /// Store time (instance clock, ns).
    pub stored_at_ns: u64,
}

/// A stored generation result (or tombstone). The bytes are shared
/// (`Arc`), so replicating one result to N database instances costs one
/// buffer plus N refcounts — the delivery fan-out stages the payload
/// once (see [`MemDb::put_shared`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoredResult {
    pub kind: EntryKind,
    pub data: Arc<[u8]>,
    /// Store time (instance clock, ns).
    pub stored_at_ns: u64,
}

/// Store statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DbStats {
    pub puts: u64,
    pub tombstones: u64,
    pub hits: u64,
    pub misses: u64,
    pub purged_on_fetch: u64,
    pub expired: u64,
    /// Writes suppressed by first-writer-wins (late duplicates).
    pub dup_suppressed: u64,
    /// Checkpoint writes accepted.
    pub checkpoints: u64,
    /// Bytes currently resident.
    pub resident_bytes: u64,
}

/// Memory-centric database instance.
pub struct MemDb {
    clock: Arc<dyn Clock>,
    ttl_ns: u64,
    inner: Mutex<Inner>, // lint: lock-rank(db, 60)
    /// Signalled on every store; [`MemDb::wait_signal`] blocks here so
    /// result waiters sleep instead of polling.
    signal: Condvar,
}

#[derive(Default)]
struct Inner {
    map: HashMap<Uid, StoredResult>,
    /// Recovery checkpoints, kept separate from terminal entries so
    /// result counts / reader semantics are unchanged by checkpointing.
    ckpts: HashMap<Uid, Checkpoint>,
    stats: DbStats,
}

impl MemDb {
    /// `ttl_ns`: result lifetime after storage.
    pub fn new(clock: Arc<dyn Clock>, ttl_ns: u64) -> Self {
        Self {
            clock,
            ttl_ns,
            inner: Mutex::new(Inner::default()),
            signal: Condvar::new(),
        }
    }

    /// Store a result (primary write path from ResultDeliver). First
    /// terminal write wins: if `uid` already holds a result **or** a
    /// tombstone, the write is suppressed and `false` is returned — a
    /// late original result and its recovery replay can never
    /// double-publish. A winning write retires the UID's checkpoint.
    pub fn put(&self, uid: Uid, data: Vec<u8>) -> bool {
        self.put_shared(uid, data.into())
    }

    /// [`MemDb::put`] without taking buffer ownership: the caller keeps
    /// (and may hand to sibling replicas) a refcount of the same bytes.
    /// This is the replication fan-out's zero-copy write path — N
    /// replicas of one result share one staged buffer.
    pub fn put_shared(&self, uid: Uid, data: Arc<[u8]>) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.map.contains_key(&uid) {
            g.stats.dup_suppressed += 1;
            return false;
        }
        g.stats.puts += 1;
        g.stats.resident_bytes += data.len() as u64;
        g.map.insert(
            uid,
            StoredResult {
                kind: EntryKind::Result,
                data,
                stored_at_ns: self.clock.now_ns(),
            },
        );
        g.ckpts.remove(&uid);
        drop(g);
        self.signal.notify_all();
        true
    }

    /// Publish a terminal tombstone (deadline / cancellation / recovery
    /// exhausted) for `uid` instead of a result. Same first-writer-wins
    /// rule as [`MemDb::put`]: an existing result *or* tombstone is
    /// never overwritten.
    pub fn put_tombstone(&self, uid: Uid, kind: EntryKind) {
        debug_assert!(kind != EntryKind::Result, "use put() for results");
        let mut g = self.inner.lock().unwrap();
        if g.map.contains_key(&uid) {
            g.stats.dup_suppressed += 1;
            return;
        }
        g.stats.tombstones += 1;
        g.map.insert(
            uid,
            StoredResult { kind, data: Vec::new().into(), stored_at_ns: self.clock.now_ns() },
        );
        g.ckpts.remove(&uid);
        drop(g);
        self.signal.notify_all();
    }

    /// Record the recovery checkpoint for `uid`: the encoded message as
    /// it entered `stage`. Stage progress is monotone (a late
    /// lower-stage write cannot rewind a newer checkpoint) and a UID
    /// that already reached a terminal entry accepts no further
    /// checkpoints.
    pub fn put_checkpoint(&self, uid: Uid, stage: u32, data: Arc<[u8]>) {
        let mut g = self.inner.lock().unwrap();
        if g.map.contains_key(&uid) {
            return;
        }
        if matches!(g.ckpts.get(&uid), Some(c) if c.stage >= stage) {
            return;
        }
        g.stats.checkpoints += 1;
        g.ckpts.insert(
            uid,
            Checkpoint { stage, data, stored_at_ns: self.clock.now_ns() },
        );
    }

    /// Peek the live checkpoint for `uid` (recovery read path; the
    /// checkpoint stays — a second failure may need it again). Expired
    /// checkpoints read as a miss.
    pub fn checkpoint(&self, uid: Uid) -> Option<Checkpoint> {
        let now = self.clock.now_ns();
        let g = self.inner.lock().unwrap();
        g.ckpts
            .get(&uid)
            .filter(|c| now.saturating_sub(c.stored_at_ns) <= self.ttl_ns)
            .cloned()
    }

    /// Drop the checkpoint for `uid` (e.g. the request was rejected
    /// after its admission checkpoint was written).
    pub fn remove_checkpoint(&self, uid: Uid) {
        self.inner.lock().unwrap().ckpts.remove(&uid);
    }

    /// Live checkpoint count.
    pub fn checkpoint_count(&self) -> usize {
        self.inner.lock().unwrap().ckpts.len()
    }

    /// Store a replicated copy (keeps the origin's timestamp semantics
    /// simple: replicas restart the TTL, which only lengthens
    /// availability — acceptable per the paper's weak-consistency model).
    /// Honors the same first-writer-wins rule as [`MemDb::put`]: a stale
    /// replicated copy arriving after this replica already holds a
    /// terminal entry (e.g. a `Failed` tombstone) must not resurrect the
    /// request.
    pub fn put_replica(&self, uid: Uid, result: StoredResult) {
        let mut g = self.inner.lock().unwrap();
        if g.map.contains_key(&uid) {
            g.stats.dup_suppressed += 1;
            return;
        }
        g.stats.resident_bytes += result.data.len() as u64;
        g.map.insert(uid, result);
        g.ckpts.remove(&uid);
        drop(g);
        self.signal.notify_all();
    }

    /// Fetch-and-purge any entry kind: the typed client read path.
    /// Returns `None` on miss or if the entry expired.
    pub fn fetch_entry(&self, uid: Uid) -> Option<(EntryKind, Vec<u8>)> {
        self.fetch_if(uid, |_| true)
    }

    /// Fetch-and-purge a **result**: the paper's legacy client read path.
    /// Tombstones are left in place (they expire by TTL or are consumed
    /// by [`MemDb::fetch_entry`]) and read as a miss.
    pub fn fetch(&self, uid: Uid) -> Option<Vec<u8>> {
        self.fetch_if(uid, |k| k == EntryKind::Result).map(|(_, data)| data)
    }

    fn fetch_if(
        &self,
        uid: Uid,
        want: impl Fn(EntryKind) -> bool,
    ) -> Option<(EntryKind, Vec<u8>)> {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        // Peek the kind first (EntryKind is Copy) so the map borrow ends
        // before stats are touched.
        let kind = g.map.get(&uid).map(|r| r.kind);
        match kind {
            Some(k) if want(k) => {
                // Present: peeked above under the same lock. A `None`
                // here would mean the map changed under a held guard —
                // answer miss rather than crash the db thread.
                let Some(r) = g.map.remove(&uid) else {
                    g.stats.misses += 1;
                    return None;
                };
                g.ckpts.remove(&uid);
                g.stats.resident_bytes -= r.data.len() as u64;
                if now.saturating_sub(r.stored_at_ns) <= self.ttl_ns {
                    g.stats.hits += 1;
                    g.stats.purged_on_fetch += 1;
                    // Client egress: the one place the shared bytes are
                    // materialized into an owned buffer.
                    Some((r.kind, r.data.to_vec()))
                } else {
                    // Present but expired: purge, report miss.
                    g.stats.expired += 1;
                    g.stats.misses += 1;
                    None
                }
            }
            // Present but filtered out (a tombstone under fetch()), or
            // absent: a miss either way; the entry stays.
            Some(_) | None => {
                g.stats.misses += 1;
                None
            }
        }
    }

    /// Block until *any* store lands on this instance or `timeout`
    /// elapses. Callers re-check their UID after waking (puts for other
    /// UIDs wake waiters too — the common case is the waiter's own
    /// result, written to every replica by ResultDeliver).
    pub fn wait_signal(&self, timeout: Duration) {
        let g = self.inner.lock().unwrap();
        let _ = self.signal.wait_timeout(g, timeout).unwrap();
    }

    /// Peek without purging (replication reads).
    pub fn peek(&self, uid: Uid) -> Option<StoredResult> {
        let g = self.inner.lock().unwrap();
        g.map.get(&uid).cloned()
    }

    /// Drop all expired entries; returns how many were purged. Run
    /// periodically by the instance's housekeeping loop.
    pub fn purge_expired(&self) -> usize {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        let ttl = self.ttl_ns;
        let before = g.map.len();
        let mut freed = 0u64;
        g.map.retain(|_, r| {
            let live = now.saturating_sub(r.stored_at_ns) <= ttl;
            if !live {
                freed += r.data.len() as u64;
            }
            live
        });
        let purged = before - g.map.len();
        g.stats.expired += purged as u64;
        g.stats.resident_bytes -= freed;
        // Checkpoints age out on the same TTL (a request this old has
        // long since been swept from the tracker — nothing will replay).
        g.ckpts
            .retain(|_, c| now.saturating_sub(c.stored_at_ns) <= ttl);
        purged
    }

    /// Snapshot of all live entries (replication export).
    pub fn export_all(&self) -> Vec<(Uid, StoredResult)> {
        let g = self.inner.lock().unwrap();
        g.map.iter().map(|(k, v)| (*k, v.clone())).collect()
    }

    /// Entry count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> DbStats {
        self.inner.lock().unwrap().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{ManualClock, NodeId};

    fn setup(ttl: u64) -> (ManualClock, MemDb) {
        let c = ManualClock::new();
        let db = MemDb::new(Arc::new(c.clone()), ttl);
        (c, db)
    }

    fn uid(i: u32) -> Uid {
        Uid::fresh(NodeId(i))
    }

    #[test]
    fn fetch_purges() {
        let (_c, db) = setup(1000);
        let u = uid(1);
        db.put(u, vec![1, 2, 3]);
        assert_eq!(db.fetch(u), Some(vec![1, 2, 3]));
        // Second fetch: already purged.
        assert_eq!(db.fetch(u), None);
        let s = db.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.purged_on_fetch, 1);
        assert_eq!(s.resident_bytes, 0);
    }

    #[test]
    fn ttl_expiry() {
        let (c, db) = setup(1000);
        let u = uid(2);
        db.put(u, vec![7; 10]);
        c.advance(1001);
        assert_eq!(db.fetch(u), None);
        assert_eq!(db.stats().expired, 1);
    }

    #[test]
    fn within_ttl_survives() {
        let (c, db) = setup(1000);
        let u = uid(3);
        db.put(u, vec![9]);
        c.advance(999);
        assert_eq!(db.fetch(u), Some(vec![9]));
    }

    #[test]
    fn purge_expired_sweeps() {
        let (c, db) = setup(100);
        for i in 0..10 {
            db.put(uid(i), vec![0; 8]);
        }
        c.advance(50);
        for i in 10..15 {
            db.put(uid(i), vec![0; 8]);
        }
        c.advance(60); // first 10 expired (age 110), last 5 live (age 60)
        assert_eq!(db.purge_expired(), 10);
        assert_eq!(db.len(), 5);
        assert_eq!(db.stats().resident_bytes, 40);
    }

    #[test]
    fn duplicate_put_first_writer_wins() {
        let (_c, db) = setup(1000);
        let u = uid(4);
        assert!(db.put(u, vec![1; 100]));
        // A replayed twin's duplicate result is suppressed entirely.
        assert!(!db.put(u, vec![2; 10]));
        assert_eq!(db.stats().resident_bytes, 100);
        assert_eq!(db.stats().dup_suppressed, 1);
        assert_eq!(db.fetch(u), Some(vec![1; 100]));
    }

    #[test]
    fn put_shared_replicas_share_one_buffer() {
        let (_c, a) = setup(1000);
        let (_c2, b) = setup(1000);
        let u = uid(50);
        let bytes: Arc<[u8]> = vec![7u8; 1 << 16].into();
        assert!(a.put_shared(u, bytes.clone()));
        assert!(b.put_shared(u, bytes.clone()));
        // One buffer, three holders: the caller and both replicas.
        assert_eq!(Arc::strong_count(&bytes), 3);
        assert!(std::ptr::eq(
            a.peek(u).unwrap().data.as_ref(),
            bytes.as_ref()
        ));
        assert_eq!(a.fetch(u), Some(vec![7u8; 1 << 16]));
        assert_eq!(Arc::strong_count(&bytes), 2, "fetch dropped a's refcount");
    }

    #[test]
    fn result_never_overwrites_tombstone() {
        // A Failed verdict already published; the late original result
        // must not resurrect the request (exactly one terminal entry).
        let (_c, db) = setup(1000);
        let u = uid(40);
        db.put_tombstone(u, EntryKind::Failed);
        assert!(!db.put(u, vec![9]));
        db.put_tombstone(u, EntryKind::Cancelled); // also suppressed
        assert_eq!(db.fetch_entry(u), Some((EntryKind::Failed, vec![])));
        assert_eq!(db.fetch_entry(u), None, "consumed exactly once");
    }

    #[test]
    fn checkpoint_lifecycle() {
        let (_c, db) = setup(1000);
        let u = uid(41);
        let bytes: Arc<[u8]> = vec![1, 2, 3].into();
        db.put_checkpoint(u, 1, bytes.clone());
        // Monotone: a late stage-0 write cannot rewind.
        db.put_checkpoint(u, 0, vec![9].into());
        let c = db.checkpoint(u).unwrap();
        assert_eq!((c.stage, &c.data[..]), (1, &[1u8, 2, 3][..]));
        // Peek does not consume (a second failure may replay again).
        assert!(db.checkpoint(u).is_some());
        assert_eq!(db.checkpoint_count(), 1);
        // A newer stage advances it; a terminal write retires it.
        db.put_checkpoint(u, 2, vec![4].into());
        assert_eq!(db.checkpoint(u).unwrap().stage, 2);
        db.put(u, vec![7]);
        assert_eq!(db.checkpoint_count(), 0, "terminal entry retires the checkpoint");
        db.put_checkpoint(u, 3, bytes); // post-terminal writes are ignored
        assert_eq!(db.checkpoint_count(), 0);
    }

    #[test]
    fn checkpoints_expire_with_ttl() {
        let (c, db) = setup(100);
        db.put_checkpoint(uid(42), 1, vec![1].into());
        c.advance(101);
        assert!(db.checkpoint(uid(42)).is_none(), "expired checkpoint reads as miss");
        db.purge_expired();
        assert_eq!(db.checkpoint_count(), 0);
    }

    #[test]
    fn fetch_retires_checkpoint() {
        let (_c, db) = setup(1000);
        let u = uid(43);
        db.put_checkpoint(u, 1, vec![1].into());
        // Tombstone retires it; consuming the tombstone keeps it gone.
        db.put_tombstone(u, EntryKind::DeadlineExceeded);
        assert_eq!(db.checkpoint_count(), 0);
        assert!(db.fetch_entry(u).is_some());
        assert_eq!(db.checkpoint_count(), 0);
    }

    #[test]
    fn peek_does_not_purge() {
        let (_c, db) = setup(1000);
        let u = uid(5);
        db.put(u, vec![5]);
        assert!(db.peek(u).is_some());
        assert!(db.peek(u).is_some());
        assert_eq!(db.len(), 1);
    }

    #[test]
    fn tombstone_lifecycle() {
        let (_c, db) = setup(1000);
        let u = uid(6);
        db.put_tombstone(u, EntryKind::DeadlineExceeded);
        // Legacy fetch treats a tombstone as a miss and leaves it.
        assert_eq!(db.fetch(u), None);
        assert_eq!(db.len(), 1);
        // Typed fetch consumes it.
        assert_eq!(db.fetch_entry(u), Some((EntryKind::DeadlineExceeded, vec![])));
        assert_eq!(db.fetch_entry(u), None);
        assert_eq!(db.stats().tombstones, 1);
    }

    #[test]
    fn tombstone_never_overwrites_result() {
        let (_c, db) = setup(1000);
        let u = uid(7);
        db.put(u, vec![1]);
        db.put_tombstone(u, EntryKind::Cancelled);
        assert_eq!(db.fetch_entry(u), Some((EntryKind::Result, vec![1])));
    }

    #[test]
    fn tombstones_expire_by_ttl() {
        let (c, db) = setup(100);
        db.put_tombstone(uid(8), EntryKind::Cancelled);
        c.advance(101);
        assert_eq!(db.purge_expired(), 1);
        assert!(db.is_empty());
    }

    #[test]
    fn put_wakes_waiter() {
        let (_c, db) = setup(u64::MAX);
        let db = Arc::new(db);
        let u = uid(9);
        let waiter = {
            let db = db.clone();
            std::thread::spawn(move || {
                let deadline = std::time::Instant::now() + Duration::from_secs(5);
                loop {
                    if let Some(r) = db.fetch(u) {
                        return r;
                    }
                    assert!(std::time::Instant::now() < deadline, "wait must not hang");
                    db.wait_signal(Duration::from_secs(1));
                }
            })
        };
        std::thread::sleep(Duration::from_millis(30));
        db.put(u, vec![42]);
        assert_eq!(waiter.join().unwrap(), vec![42]);
    }
}
