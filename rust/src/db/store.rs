//! One database instance: an in-memory UID-keyed store with TTL and
//! fetch-purge lifecycle.

use crate::util::{Clock, Uid};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// A stored generation result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoredResult {
    pub data: Vec<u8>,
    /// Store time (instance clock, ns).
    pub stored_at_ns: u64,
}

/// Store statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DbStats {
    pub puts: u64,
    pub hits: u64,
    pub misses: u64,
    pub purged_on_fetch: u64,
    pub expired: u64,
    /// Bytes currently resident.
    pub resident_bytes: u64,
}

/// Memory-centric database instance.
pub struct MemDb {
    clock: Arc<dyn Clock>,
    ttl_ns: u64,
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    map: HashMap<Uid, StoredResult>,
    stats: DbStats,
}

impl MemDb {
    /// `ttl_ns`: result lifetime after storage.
    pub fn new(clock: Arc<dyn Clock>, ttl_ns: u64) -> Self {
        Self {
            clock,
            ttl_ns,
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Store a result (primary write path from ResultDeliver).
    pub fn put(&self, uid: Uid, data: Vec<u8>) {
        let mut g = self.inner.lock().unwrap();
        g.stats.puts += 1;
        g.stats.resident_bytes += data.len() as u64;
        let prev = g.map.insert(
            uid,
            StoredResult { data, stored_at_ns: self.clock.now_ns() },
        );
        if let Some(p) = prev {
            g.stats.resident_bytes -= p.data.len() as u64;
        }
    }

    /// Store a replicated copy (keeps the origin's timestamp semantics
    /// simple: replicas restart the TTL, which only lengthens
    /// availability — acceptable per the paper's weak-consistency model).
    pub fn put_replica(&self, uid: Uid, result: StoredResult) {
        let mut g = self.inner.lock().unwrap();
        g.stats.resident_bytes += result.data.len() as u64;
        if let Some(p) = g.map.insert(uid, result) {
            g.stats.resident_bytes -= p.data.len() as u64;
        }
    }

    /// Fetch-and-purge: the paper's client read path. Returns `None` on
    /// miss or if the entry expired.
    pub fn fetch(&self, uid: Uid) -> Option<Vec<u8>> {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        match g.map.remove(&uid) {
            Some(r) if now.saturating_sub(r.stored_at_ns) <= self.ttl_ns => {
                g.stats.hits += 1;
                g.stats.purged_on_fetch += 1;
                g.stats.resident_bytes -= r.data.len() as u64;
                Some(r.data)
            }
            Some(r) => {
                // Present but expired: purge, report miss.
                g.stats.expired += 1;
                g.stats.misses += 1;
                g.stats.resident_bytes -= r.data.len() as u64;
                None
            }
            None => {
                g.stats.misses += 1;
                None
            }
        }
    }

    /// Peek without purging (replication reads).
    pub fn peek(&self, uid: Uid) -> Option<StoredResult> {
        let g = self.inner.lock().unwrap();
        g.map.get(&uid).cloned()
    }

    /// Drop all expired entries; returns how many were purged. Run
    /// periodically by the instance's housekeeping loop.
    pub fn purge_expired(&self) -> usize {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        let ttl = self.ttl_ns;
        let before = g.map.len();
        let mut freed = 0u64;
        g.map.retain(|_, r| {
            let live = now.saturating_sub(r.stored_at_ns) <= ttl;
            if !live {
                freed += r.data.len() as u64;
            }
            live
        });
        let purged = before - g.map.len();
        g.stats.expired += purged as u64;
        g.stats.resident_bytes -= freed;
        purged
    }

    /// Snapshot of all live entries (replication export).
    pub fn export_all(&self) -> Vec<(Uid, StoredResult)> {
        let g = self.inner.lock().unwrap();
        g.map.iter().map(|(k, v)| (*k, v.clone())).collect()
    }

    /// Entry count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> DbStats {
        self.inner.lock().unwrap().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{ManualClock, NodeId};

    fn setup(ttl: u64) -> (ManualClock, MemDb) {
        let c = ManualClock::new();
        let db = MemDb::new(Arc::new(c.clone()), ttl);
        (c, db)
    }

    fn uid(i: u32) -> Uid {
        Uid::fresh(NodeId(i))
    }

    #[test]
    fn fetch_purges() {
        let (_c, db) = setup(1000);
        let u = uid(1);
        db.put(u, vec![1, 2, 3]);
        assert_eq!(db.fetch(u), Some(vec![1, 2, 3]));
        // Second fetch: already purged.
        assert_eq!(db.fetch(u), None);
        let s = db.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.purged_on_fetch, 1);
        assert_eq!(s.resident_bytes, 0);
    }

    #[test]
    fn ttl_expiry() {
        let (c, db) = setup(1000);
        let u = uid(2);
        db.put(u, vec![7; 10]);
        c.advance(1001);
        assert_eq!(db.fetch(u), None);
        assert_eq!(db.stats().expired, 1);
    }

    #[test]
    fn within_ttl_survives() {
        let (c, db) = setup(1000);
        let u = uid(3);
        db.put(u, vec![9]);
        c.advance(999);
        assert_eq!(db.fetch(u), Some(vec![9]));
    }

    #[test]
    fn purge_expired_sweeps() {
        let (c, db) = setup(100);
        for i in 0..10 {
            db.put(uid(i), vec![0; 8]);
        }
        c.advance(50);
        for i in 10..15 {
            db.put(uid(i), vec![0; 8]);
        }
        c.advance(60); // first 10 expired (age 110), last 5 live (age 60)
        assert_eq!(db.purge_expired(), 10);
        assert_eq!(db.len(), 5);
        assert_eq!(db.stats().resident_bytes, 40);
    }

    #[test]
    fn overwrite_accounts_bytes() {
        let (_c, db) = setup(1000);
        let u = uid(4);
        db.put(u, vec![0; 100]);
        db.put(u, vec![0; 10]);
        assert_eq!(db.stats().resident_bytes, 10);
    }

    #[test]
    fn peek_does_not_purge() {
        let (_c, db) = setup(1000);
        let u = uid(5);
        db.put(u, vec![5]);
        assert!(db.peek(u).is_some());
        assert!(db.peek(u).is_some());
        assert_eq!(db.len(), 1);
    }
}
