//! One database instance: an in-memory UID-keyed store with TTL and
//! fetch-purge lifecycle, condvar waiters (blocking result waits without
//! busy-polling), and request-lifecycle tombstones.

use crate::util::{Clock, Uid};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// What a stored entry represents. Besides real results the workflow
/// data plane publishes **tombstones**: terminal markers written instead
/// of a result when in-flight work was dropped (deadline passed,
/// request cancelled), so every result reader observes the same terminal
/// state the control plane decided.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EntryKind {
    /// A real generation result.
    Result,
    /// The request's deadline passed before completion.
    DeadlineExceeded,
    /// The request was cancelled in flight.
    Cancelled,
}

/// A stored generation result (or tombstone).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoredResult {
    pub kind: EntryKind,
    pub data: Vec<u8>,
    /// Store time (instance clock, ns).
    pub stored_at_ns: u64,
}

/// Store statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DbStats {
    pub puts: u64,
    pub tombstones: u64,
    pub hits: u64,
    pub misses: u64,
    pub purged_on_fetch: u64,
    pub expired: u64,
    /// Bytes currently resident.
    pub resident_bytes: u64,
}

/// Memory-centric database instance.
pub struct MemDb {
    clock: Arc<dyn Clock>,
    ttl_ns: u64,
    inner: Mutex<Inner>,
    /// Signalled on every store; [`MemDb::wait_signal`] blocks here so
    /// result waiters sleep instead of polling.
    signal: Condvar,
}

#[derive(Default)]
struct Inner {
    map: HashMap<Uid, StoredResult>,
    stats: DbStats,
}

impl MemDb {
    /// `ttl_ns`: result lifetime after storage.
    pub fn new(clock: Arc<dyn Clock>, ttl_ns: u64) -> Self {
        Self {
            clock,
            ttl_ns,
            inner: Mutex::new(Inner::default()),
            signal: Condvar::new(),
        }
    }

    /// Store a result (primary write path from ResultDeliver).
    pub fn put(&self, uid: Uid, data: Vec<u8>) {
        let mut g = self.inner.lock().unwrap();
        g.stats.puts += 1;
        g.stats.resident_bytes += data.len() as u64;
        let prev = g.map.insert(
            uid,
            StoredResult {
                kind: EntryKind::Result,
                data,
                stored_at_ns: self.clock.now_ns(),
            },
        );
        if let Some(p) = prev {
            g.stats.resident_bytes -= p.data.len() as u64;
        }
        drop(g);
        self.signal.notify_all();
    }

    /// Publish a terminal tombstone (deadline/cancellation) for `uid`
    /// instead of a result. A tombstone never overwrites a real result
    /// that already arrived (first terminal write wins).
    pub fn put_tombstone(&self, uid: Uid, kind: EntryKind) {
        debug_assert!(kind != EntryKind::Result, "use put() for results");
        let mut g = self.inner.lock().unwrap();
        if matches!(g.map.get(&uid), Some(r) if r.kind == EntryKind::Result) {
            return;
        }
        g.stats.tombstones += 1;
        g.map.insert(
            uid,
            StoredResult { kind, data: Vec::new(), stored_at_ns: self.clock.now_ns() },
        );
        drop(g);
        self.signal.notify_all();
    }

    /// Store a replicated copy (keeps the origin's timestamp semantics
    /// simple: replicas restart the TTL, which only lengthens
    /// availability — acceptable per the paper's weak-consistency model).
    pub fn put_replica(&self, uid: Uid, result: StoredResult) {
        let mut g = self.inner.lock().unwrap();
        g.stats.resident_bytes += result.data.len() as u64;
        if let Some(p) = g.map.insert(uid, result) {
            g.stats.resident_bytes -= p.data.len() as u64;
        }
        drop(g);
        self.signal.notify_all();
    }

    /// Fetch-and-purge any entry kind: the typed client read path.
    /// Returns `None` on miss or if the entry expired.
    pub fn fetch_entry(&self, uid: Uid) -> Option<(EntryKind, Vec<u8>)> {
        self.fetch_if(uid, |_| true)
    }

    /// Fetch-and-purge a **result**: the paper's legacy client read path.
    /// Tombstones are left in place (they expire by TTL or are consumed
    /// by [`MemDb::fetch_entry`]) and read as a miss.
    pub fn fetch(&self, uid: Uid) -> Option<Vec<u8>> {
        self.fetch_if(uid, |k| k == EntryKind::Result).map(|(_, data)| data)
    }

    fn fetch_if(
        &self,
        uid: Uid,
        want: impl Fn(EntryKind) -> bool,
    ) -> Option<(EntryKind, Vec<u8>)> {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        // Peek the kind first (EntryKind is Copy) so the map borrow ends
        // before stats are touched.
        let kind = g.map.get(&uid).map(|r| r.kind);
        match kind {
            Some(k) if want(k) => {
                let r = g.map.remove(&uid).expect("present: just peeked");
                g.stats.resident_bytes -= r.data.len() as u64;
                if now.saturating_sub(r.stored_at_ns) <= self.ttl_ns {
                    g.stats.hits += 1;
                    g.stats.purged_on_fetch += 1;
                    Some((r.kind, r.data))
                } else {
                    // Present but expired: purge, report miss.
                    g.stats.expired += 1;
                    g.stats.misses += 1;
                    None
                }
            }
            // Present but filtered out (a tombstone under fetch()), or
            // absent: a miss either way; the entry stays.
            Some(_) | None => {
                g.stats.misses += 1;
                None
            }
        }
    }

    /// Block until *any* store lands on this instance or `timeout`
    /// elapses. Callers re-check their UID after waking (puts for other
    /// UIDs wake waiters too — the common case is the waiter's own
    /// result, written to every replica by ResultDeliver).
    pub fn wait_signal(&self, timeout: Duration) {
        let g = self.inner.lock().unwrap();
        let _ = self.signal.wait_timeout(g, timeout).unwrap();
    }

    /// Peek without purging (replication reads).
    pub fn peek(&self, uid: Uid) -> Option<StoredResult> {
        let g = self.inner.lock().unwrap();
        g.map.get(&uid).cloned()
    }

    /// Drop all expired entries; returns how many were purged. Run
    /// periodically by the instance's housekeeping loop.
    pub fn purge_expired(&self) -> usize {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        let ttl = self.ttl_ns;
        let before = g.map.len();
        let mut freed = 0u64;
        g.map.retain(|_, r| {
            let live = now.saturating_sub(r.stored_at_ns) <= ttl;
            if !live {
                freed += r.data.len() as u64;
            }
            live
        });
        let purged = before - g.map.len();
        g.stats.expired += purged as u64;
        g.stats.resident_bytes -= freed;
        purged
    }

    /// Snapshot of all live entries (replication export).
    pub fn export_all(&self) -> Vec<(Uid, StoredResult)> {
        let g = self.inner.lock().unwrap();
        g.map.iter().map(|(k, v)| (*k, v.clone())).collect()
    }

    /// Entry count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> DbStats {
        self.inner.lock().unwrap().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{ManualClock, NodeId};

    fn setup(ttl: u64) -> (ManualClock, MemDb) {
        let c = ManualClock::new();
        let db = MemDb::new(Arc::new(c.clone()), ttl);
        (c, db)
    }

    fn uid(i: u32) -> Uid {
        Uid::fresh(NodeId(i))
    }

    #[test]
    fn fetch_purges() {
        let (_c, db) = setup(1000);
        let u = uid(1);
        db.put(u, vec![1, 2, 3]);
        assert_eq!(db.fetch(u), Some(vec![1, 2, 3]));
        // Second fetch: already purged.
        assert_eq!(db.fetch(u), None);
        let s = db.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.purged_on_fetch, 1);
        assert_eq!(s.resident_bytes, 0);
    }

    #[test]
    fn ttl_expiry() {
        let (c, db) = setup(1000);
        let u = uid(2);
        db.put(u, vec![7; 10]);
        c.advance(1001);
        assert_eq!(db.fetch(u), None);
        assert_eq!(db.stats().expired, 1);
    }

    #[test]
    fn within_ttl_survives() {
        let (c, db) = setup(1000);
        let u = uid(3);
        db.put(u, vec![9]);
        c.advance(999);
        assert_eq!(db.fetch(u), Some(vec![9]));
    }

    #[test]
    fn purge_expired_sweeps() {
        let (c, db) = setup(100);
        for i in 0..10 {
            db.put(uid(i), vec![0; 8]);
        }
        c.advance(50);
        for i in 10..15 {
            db.put(uid(i), vec![0; 8]);
        }
        c.advance(60); // first 10 expired (age 110), last 5 live (age 60)
        assert_eq!(db.purge_expired(), 10);
        assert_eq!(db.len(), 5);
        assert_eq!(db.stats().resident_bytes, 40);
    }

    #[test]
    fn overwrite_accounts_bytes() {
        let (_c, db) = setup(1000);
        let u = uid(4);
        db.put(u, vec![0; 100]);
        db.put(u, vec![0; 10]);
        assert_eq!(db.stats().resident_bytes, 10);
    }

    #[test]
    fn peek_does_not_purge() {
        let (_c, db) = setup(1000);
        let u = uid(5);
        db.put(u, vec![5]);
        assert!(db.peek(u).is_some());
        assert!(db.peek(u).is_some());
        assert_eq!(db.len(), 1);
    }

    #[test]
    fn tombstone_lifecycle() {
        let (_c, db) = setup(1000);
        let u = uid(6);
        db.put_tombstone(u, EntryKind::DeadlineExceeded);
        // Legacy fetch treats a tombstone as a miss and leaves it.
        assert_eq!(db.fetch(u), None);
        assert_eq!(db.len(), 1);
        // Typed fetch consumes it.
        assert_eq!(db.fetch_entry(u), Some((EntryKind::DeadlineExceeded, vec![])));
        assert_eq!(db.fetch_entry(u), None);
        assert_eq!(db.stats().tombstones, 1);
    }

    #[test]
    fn tombstone_never_overwrites_result() {
        let (_c, db) = setup(1000);
        let u = uid(7);
        db.put(u, vec![1]);
        db.put_tombstone(u, EntryKind::Cancelled);
        assert_eq!(db.fetch_entry(u), Some((EntryKind::Result, vec![1])));
    }

    #[test]
    fn tombstones_expire_by_ttl() {
        let (c, db) = setup(100);
        db.put_tombstone(uid(8), EntryKind::Cancelled);
        c.advance(101);
        assert_eq!(db.purge_expired(), 1);
        assert!(db.is_empty());
    }

    #[test]
    fn put_wakes_waiter() {
        let (_c, db) = setup(u64::MAX);
        let db = Arc::new(db);
        let u = uid(9);
        let waiter = {
            let db = db.clone();
            std::thread::spawn(move || {
                let deadline = std::time::Instant::now() + Duration::from_secs(5);
                loop {
                    if let Some(r) = db.fetch(u) {
                        return r;
                    }
                    assert!(std::time::Instant::now() < deadline, "wait must not hang");
                    db.wait_signal(Duration::from_secs(1));
                }
            })
        };
        std::thread::sleep(Duration::from_millis(30));
        db.put(u, vec![42]);
        assert_eq!(waiter.join().unwrap(), vec![42]);
    }
}
