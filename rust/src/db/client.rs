//! Client read path (§7): "clients or proxies retrieve results by
//! querying one database instance at a time. If the result is absent —
//! due to ongoing replication or instance failure — the client proceeds
//! to query another instance in the next attempt."

use super::MemDb;
use crate::util::Uid;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Handle to one replica with a liveness switch (tests kill replicas).
pub struct Replica {
    pub db: Arc<MemDb>,
    pub alive: AtomicBool,
}

/// Client that retries across the replica set.
pub struct DbClient {
    replicas: Vec<Replica>,
}

impl DbClient {
    pub fn new(dbs: Vec<Arc<MemDb>>) -> Self {
        Self {
            replicas: dbs
                .into_iter()
                .map(|db| Replica { db, alive: AtomicBool::new(true) })
                .collect(),
        }
    }

    /// Mark a replica dead/alive (fault injection).
    pub fn set_alive(&self, idx: usize, alive: bool) {
        self.replicas[idx].alive.store(alive, Ordering::SeqCst);
    }

    /// Fetch: query replicas one at a time, first hit wins (and purges on
    /// that replica; other replicas purge by TTL — the paper's transient
    /// model tolerates the stale copies).
    pub fn fetch(&self, uid: Uid) -> Option<Vec<u8>> {
        for r in &self.replicas {
            if !r.alive.load(Ordering::SeqCst) {
                continue; // instance failure: try the next one
            }
            if let Some(data) = r.db.fetch(uid) {
                return Some(data);
            }
        }
        None
    }

    /// Number of replicas.
    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    /// True when there are no replicas.
    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{ManualClock, NodeId};

    fn setup(n: usize) -> (Vec<Arc<MemDb>>, DbClient) {
        let clock = Arc::new(ManualClock::new());
        let dbs: Vec<Arc<MemDb>> = (0..n)
            .map(|_| Arc::new(MemDb::new(clock.clone(), 1_000_000)))
            .collect();
        let client = DbClient::new(dbs.clone());
        (dbs, client)
    }

    #[test]
    fn falls_through_to_replica() {
        let (dbs, client) = setup(3);
        let u = Uid::fresh(NodeId(0));
        // Result only reached the third replica (replication lag).
        dbs[2].put(u, b"late".to_vec());
        assert_eq!(client.fetch(u), Some(b"late".to_vec()));
    }

    #[test]
    fn dead_primary_served_by_backup() {
        let (dbs, client) = setup(2);
        let u = Uid::fresh(NodeId(0));
        dbs[0].put(u, b"r".to_vec());
        dbs[1].put(u, b"r".to_vec());
        client.set_alive(0, false);
        assert_eq!(client.fetch(u), Some(b"r".to_vec()));
    }

    #[test]
    fn all_missing_is_none() {
        let (_dbs, client) = setup(3);
        assert_eq!(client.fetch(Uid::fresh(NodeId(0))), None);
    }

    #[test]
    fn all_dead_is_none() {
        let (dbs, client) = setup(2);
        let u = Uid::fresh(NodeId(0));
        dbs[0].put(u, b"x".to_vec());
        client.set_alive(0, false);
        client.set_alive(1, false);
        assert_eq!(client.fetch(u), None);
    }
}
