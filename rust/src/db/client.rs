//! Client read path (§7): "clients or proxies retrieve results by
//! querying one database instance at a time. If the result is absent —
//! due to ongoing replication or instance failure — the client proceeds
//! to query another instance in the next attempt."

use super::{Checkpoint, EntryKind, MemDb};
use crate::util::Uid;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Upper bound on one blocking slice of a multi-replica wait: the waiter
/// blocks on one replica's condvar, so a result that lands only on
/// *another* replica (replication lag, replica death mid-wait) is still
/// observed within this bound.
const WAIT_SLICE: Duration = Duration::from_millis(50);

/// Handle to one replica with a liveness switch (tests kill replicas).
pub struct Replica {
    pub db: Arc<MemDb>,
    pub alive: AtomicBool,
}

/// Client that retries across the replica set.
pub struct DbClient {
    replicas: Vec<Replica>,
}

impl DbClient {
    pub fn new(dbs: Vec<Arc<MemDb>>) -> Self {
        Self {
            replicas: dbs
                .into_iter()
                .map(|db| Replica { db, alive: AtomicBool::new(true) })
                .collect(),
        }
    }

    /// Mark a replica dead/alive (fault injection).
    pub fn set_alive(&self, idx: usize, alive: bool) {
        self.replicas[idx].alive.store(alive, Ordering::SeqCst);
    }

    /// Fetch a result: query replicas one at a time, first hit wins (and
    /// purges on that replica; other replicas purge by TTL — the paper's
    /// transient model tolerates the stale copies). Tombstones read as a
    /// miss; use [`DbClient::fetch_entry`] for the typed lifecycle view.
    pub fn fetch(&self, uid: Uid) -> Option<Vec<u8>> {
        for r in &self.replicas {
            if !r.alive.load(Ordering::SeqCst) {
                continue; // instance failure: try the next one
            }
            if let Some(data) = r.db.fetch(uid) {
                return Some(data);
            }
        }
        None
    }

    /// Typed fetch: result **or** tombstone, whichever terminal entry a
    /// replica holds. Same one-at-a-time fall-through as
    /// [`DbClient::fetch`].
    pub fn fetch_entry(&self, uid: Uid) -> Option<(EntryKind, Vec<u8>)> {
        for r in &self.replicas {
            if !r.alive.load(Ordering::SeqCst) {
                continue;
            }
            if let Some(entry) = r.db.fetch_entry(uid) {
                return Some(entry);
            }
        }
        None
    }

    /// Block until any replica signals a store, or `timeout` elapses.
    /// The blocking primitive behind [`crate::client::RequestHandle::wait`]
    /// — waiters sleep on a replica condvar instead of busy-polling.
    pub fn wait_signal(&self, timeout: Duration) {
        match self
            .replicas
            .iter()
            .find(|r| r.alive.load(Ordering::SeqCst))
        {
            Some(r) => r.db.wait_signal(timeout.min(WAIT_SLICE)),
            // No live replica to block on: bounded sleep, then the caller
            // re-checks (replicas may come back alive).
            None => std::thread::sleep(timeout.min(Duration::from_millis(5))),
        }
    }

    /// Blocking typed fetch: wait up to `timeout` for a result or
    /// tombstone to land on any replica.
    pub fn wait_entry(&self, uid: Uid, timeout: Duration) -> Option<(EntryKind, Vec<u8>)> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(entry) = self.fetch_entry(uid) {
                return Some(entry);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            self.wait_signal(deadline - now);
        }
    }

    /// Replicate a recovery checkpoint to every replica (the bytes are
    /// shared, so replication costs refcounts, not copies). Dead
    /// replicas are skipped — like result writes, the paper's
    /// weak-consistency model tolerates a replica missing an update.
    pub fn put_checkpoint(&self, uid: Uid, stage: u32, data: Arc<[u8]>) {
        for r in &self.replicas {
            if r.alive.load(Ordering::SeqCst) {
                r.db.put_checkpoint(uid, stage, data.clone());
            }
        }
    }

    /// Store `uid`'s terminal result on every live replica, zero-copy
    /// (shared refcount per replica). First-writer-wins per replica;
    /// returns true if **any** replica accepted the write — the same
    /// weak-consistency contract as [`DbClient::put_checkpoint`]. Used
    /// by the proxy's cache-hit admission path, which terminates a
    /// request by publishing the cached result directly.
    pub fn put_shared(&self, uid: Uid, data: Arc<[u8]>) -> bool {
        let mut stored = false;
        for r in &self.replicas {
            if r.alive.load(Ordering::SeqCst) {
                stored |= r.db.put_shared(uid, data.clone());
            }
        }
        stored
    }

    /// Read the newest live checkpoint for `uid` across replicas (the
    /// recovery sweep's fallback read path; replicas may have diverged
    /// if one missed a later stage's write).
    pub fn checkpoint(&self, uid: Uid) -> Option<Checkpoint> {
        self.replicas
            .iter()
            .filter(|r| r.alive.load(Ordering::SeqCst))
            .filter_map(|r| r.db.checkpoint(uid))
            .max_by_key(|c| c.stage)
    }

    /// Drop `uid`'s checkpoint on every replica (admission rolled back).
    pub fn remove_checkpoint(&self, uid: Uid) {
        for r in &self.replicas {
            r.db.remove_checkpoint(uid);
        }
    }

    /// Number of replicas.
    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    /// True when there are no replicas.
    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{ManualClock, NodeId};

    fn setup(n: usize) -> (Vec<Arc<MemDb>>, DbClient) {
        let clock = Arc::new(ManualClock::new());
        let dbs: Vec<Arc<MemDb>> = (0..n)
            .map(|_| Arc::new(MemDb::new(clock.clone(), 1_000_000)))
            .collect();
        let client = DbClient::new(dbs.clone());
        (dbs, client)
    }

    #[test]
    fn falls_through_to_replica() {
        let (dbs, client) = setup(3);
        let u = Uid::fresh(NodeId(0));
        // Result only reached the third replica (replication lag).
        dbs[2].put(u, b"late".to_vec());
        assert_eq!(client.fetch(u), Some(b"late".to_vec()));
    }

    #[test]
    fn dead_primary_served_by_backup() {
        let (dbs, client) = setup(2);
        let u = Uid::fresh(NodeId(0));
        dbs[0].put(u, b"r".to_vec());
        dbs[1].put(u, b"r".to_vec());
        client.set_alive(0, false);
        assert_eq!(client.fetch(u), Some(b"r".to_vec()));
    }

    #[test]
    fn all_missing_is_none() {
        let (_dbs, client) = setup(3);
        assert_eq!(client.fetch(Uid::fresh(NodeId(0))), None);
    }

    #[test]
    fn all_dead_is_none() {
        let (dbs, client) = setup(2);
        let u = Uid::fresh(NodeId(0));
        dbs[0].put(u, b"x".to_vec());
        client.set_alive(0, false);
        client.set_alive(1, false);
        assert_eq!(client.fetch(u), None);
    }

    #[test]
    fn fetch_entry_sees_tombstones() {
        let (dbs, client) = setup(2);
        let u = Uid::fresh(NodeId(0));
        dbs[0].put_tombstone(u, EntryKind::DeadlineExceeded);
        assert_eq!(client.fetch(u), None, "legacy fetch skips tombstones");
        assert_eq!(
            client.fetch_entry(u),
            Some((EntryKind::DeadlineExceeded, vec![]))
        );
    }

    #[test]
    fn put_shared_replicates_and_respects_first_writer() {
        let (dbs, client) = setup(2);
        let u = Uid::fresh(NodeId(0));
        assert!(client.put_shared(u, Arc::from(b"winner".to_vec())));
        assert!(!client.put_shared(u, Arc::from(b"loser".to_vec())));
        for db in &dbs {
            assert_eq!(db.fetch(u), Some(b"winner".to_vec()));
        }
    }

    #[test]
    fn wait_entry_blocks_until_put() {
        let (dbs, client) = setup(2);
        let client = Arc::new(client);
        let u = Uid::fresh(NodeId(0));
        let waiter = {
            let client = client.clone();
            std::thread::spawn(move || client.wait_entry(u, Duration::from_secs(5)))
        };
        std::thread::sleep(Duration::from_millis(30));
        dbs[0].put(u, b"late".to_vec());
        assert_eq!(
            waiter.join().unwrap(),
            Some((EntryKind::Result, b"late".to_vec()))
        );
    }

    #[test]
    fn wait_entry_times_out() {
        let (_dbs, client) = setup(1);
        let t0 = Instant::now();
        assert_eq!(
            client.wait_entry(Uid::fresh(NodeId(0)), Duration::from_millis(40)),
            None
        );
        assert!(t0.elapsed() >= Duration::from_millis(40));
    }
}
