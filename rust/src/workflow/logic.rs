//! Application logic (§4.4): "the specific execution behavior is defined
//! by user-provided code. When a request is received, the TaskWorker
//! invokes the corresponding user function based on an application
//! identity attached to the request data."
//!
//! [`I2vLogic`] is the Wan2.1-style image-to-video workflow over the four
//! PJRT stage executables; [`EchoLogic`] is a trivial logic for transport
//! and scheduling tests.

use crate::runtime::{StageExecutor, TensorValue};
use crate::transport::{Payload, WorkflowMessage};
use anyhow::{anyhow, Result};

/// Amortizable fraction of the per-request diffusion cost: the share of
/// a stage invocation spent on batch-invariant work (weight streaming,
/// kernel launch, context setup) rather than per-sample compute. 0.7
/// puts the full-batch speed-up near the ~3× regime micro-served
/// diffusion stages report from stage-local batching.
pub const I2V_BATCH_FIXED_FRAC: f64 = 0.7;

/// User-provided stage logic, dispatched by stage name.
pub trait AppLogic: Send + Sync {
    /// Execute one request at one stage; returns the next payload.
    fn execute(
        &self,
        stage_name: &str,
        exec: &StageExecutor,
        msg: &WorkflowMessage,
    ) -> Result<Payload>;

    /// Execute a micro-batch of compatible requests (same app, same
    /// stage) in one invocation, returning one result per member in
    /// order. The default loops [`AppLogic::execute`] — correct for any
    /// logic, amortizing nothing; logics whose stage cost has a
    /// batch-invariant component override this so batching buys
    /// throughput (see [`EchoLogic`] / [`I2vLogic`]). Per-member
    /// `Result`s keep one failing member from poisoning the batch.
    fn execute_batch(
        &self,
        stage_name: &str,
        exec: &StageExecutor,
        msgs: &[WorkflowMessage],
    ) -> Vec<Result<Payload>> {
        msgs.iter().map(|m| self.execute(stage_name, exec, m)).collect()
    }
}

/// Pass-through logic: runs the executor (for utilization realism) and
/// forwards the payload unchanged.
pub struct EchoLogic;

impl AppLogic for EchoLogic {
    fn execute(
        &self,
        _stage_name: &str,
        exec: &StageExecutor,
        msg: &WorkflowMessage,
    ) -> Result<Payload> {
        exec.run(&[])?;
        Ok(msg.payload.clone())
    }

    /// Echo's cost is pure per-invocation overhead — one executor run
    /// covers the whole batch and every member passes through.
    fn execute_batch(
        &self,
        _stage_name: &str,
        exec: &StageExecutor,
        msgs: &[WorkflowMessage],
    ) -> Vec<Result<Payload>> {
        let run = exec.run(&[]);
        msgs.iter()
            .map(|m| match &run {
                Ok(_) => Ok(m.payload.clone()),
                Err(e) => Err(anyhow!("batch execution failed: {e}")),
            })
            .collect()
    }
}

/// The image-to-video workflow (§2.4): text+image in, video out.
///
/// Stage payload contract (named tensors):
/// - entrance input: `tokens` `[SEQ_TEXT]` (f32-encoded ints) and
///   `image` `[H, W, C]`
/// - after `text_encoder`: + `ctx` `[SEQ_TEXT, D]`
/// - after `vae_encode`: + `img_lat` `[IMG_TOKENS, D_LAT]` (image dropped)
/// - after `diffusion`: `latent` `[VID_TOKENS, D_LAT]` (+ nothing else)
/// - after `vae_decode`: `video` `[F, H, W, C]`
pub struct I2vLogic {
    /// Diffusion Euler steps per request (the per-request hot loop).
    pub steps: usize,
    /// Latent geometry (from the artifact manifest).
    pub vid_tokens: usize,
    pub d_latent: usize,
}

impl I2vLogic {
    pub fn new(steps: usize, vid_tokens: usize, d_latent: usize) -> Self {
        Self { steps, vid_tokens, d_latent }
    }

    fn find<'a>(payload: &'a Payload, name: &str) -> Result<(&'a [u32], &'a [f32])> {
        match payload {
            Payload::Tensors(ts) => ts
                .iter()
                .find(|(n, _, _)| n == name)
                .map(|(_, s, d)| (s.as_slice(), d.as_slice()))
                .ok_or_else(|| anyhow!("missing tensor {name}")),
            _ => Err(anyhow!("expected named-tensor payload")),
        }
    }

    /// Deterministic per-request initial noise (seeded by the UID) so
    /// results are reproducible and workers never need an RNG service.
    fn initial_noise(&self, uid: u128) -> Vec<f32> {
        let mut rng = crate::util::Rng::new((uid as u64) ^ ((uid >> 64) as u64));
        (0..self.vid_tokens * self.d_latent)
            .map(|_| rng.gaussian() as f32)
            .collect()
    }
}

impl AppLogic for I2vLogic {
    fn execute(
        &self,
        stage_name: &str,
        exec: &StageExecutor,
        msg: &WorkflowMessage,
    ) -> Result<Payload> {
        // Simulated executors skip tensor plumbing (resource-scale runs).
        if let StageExecutor::Simulated { .. } = exec {
            exec.run(&[])?;
            return Ok(msg.payload.clone());
        }
        match stage_name {
            "text_encoder" => {
                let (shape, tok_f) = Self::find(&msg.payload, "tokens")?;
                let (img_shape, img) = Self::find(&msg.payload, "image")?;
                let tokens: Vec<i32> = tok_f.iter().map(|&x| x as i32).collect();
                let ctx = exec.run(&[TensorValue::I32(tokens)])?;
                Ok(Payload::Tensors(vec![
                    ("ctx".into(), vec![shape[0], ctx.len() as u32 / shape[0]], ctx),
                    ("image".into(), img_shape.to_vec(), img.to_vec()),
                ]))
            }
            "vae_encode" => {
                let (_, img) = Self::find(&msg.payload, "image")?;
                let (ctx_shape, ctx) = Self::find(&msg.payload, "ctx")?;
                let lat = exec.run(&[TensorValue::F32(img.to_vec())])?;
                let d = self.d_latent as u32;
                Ok(Payload::Tensors(vec![
                    ("ctx".into(), ctx_shape.to_vec(), ctx.to_vec()),
                    ("img_lat".into(), vec![lat.len() as u32 / d, d], lat),
                ]))
            }
            "diffusion" => {
                let (_, ctx) = Self::find(&msg.payload, "ctx")?;
                let (_, img_lat) = Self::find(&msg.payload, "img_lat")?;
                let mut x = self.initial_noise(msg.header.uid.0);
                let dt = 1.0 / self.steps as f32;
                // Euler loop stays in rust: one executable call per step,
                // matching the paper's per-step streaming through the
                // diffusion stage.
                for i in 0..self.steps {
                    let t = 1000.0 * (1.0 - i as f32 / self.steps as f32);
                    x = exec.run(&[
                        TensorValue::F32(x),
                        TensorValue::F32(vec![t]),
                        TensorValue::F32(vec![dt]),
                        TensorValue::F32(ctx.to_vec()),
                        TensorValue::F32(img_lat.to_vec()),
                    ])?;
                }
                Ok(Payload::Tensors(vec![(
                    "latent".into(),
                    vec![self.vid_tokens as u32, self.d_latent as u32],
                    x,
                )]))
            }
            "vae_decode" => {
                let (_, latent) = Self::find(&msg.payload, "latent")?;
                let video = exec.run(&[TensorValue::F32(latent.to_vec())])?;
                Ok(Payload::Tensors(vec![(
                    "video".into(),
                    vec![video.len() as u32],
                    video,
                )]))
            }
            other => Err(anyhow!("i2v logic has no stage {other}")),
        }
    }

    /// Amortized batch execution under the simulated cost model: one
    /// invocation pays the batch-invariant [`I2V_BATCH_FIXED_FRAC`] of
    /// the stage cost once and the per-sample remainder per member. PJRT
    /// artifacts are traced at batch = 1 (per-request tensor shapes), so
    /// real-compute runs fall back to the sequential default.
    fn execute_batch(
        &self,
        stage_name: &str,
        exec: &StageExecutor,
        msgs: &[WorkflowMessage],
    ) -> Vec<Result<Payload>> {
        if exec.is_simulated() {
            let run = exec.run_amortized(msgs.len(), I2V_BATCH_FIXED_FRAC);
            return msgs
                .iter()
                .map(|m| match &run {
                    Ok(_) => Ok(m.payload.clone()),
                    Err(e) => Err(anyhow!("batch execution failed: {e}")),
                })
                .collect();
        }
        msgs.iter().map(|m| self.execute(stage_name, exec, m)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{AppId, MessageHeader, StageId};
    use crate::util::{NodeId, Uid};
    use std::time::Duration;

    fn msg(payload: Payload) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(42),
                ts_ns: 0,
                app: AppId(1),
                stage: StageId(0),
                origin: NodeId(0),
            },
            payload,
        }
    }

    #[test]
    fn echo_passes_through() {
        let logic = EchoLogic;
        let m = msg(Payload::Bytes(vec![1, 2, 3]));
        let exec = StageExecutor::Simulated { busy: Duration::ZERO };
        assert_eq!(logic.execute("any", &exec, &m).unwrap(), m.payload);
    }

    #[test]
    fn echo_batch_amortizes_to_one_invocation() {
        let logic = EchoLogic;
        let exec = StageExecutor::Simulated { busy: Duration::from_millis(5) };
        let msgs: Vec<WorkflowMessage> =
            (0..4).map(|i| msg(Payload::Bytes(vec![i]))).collect();
        let t0 = std::time::Instant::now();
        let results = logic.execute_batch("any", &exec, &msgs);
        let d = t0.elapsed();
        assert!(d >= Duration::from_millis(5) && d < Duration::from_millis(20));
        assert_eq!(results.len(), 4);
        for (r, m) in results.iter().zip(&msgs) {
            assert_eq!(r.as_ref().unwrap(), &m.payload);
        }
    }

    #[test]
    fn i2v_batch_amortizes_on_simulated_executor() {
        let logic = I2vLogic::new(4, 8, 2);
        let exec = StageExecutor::Simulated { busy: Duration::from_millis(4) };
        let msgs: Vec<WorkflowMessage> =
            (0..8).map(|i| msg(Payload::Bytes(vec![i]))).collect();
        let t0 = std::time::Instant::now();
        let results = logic.execute_batch("diffusion", &exec, &msgs);
        let d = t0.elapsed();
        // 4 ms × (0.7 + 0.3×8) = 12.4 ms, vs 32 ms sequential.
        assert!(d >= Duration::from_micros(12_000), "{d:?}");
        assert!(d < Duration::from_millis(32), "{d:?}");
        assert!(results.iter().all(|r| r.is_ok()));
    }

    #[test]
    fn default_execute_batch_loops_sequentially() {
        // A logic without an override pays the per-request cost n times.
        struct Plain;
        impl AppLogic for Plain {
            fn execute(
                &self,
                _s: &str,
                exec: &StageExecutor,
                msg: &WorkflowMessage,
            ) -> Result<Payload> {
                exec.run(&[])?;
                Ok(msg.payload.clone())
            }
        }
        let exec = StageExecutor::Simulated { busy: Duration::from_millis(3) };
        let msgs: Vec<WorkflowMessage> =
            (0..3).map(|i| msg(Payload::Bytes(vec![i]))).collect();
        let t0 = std::time::Instant::now();
        let results = Plain.execute_batch("any", &exec, &msgs);
        assert!(t0.elapsed() >= Duration::from_millis(9), "3 sequential runs");
        assert_eq!(results.len(), 3);
    }

    #[test]
    fn i2v_noise_is_deterministic_per_uid() {
        let logic = I2vLogic::new(4, 8, 2);
        assert_eq!(logic.initial_noise(7), logic.initial_noise(7));
        assert_ne!(logic.initial_noise(7), logic.initial_noise(8));
    }

    #[test]
    fn i2v_missing_tensor_is_error() {
        let logic = I2vLogic::new(4, 8, 2);
        let exec = StageExecutor::Simulated { busy: Duration::ZERO };
        // Simulated executors pass through, so use a Pjrt-shaped check via
        // the find() contract directly.
        let m = msg(Payload::Bytes(vec![]));
        assert!(I2vLogic::find(&m.payload, "tokens").is_err());
        // Simulated executor still succeeds (pass-through).
        assert!(logic.execute("text_encoder", &exec, &m).is_ok());
    }

    #[test]
    fn i2v_unknown_stage_rejected() {
        let _logic = I2vLogic::new(1, 1, 1);
        // Needs a real executor shape to hit the match arm; simulated
        // short-circuits, so check via a Pjrt variant is impossible here —
        // instead verify find() of the dispatch path:
        let m = msg(Payload::Tensors(vec![]));
        assert!(I2vLogic::find(&m.payload, "nope").is_err());
    }
}
