//! Application logic (§4.4): "the specific execution behavior is defined
//! by user-provided code. When a request is received, the TaskWorker
//! invokes the corresponding user function based on an application
//! identity attached to the request data."
//!
//! [`I2vLogic`] is the Wan2.1-style image-to-video workflow over the four
//! PJRT stage executables; [`EchoLogic`] is a trivial logic for transport
//! and scheduling tests.

use crate::runtime::{StageExecutor, TensorValue};
use crate::transport::{Payload, WorkflowMessage};
use anyhow::{anyhow, Result};

/// User-provided stage logic, dispatched by stage name.
pub trait AppLogic: Send + Sync {
    /// Execute one request at one stage; returns the next payload.
    fn execute(
        &self,
        stage_name: &str,
        exec: &StageExecutor,
        msg: &WorkflowMessage,
    ) -> Result<Payload>;
}

/// Pass-through logic: runs the executor (for utilization realism) and
/// forwards the payload unchanged.
pub struct EchoLogic;

impl AppLogic for EchoLogic {
    fn execute(
        &self,
        _stage_name: &str,
        exec: &StageExecutor,
        msg: &WorkflowMessage,
    ) -> Result<Payload> {
        exec.run(&[])?;
        Ok(msg.payload.clone())
    }
}

/// The image-to-video workflow (§2.4): text+image in, video out.
///
/// Stage payload contract (named tensors):
/// - entrance input: `tokens` `[SEQ_TEXT]` (f32-encoded ints) and
///   `image` `[H, W, C]`
/// - after `text_encoder`: + `ctx` `[SEQ_TEXT, D]`
/// - after `vae_encode`: + `img_lat` `[IMG_TOKENS, D_LAT]` (image dropped)
/// - after `diffusion`: `latent` `[VID_TOKENS, D_LAT]` (+ nothing else)
/// - after `vae_decode`: `video` `[F, H, W, C]`
pub struct I2vLogic {
    /// Diffusion Euler steps per request (the per-request hot loop).
    pub steps: usize,
    /// Latent geometry (from the artifact manifest).
    pub vid_tokens: usize,
    pub d_latent: usize,
}

impl I2vLogic {
    pub fn new(steps: usize, vid_tokens: usize, d_latent: usize) -> Self {
        Self { steps, vid_tokens, d_latent }
    }

    fn find<'a>(payload: &'a Payload, name: &str) -> Result<(&'a [u32], &'a [f32])> {
        match payload {
            Payload::Tensors(ts) => ts
                .iter()
                .find(|(n, _, _)| n == name)
                .map(|(_, s, d)| (s.as_slice(), d.as_slice()))
                .ok_or_else(|| anyhow!("missing tensor {name}")),
            _ => Err(anyhow!("expected named-tensor payload")),
        }
    }

    /// Deterministic per-request initial noise (seeded by the UID) so
    /// results are reproducible and workers never need an RNG service.
    fn initial_noise(&self, uid: u128) -> Vec<f32> {
        let mut rng = crate::util::Rng::new((uid as u64) ^ ((uid >> 64) as u64));
        (0..self.vid_tokens * self.d_latent)
            .map(|_| rng.gaussian() as f32)
            .collect()
    }
}

impl AppLogic for I2vLogic {
    fn execute(
        &self,
        stage_name: &str,
        exec: &StageExecutor,
        msg: &WorkflowMessage,
    ) -> Result<Payload> {
        // Simulated executors skip tensor plumbing (resource-scale runs).
        if let StageExecutor::Simulated { .. } = exec {
            exec.run(&[])?;
            return Ok(msg.payload.clone());
        }
        match stage_name {
            "text_encoder" => {
                let (shape, tok_f) = Self::find(&msg.payload, "tokens")?;
                let (img_shape, img) = Self::find(&msg.payload, "image")?;
                let tokens: Vec<i32> = tok_f.iter().map(|&x| x as i32).collect();
                let ctx = exec.run(&[TensorValue::I32(tokens)])?;
                Ok(Payload::Tensors(vec![
                    ("ctx".into(), vec![shape[0], ctx.len() as u32 / shape[0]], ctx),
                    ("image".into(), img_shape.to_vec(), img.to_vec()),
                ]))
            }
            "vae_encode" => {
                let (_, img) = Self::find(&msg.payload, "image")?;
                let (ctx_shape, ctx) = Self::find(&msg.payload, "ctx")?;
                let lat = exec.run(&[TensorValue::F32(img.to_vec())])?;
                let d = self.d_latent as u32;
                Ok(Payload::Tensors(vec![
                    ("ctx".into(), ctx_shape.to_vec(), ctx.to_vec()),
                    ("img_lat".into(), vec![lat.len() as u32 / d, d], lat),
                ]))
            }
            "diffusion" => {
                let (_, ctx) = Self::find(&msg.payload, "ctx")?;
                let (_, img_lat) = Self::find(&msg.payload, "img_lat")?;
                let mut x = self.initial_noise(msg.header.uid.0);
                let dt = 1.0 / self.steps as f32;
                // Euler loop stays in rust: one executable call per step,
                // matching the paper's per-step streaming through the
                // diffusion stage.
                for i in 0..self.steps {
                    let t = 1000.0 * (1.0 - i as f32 / self.steps as f32);
                    x = exec.run(&[
                        TensorValue::F32(x),
                        TensorValue::F32(vec![t]),
                        TensorValue::F32(vec![dt]),
                        TensorValue::F32(ctx.to_vec()),
                        TensorValue::F32(img_lat.to_vec()),
                    ])?;
                }
                Ok(Payload::Tensors(vec![(
                    "latent".into(),
                    vec![self.vid_tokens as u32, self.d_latent as u32],
                    x,
                )]))
            }
            "vae_decode" => {
                let (_, latent) = Self::find(&msg.payload, "latent")?;
                let video = exec.run(&[TensorValue::F32(latent.to_vec())])?;
                Ok(Payload::Tensors(vec![(
                    "video".into(),
                    vec![video.len() as u32],
                    video,
                )]))
            }
            other => Err(anyhow!("i2v logic has no stage {other}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{AppId, MessageHeader, StageId};
    use crate::util::{NodeId, Uid};
    use std::time::Duration;

    fn msg(payload: Payload) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(42),
                ts_ns: 0,
                app: AppId(1),
                stage: StageId(0),
                origin: NodeId(0),
            },
            payload,
        }
    }

    #[test]
    fn echo_passes_through() {
        let logic = EchoLogic;
        let m = msg(Payload::Bytes(vec![1, 2, 3]));
        let exec = StageExecutor::Simulated { busy: Duration::ZERO };
        assert_eq!(logic.execute("any", &exec, &m).unwrap(), m.payload);
    }

    #[test]
    fn i2v_noise_is_deterministic_per_uid() {
        let logic = I2vLogic::new(4, 8, 2);
        assert_eq!(logic.initial_noise(7), logic.initial_noise(7));
        assert_ne!(logic.initial_noise(7), logic.initial_noise(8));
    }

    #[test]
    fn i2v_missing_tensor_is_error() {
        let logic = I2vLogic::new(4, 8, 2);
        let exec = StageExecutor::Simulated { busy: Duration::ZERO };
        // Simulated executors pass through, so use a Pjrt-shaped check via
        // the find() contract directly.
        let m = msg(Payload::Bytes(vec![]));
        assert!(I2vLogic::find(&m.payload, "tokens").is_err());
        // Simulated executor still succeeds (pass-through).
        assert!(logic.execute("text_encoder", &exec, &m).is_ok());
    }

    #[test]
    fn i2v_unknown_stage_rejected() {
        let _logic = I2vLogic::new(1, 1, 1);
        // Needs a real executor shape to hit the match arm; simulated
        // short-circuits, so check via a Pjrt variant is impossible here —
        // instead verify find() of the dispatch path:
        let m = msg(Payload::Tensors(vec![]));
        assert!(I2vLogic::find(&m.payload, "nope").is_err());
    }
}
