//! ResultDeliver (§4.5): routes stage outputs to the next hop.
//!
//! "RD obtains routing information from the TaskManager ... Since a
//! single instance may participate in multiple workflows, RD uses the
//! application identity included in the request to determine the
//! appropriate next hop. When multiple destination instances are
//! available, RD uses a round-robin mechanism."

use crate::db::{EntryKind, MemDb};
use crate::rdma::{Fabric, RegionId};
use crate::transport::{RdmaEndpoint, WorkflowMessage};
use crate::util::Uid;
use std::collections::HashMap;
use std::sync::Arc;

/// A delivery destination.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NextHop {
    /// Another instance's ring-buffer region.
    Instance(RegionId),
    /// Final stage: persist into the database layer.
    Database,
}

/// What happened to one delivered message. `Sent` carries the chosen
/// ring region so the caller can record the request's location with the
/// control plane (the recovery sweep finds stranded requests by it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Delivery {
    /// Forwarded into an instance's ring.
    Sent(RegionId),
    /// Final stage: persisted to the database layer.
    Stored,
    /// No route / ring refused the write.
    Dropped,
}

impl Delivery {
    /// True unless the message was dropped.
    pub fn ok(self) -> bool {
        !matches!(self, Delivery::Dropped)
    }
}

/// Result router for one instance. Routes are **per application** — a
/// shared instance (§8.3) serves several workflows whose next stages
/// differ, so RD keys the hop list by the message's app id.
pub struct ResultDeliver {
    fabric: Fabric,
    routes: HashMap<crate::transport::AppId, Vec<NextHop>>,
    senders: HashMap<RegionId, crate::transport::RdmaSender>,
    dbs: Vec<Arc<MemDb>>,
    rr: HashMap<crate::transport::AppId, usize>,
    /// Write per-hop recovery checkpoints (off by default, like the
    /// failure detector that replays them — disabled deployments pay
    /// zero encode/replication overhead).
    checkpointing: bool,
    /// Ring-path instrumentation handed to every sender (set registry
    /// counters; None until the owning instance wires its registry in).
    metrics: Option<crate::transport::RingMetrics>,
    /// Eager/rendezvous cutover applied to every sender
    /// (`rdma.rendezvous_threshold_bytes`; 0 = eager only).
    rendezvous_threshold: usize,
    /// Artifact cache to seed with full-workflow terminals (None when the
    /// deployment has no `cache` block — the store path is unchanged).
    cache: Option<Arc<crate::cache::ArtifactCache>>,
    /// Tracing hook from the owning instance (None = tracing off; every
    /// record site is a skipped `if let`).
    trace: Option<crate::trace::TraceHook>,
    delivered: u64,
    dropped: u64,
}

impl ResultDeliver {
    pub fn new(fabric: Fabric, dbs: Vec<Arc<MemDb>>) -> Self {
        Self {
            fabric,
            routes: HashMap::new(),
            senders: HashMap::new(),
            dbs,
            rr: HashMap::new(),
            checkpointing: false,
            metrics: None,
            rendezvous_threshold: 0,
            cache: None,
            trace: None,
            delivered: 0,
            dropped: 0,
        }
    }

    /// Attach the owning instance's tracing hook: downstream ring pushes
    /// and recovery checkpoints record into its flight recorder.
    pub fn set_trace(&mut self, trace: crate::trace::TraceHook) {
        self.trace = Some(trace);
    }

    /// Record one trace event when tracing is on; free when it is off.
    #[inline]
    fn trace(&self, uid: Uid, stage: Option<u32>, kind: crate::trace::EventKind) {
        if let Some(t) = &self.trace {
            t.record(uid, stage, kind);
        }
    }

    /// Attach the set's artifact cache: terminal stores will seed its
    /// full-workflow tier (the bytes are already shared for replication,
    /// so the seed is a refcount, not a copy).
    pub fn set_cache(&mut self, cache: Arc<crate::cache::ArtifactCache>) {
        self.cache = Some(cache);
    }

    /// Enable/disable per-hop recovery checkpoints (the wset wires this
    /// to `nm.instance_timeout_ms > 0`).
    pub fn set_checkpointing(&mut self, on: bool) {
        self.checkpointing = on;
    }

    /// Attach ring-path metrics (`ring_pushes_total` / `ring_verbs_total`
    /// / …) to every current and future sender this router owns.
    pub fn set_metrics(&mut self, metrics: crate::transport::RingMetrics) {
        for tx in self.senders.values_mut() {
            tx.set_metrics(metrics.clone());
        }
        self.metrics = Some(metrics);
    }

    /// Set the eager/rendezvous cutover on every current and future ring
    /// sender this router owns (`rdma.rendezvous_threshold_bytes`;
    /// 0 disables the rendezvous path).
    pub fn set_rendezvous_threshold(&mut self, bytes: usize) {
        self.rendezvous_threshold = bytes;
        for tx in self.senders.values_mut() {
            tx.set_rendezvous_threshold(bytes);
        }
    }

    /// Install per-app routing from a (re)assignment. Senders for
    /// regions still referenced are kept (connection reuse); senders for
    /// regions no route mentions any more are **pruned** — a retired or
    /// dead instance must not keep a ring producer alive forever.
    /// Per-app round-robin counters survive the update (an NM
    /// reassignment must not skew load back onto each app's first hop);
    /// counters for apps no longer routed are dropped.
    pub fn set_routes(&mut self, routes: Vec<(crate::transport::AppId, Vec<NextHop>)>) {
        let threshold = self.rendezvous_threshold;
        for (_, hops) in &routes {
            for hop in hops {
                if let NextHop::Instance(rid) = hop {
                    if self.senders.contains_key(rid) {
                        continue;
                    }
                    // Producers only need the region id; geometry is
                    // read from the ring header. A region that vanished
                    // between the NM building this assignment and us
                    // applying it (instance died mid-update) is skipped:
                    // deliveries to it count as drops until the next
                    // route repair replaces the hop.
                    let Ok(mut tx) = RdmaEndpoint::sender_for(&self.fabric, *rid) else {
                        continue;
                    };
                    if let Some(m) = &self.metrics {
                        tx.set_metrics(m.clone());
                    }
                    tx.set_rendezvous_threshold(threshold);
                    self.senders.insert(*rid, tx);
                }
            }
        }
        self.routes = routes.into_iter().collect();
        let routes = &self.routes;
        self.senders.retain(|rid, _| {
            routes
                .values()
                .any(|hops| hops.contains(&NextHop::Instance(*rid)))
        });
        self.rr.retain(|app, _| routes.contains_key(app));
    }

    /// Hop list for an app (tests).
    pub fn hops(&self, app: crate::transport::AppId) -> Option<&[NextHop]> {
        self.routes.get(&app).map(Vec::as_slice)
    }

    /// Deliver one result message. Round-robin across the app's instance
    /// hops; DB hops write to every replica ("data is automatically
    /// replicated across multiple database instances", §3.4).
    ///
    /// An instance hop doubles as a **stage-completion checkpoint**: the
    /// forwarded message (the last completed stage's output, stamped
    /// with the stage it is entering) is written to the database layer
    /// so the recovery sweep can replay it if the receiving instance
    /// dies (§ worker fault tolerance). The encode happens once; the
    /// replicas share the buffer.
    pub fn deliver(&mut self, msg: &WorkflowMessage) -> Delivery {
        match self.pick_hop(msg.header.app) {
            Some(hop) => self.deliver_to(&hop, msg),
            None => {
                self.dropped += 1;
                Delivery::Dropped
            }
        }
    }

    /// Coalesced delivery for a micro-batch's results: **one** hop
    /// choice per app for the whole batch (the round-robin counter
    /// advances once, so the batch lands on a single downstream ring and
    /// stays batchable there), and every member bound for the same ring
    /// crosses the fabric as **one** batched push
    /// ([`crate::transport::RdmaSender::send_batch`]) — one lock
    /// acquisition for the group instead of one per member. Per-UID
    /// recovery checkpoints and the database layer's first-writer-wins
    /// terminals are preserved; a ring that fills mid-batch accepts a
    /// prefix and the rest report [`Delivery::Dropped`], which the
    /// worker strands into the recovery path. A batch of one is
    /// byte-identical to the single-message [`ResultDeliver::deliver`]
    /// ring protocol. Returns one [`Delivery`] per input, in order.
    pub fn deliver_batch(&mut self, msgs: &[WorkflowMessage]) -> Vec<Delivery> {
        let mut chosen: HashMap<crate::transport::AppId, Option<NextHop>> = HashMap::new();
        let mut out = vec![Delivery::Dropped; msgs.len()];
        // Same-ring members keep their relative order inside one group
        // (per-sender FIFO is preserved through the batched push).
        let mut groups: Vec<(RegionId, Vec<usize>)> = Vec::new();
        for (idx, msg) in msgs.iter().enumerate() {
            let app = msg.header.app;
            let hop = chosen
                .entry(app)
                .or_insert_with(|| self.pick_hop(app))
                .clone();
            match hop {
                None => {
                    self.dropped += 1;
                }
                Some(NextHop::Database) => {
                    self.store(msg.header.uid, msg.encode());
                    self.delivered += 1;
                    out[idx] = Delivery::Stored;
                }
                Some(NextHop::Instance(rid)) => {
                    match groups.iter_mut().find(|(r, _)| *r == rid) {
                        Some((_, idxs)) => idxs.push(idx),
                        None => groups.push((rid, vec![idx])),
                    }
                }
            }
        }
        for (rid, idxs) in groups {
            let ckpt = self.checkpointing && !self.dbs.is_empty();
            // A route without a live producer (its region vanished
            // before set_routes could connect) drops the whole group —
            // same observable outcome as a dead ring, and the route
            // repair path replaces the hop.
            let Some(tx) = self.senders.get_mut(&rid) else {
                self.dropped += idxs.len() as u64;
                continue;
            };
            // Encode each member once (the Arc wrap for checkpoint
            // sharing is deferred to the accepted members, so the
            // checkpointing-off path pays no extra copy). A member that
            // can *never* fit the ring is dropped up front — it must
            // not head-of-line block its deliverable batchmates.
            let mut encoded: Vec<Vec<u8>> = Vec::with_capacity(idxs.len());
            let mut sendable: Vec<usize> = Vec::with_capacity(idxs.len());
            for &i in &idxs {
                let bytes = msgs[i].encode();
                if tx.accepts(bytes.len()) {
                    encoded.push(bytes);
                    sendable.push(i);
                } else {
                    self.dropped += 1;
                }
            }
            let frames: Vec<&[u8]> = encoded.iter().map(Vec::as_slice).collect();
            let accepted = tx.send_batch(&frames);
            drop(frames);
            for (k, &i) in sendable.iter().enumerate() {
                if k < accepted {
                    self.trace(
                        msgs[i].header.uid,
                        Some(msgs[i].header.stage.0),
                        crate::trace::EventKind::RingPush,
                    );
                    if ckpt {
                        let bytes: Arc<[u8]> = std::mem::take(&mut encoded[k]).into();
                        for db in &self.dbs {
                            db.put_checkpoint(
                                msgs[i].header.uid,
                                msgs[i].header.stage.0,
                                bytes.clone(),
                            );
                        }
                        self.trace(
                            msgs[i].header.uid,
                            Some(msgs[i].header.stage.0),
                            crate::trace::EventKind::Checkpoint,
                        );
                    }
                    self.delivered += 1;
                    out[i] = Delivery::Sent(rid);
                } else {
                    self.dropped += 1;
                }
            }
        }
        out
    }

    /// Choose the next hop for `app`, advancing its round-robin counter
    /// (None = no route / empty hop list; the caller accounts the drop).
    fn pick_hop(&mut self, app: crate::transport::AppId) -> Option<NextHop> {
        let hops = self.routes.get(&app)?;
        if hops.is_empty() {
            return None;
        }
        let rr = self.rr.entry(app).or_insert(0);
        let hop = hops[*rr % hops.len()].clone();
        *rr = rr.wrapping_add(1);
        Some(hop)
    }

    /// Push one message to an already-chosen hop, writing the recovery
    /// checkpoint (when enabled) and counting the outcome.
    fn deliver_to(&mut self, hop: &NextHop, msg: &WorkflowMessage) -> Delivery {
        let outcome = match hop {
            NextHop::Instance(rid) => {
                let rid = *rid;
                let ckpt = self.checkpointing && !self.dbs.is_empty();
                // No producer for the hop (region vanished before a
                // sender could connect): drop, as for a dead ring.
                let Some(tx) = self.senders.get_mut(&rid) else {
                    self.dropped += 1;
                    return Delivery::Dropped;
                };
                if ckpt {
                    // Encode once; the ring push and every replica's
                    // checkpoint share the same buffer.
                    let bytes: Arc<[u8]> = msg.encode().into();
                    if tx.send_encoded(&bytes) {
                        self.trace(
                            msg.header.uid,
                            Some(msg.header.stage.0),
                            crate::trace::EventKind::RingPush,
                        );
                        for db in &self.dbs {
                            db.put_checkpoint(
                                msg.header.uid,
                                msg.header.stage.0,
                                bytes.clone(),
                            );
                        }
                        self.trace(
                            msg.header.uid,
                            Some(msg.header.stage.0),
                            crate::trace::EventKind::Checkpoint,
                        );
                        Delivery::Sent(rid)
                    } else {
                        Delivery::Dropped
                    }
                } else if tx.send(msg) {
                    self.trace(
                        msg.header.uid,
                        Some(msg.header.stage.0),
                        crate::trace::EventKind::RingPush,
                    );
                    Delivery::Sent(rid)
                } else {
                    Delivery::Dropped
                }
            }
            NextHop::Database => {
                self.store(msg.header.uid, msg.encode());
                Delivery::Stored
            }
        };
        if outcome.ok() {
            self.delivered += 1;
        } else {
            self.dropped += 1;
        }
        outcome
    }

    /// Replicate a final result: encode once, stage the bytes into one
    /// shared buffer (the single staging copy, charged to
    /// `payload_bytes_copied_total`), and fan the N replica writes out
    /// as refcounts of that buffer — replication cost is independent of
    /// payload size past the one staging.
    fn store(&self, uid: Uid, bytes: Vec<u8>) {
        if self.dbs.is_empty() {
            return;
        }
        if let Some(m) = &self.metrics {
            m.payload_bytes_copied.add(bytes.len() as u64);
        }
        let shared: Arc<[u8]> = bytes.into();
        if let Some(c) = &self.cache {
            // Seed the full-workflow admission tier. The cache looked the
            // key up at admission and only *noted* misses, so a request
            // that was cancelled or deadline-dropped upstream never gets
            // here and can never poison the cache; fills are
            // first-writer-wins like the replica writes below.
            c.complete_workflow(uid, &shared);
        }
        for db in &self.dbs {
            db.put_shared(uid, shared.clone());
        }
    }

    /// Publish a terminal tombstone for a dropped request (deadline
    /// exceeded / cancelled) to every DB replica — the data-plane half of
    /// the unified lifecycle: result readers observe the same terminal
    /// state the control plane decided, instead of waiting forever.
    pub fn tombstone(&self, uid: Uid, kind: EntryKind) {
        for db in &self.dbs {
            db.put_tombstone(uid, kind);
        }
    }

    /// (delivered, dropped) counters.
    pub fn counts(&self) -> (u64, u64) {
        (self.delivered, self.dropped)
    }

    /// Number of live ring producers (tests: sender pruning).
    pub fn sender_count(&self) -> usize {
        self.senders.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ringbuf::RingConfig;
    use crate::transport::{AppId, MessageHeader, Payload, StageId};
    use crate::util::{ManualClock, NodeId};

    fn msg(i: u32) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(i as u128),
                ts_ns: 0,
                app: AppId(1),
                stage: StageId(1),
                origin: NodeId(0),
            },
            payload: Payload::Bytes(vec![i as u8; 16]),
        }
    }

    #[test]
    fn round_robin_across_instances() {
        let fabric = Fabric::ideal();
        let mut ep1 = RdmaEndpoint::new(&fabric, RingConfig::default());
        let mut ep2 = RdmaEndpoint::new(&fabric, RingConfig::default());
        let mut rd = ResultDeliver::new(fabric.clone(), vec![]);
        rd.set_routes(vec![(
            AppId(1),
            vec![
                NextHop::Instance(ep1.region_id()),
                NextHop::Instance(ep2.region_id()),
            ],
        )]);
        for i in 0..6 {
            assert!(rd.deliver(&msg(i)).ok());
        }
        let mut n1 = 0;
        while ep1.recv().is_some() {
            n1 += 1;
        }
        let mut n2 = 0;
        while ep2.recv().is_some() {
            n2 += 1;
        }
        assert_eq!((n1, n2), (3, 3), "round robin must balance");
    }

    #[test]
    fn database_hop_replicates() {
        let fabric = Fabric::ideal();
        let clock = Arc::new(ManualClock::new());
        let dbs: Vec<Arc<MemDb>> = (0..2)
            .map(|_| Arc::new(MemDb::new(clock.clone(), u64::MAX)))
            .collect();
        let mut rd = ResultDeliver::new(fabric, dbs.clone());
        rd.set_routes(vec![(AppId(1), vec![NextHop::Database])]);
        let m = msg(9);
        assert_eq!(rd.deliver(&m), Delivery::Stored);
        for db in &dbs {
            let stored = db.fetch(m.header.uid).unwrap();
            assert_eq!(WorkflowMessage::decode(&stored).unwrap(), m);
        }
    }

    #[test]
    fn store_fans_out_one_staging_copy_for_n_replicas() {
        let fabric = Fabric::ideal();
        let clock = Arc::new(ManualClock::new());
        let dbs: Vec<Arc<MemDb>> = (0..3)
            .map(|_| Arc::new(MemDb::new(clock.clone(), u64::MAX)))
            .collect();
        let reg = crate::metrics::Registry::new();
        let mut rd = ResultDeliver::new(fabric, dbs.clone());
        rd.set_metrics(crate::transport::RingMetrics::from_registry(&reg));
        rd.set_routes(vec![(AppId(1), vec![NextHop::Database])]);
        let m = msg(4);
        let enc_len = m.encode().len() as u64;
        assert_eq!(rd.deliver(&m), Delivery::Stored);
        assert_eq!(
            reg.counter("payload_bytes_copied_total").get(),
            enc_len,
            "one encode + one staging copy serve all three replicas"
        );
        let a = dbs[0].peek(m.header.uid).unwrap();
        let b = dbs[1].peek(m.header.uid).unwrap();
        assert!(
            std::ptr::eq(a.data.as_ref(), b.data.as_ref()),
            "replicas hold refcounts of one buffer, not copies"
        );
        for db in &dbs {
            let stored = db.fetch(m.header.uid).unwrap();
            assert_eq!(WorkflowMessage::decode(&stored).unwrap(), m);
        }
    }

    #[test]
    fn rendezvous_threshold_applies_to_lazily_built_senders() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        let reg = crate::metrics::Registry::new();
        let handles = crate::transport::RingMetrics::from_registry(&reg);
        ep.set_metrics(handles.clone());
        let mut rd = ResultDeliver::new(fabric.clone(), vec![]);
        rd.set_metrics(handles);
        rd.set_rendezvous_threshold(256);
        // The sender is built lazily inside set_routes — it must still
        // inherit the cutover.
        rd.set_routes(vec![(AppId(1), vec![NextHop::Instance(ep.region_id())])]);
        let mut big = msg(1);
        big.payload = Payload::Bytes(vec![5u8; 4096]);
        assert!(rd.deliver(&big).ok());
        assert_eq!(ep.recv().unwrap(), big);
        assert_eq!(
            reg.counter("rendezvous_reads_total").get(),
            1,
            "the large message crossed by descriptor, not inline"
        );
    }

    #[test]
    fn tombstone_reaches_every_replica() {
        let fabric = Fabric::ideal();
        let clock = Arc::new(ManualClock::new());
        let dbs: Vec<Arc<MemDb>> = (0..2)
            .map(|_| Arc::new(MemDb::new(clock.clone(), u64::MAX)))
            .collect();
        let rd = ResultDeliver::new(fabric, dbs.clone());
        let u = Uid(77);
        rd.tombstone(u, EntryKind::DeadlineExceeded);
        for db in &dbs {
            assert_eq!(db.fetch_entry(u), Some((EntryKind::DeadlineExceeded, vec![])));
        }
    }

    #[test]
    fn set_routes_prunes_retired_senders() {
        let fabric = Fabric::ideal();
        let mut ep1 = RdmaEndpoint::new(&fabric, RingConfig::default());
        let ep2 = RdmaEndpoint::new(&fabric, RingConfig::default());
        let mut rd = ResultDeliver::new(fabric.clone(), vec![]);
        rd.set_routes(vec![(
            AppId(1),
            vec![
                NextHop::Instance(ep1.region_id()),
                NextHop::Instance(ep2.region_id()),
            ],
        )]);
        assert_eq!(rd.sender_count(), 2);
        // The NM evicts ep2's instance: the reassignment no longer
        // references its region, so its producer must be dropped.
        rd.set_routes(vec![(AppId(1), vec![NextHop::Instance(ep1.region_id())])]);
        assert_eq!(rd.sender_count(), 1, "dead region's producer pruned");
        assert!(rd.deliver(&msg(0)).ok());
        assert!(ep1.recv().is_some());
    }

    #[test]
    fn round_robin_survives_route_updates() {
        let fabric = Fabric::ideal();
        let mut ep1 = RdmaEndpoint::new(&fabric, RingConfig::default());
        let mut ep2 = RdmaEndpoint::new(&fabric, RingConfig::default());
        let routes = || {
            vec![(
                AppId(1),
                vec![
                    NextHop::Instance(ep1.region_id()),
                    NextHop::Instance(ep2.region_id()),
                ],
            )]
        };
        let mut rd = ResultDeliver::new(fabric.clone(), vec![]);
        rd.set_routes(routes());
        // One delivery lands on ep1; an NM reassignment (same hops) must
        // not reset the counter back onto ep1.
        assert_eq!(rd.deliver(&msg(0)), Delivery::Sent(ep1.region_id()));
        rd.set_routes(routes());
        assert_eq!(rd.deliver(&msg(1)), Delivery::Sent(ep2.region_id()));
        assert!(ep1.recv().is_some());
        assert!(ep2.recv().is_some());
        // Counters for apps that lost all routes are dropped.
        rd.set_routes(vec![]);
        assert_eq!(rd.deliver(&msg(2)), Delivery::Dropped);
    }

    #[test]
    fn instance_hop_writes_recovery_checkpoint() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        let clock = Arc::new(ManualClock::new());
        let dbs: Vec<Arc<MemDb>> = (0..2)
            .map(|_| Arc::new(MemDb::new(clock.clone(), u64::MAX)))
            .collect();
        let mut rd = ResultDeliver::new(fabric.clone(), dbs.clone());
        rd.set_checkpointing(true);
        rd.set_routes(vec![(AppId(1), vec![NextHop::Instance(ep.region_id())])]);
        let m = msg(5); // header.stage = 1: entering stage 1
        assert!(rd.deliver(&m).ok());
        for db in &dbs {
            let ck = db.checkpoint(m.header.uid).expect("checkpoint on every replica");
            assert_eq!(ck.stage, 1);
            assert_eq!(
                WorkflowMessage::decode(&ck.data).unwrap(),
                m,
                "checkpoint replays the exact forwarded message"
            );
            assert_eq!(db.len(), 0, "checkpoints are not terminal entries");
        }
        assert!(ep.recv().is_some());
    }

    #[test]
    fn batch_lands_on_one_ring_and_advances_rr_once() {
        let fabric = Fabric::ideal();
        let mut ep1 = RdmaEndpoint::new(&fabric, RingConfig::default());
        let mut ep2 = RdmaEndpoint::new(&fabric, RingConfig::default());
        let mut rd = ResultDeliver::new(fabric.clone(), vec![]);
        rd.set_routes(vec![(
            AppId(1),
            vec![
                NextHop::Instance(ep1.region_id()),
                NextHop::Instance(ep2.region_id()),
            ],
        )]);
        let batch: Vec<WorkflowMessage> = (0..4).map(msg).collect();
        let deliveries = rd.deliver_batch(&batch);
        assert_eq!(deliveries.len(), 4);
        assert!(deliveries
            .iter()
            .all(|d| *d == Delivery::Sent(ep1.region_id())));
        let mut n1 = 0;
        while ep1.recv().is_some() {
            n1 += 1;
        }
        assert_eq!(n1, 4, "the whole batch stays together (re-batchable downstream)");
        // The counter advanced once for the batch, so the *next* batch
        // round-robins to the sibling ring.
        assert!(rd
            .deliver_batch(&[msg(9)])
            .iter()
            .all(|d| *d == Delivery::Sent(ep2.region_id())));
        assert!(ep2.recv().is_some());
        assert_eq!(rd.counts(), (5, 0));
    }

    #[test]
    fn same_hop_batch_is_one_ring_lock_acquisition() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        let reg = crate::metrics::Registry::new();
        let mut rd = ResultDeliver::new(fabric.clone(), vec![]);
        rd.set_metrics(crate::transport::RingMetrics::from_registry(&reg));
        rd.set_routes(vec![(AppId(1), vec![NextHop::Instance(ep.region_id())])]);
        let batch: Vec<WorkflowMessage> = (0..6).map(msg).collect();
        assert!(rd.deliver_batch(&batch).iter().all(|d| d.ok()));
        assert_eq!(
            reg.counter("ring_pushes_total").get(),
            1,
            "an n-member same-hop batch is exactly one ring lock acquisition"
        );
        assert_eq!(reg.counter("ring_messages_total").get(), 6);
        assert!(reg.counter("ring_verbs_total").get() >= 6);
        // A batch of one goes through the same path as a single push.
        assert!(rd.deliver_batch(&[msg(9)]).iter().all(|d| d.ok()));
        assert_eq!(reg.counter("ring_pushes_total").get(), 2);
        let mut n = 0;
        while ep.recv().is_some() {
            n += 1;
        }
        assert_eq!(n, 7, "every member delivered");
    }

    #[test]
    fn batch_checkpoints_every_member() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        let clock = Arc::new(ManualClock::new());
        let db = Arc::new(MemDb::new(clock, u64::MAX));
        let mut rd = ResultDeliver::new(fabric.clone(), vec![db.clone()]);
        rd.set_checkpointing(true);
        rd.set_routes(vec![(AppId(1), vec![NextHop::Instance(ep.region_id())])]);
        let batch: Vec<WorkflowMessage> = (0..3).map(msg).collect();
        assert!(rd.deliver_batch(&batch).iter().all(|d| d.ok()));
        for m in &batch {
            let ck = db.checkpoint(m.header.uid).expect("per-UID checkpoint");
            assert_eq!(ck.stage, 1);
            assert_eq!(WorkflowMessage::decode(&ck.data).unwrap(), *m);
            assert!(ep.recv().is_some());
        }
    }

    #[test]
    fn oversized_member_does_not_block_batchmates() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(
            &fabric,
            RingConfig {
                nslots: 16,
                cap_bytes: 256,
                ..Default::default()
            },
        );
        let mut rd = ResultDeliver::new(fabric.clone(), vec![]);
        rd.set_routes(vec![(AppId(1), vec![NextHop::Instance(ep.region_id())])]);
        let mut big = msg(1);
        // Frame larger than the byte ring: permanently unacceptable.
        big.payload = Payload::Bytes(vec![9u8; 512]);
        let batch = vec![msg(0), big, msg(2)];
        let d = rd.deliver_batch(&batch);
        assert_eq!(d[0], Delivery::Sent(ep.region_id()));
        assert_eq!(d[1], Delivery::Dropped, "oversized member drops alone");
        assert_eq!(
            d[2],
            Delivery::Sent(ep.region_id()),
            "trailing batchmate must not be head-of-line blocked"
        );
        assert_eq!(ep.recv().unwrap().header.uid, Uid(0));
        assert_eq!(ep.recv().unwrap().header.uid, Uid(2));
        assert!(ep.recv().is_none());
        assert_eq!(rd.counts(), (2, 1));
    }

    #[test]
    fn batch_without_routes_drops_each_member() {
        let fabric = Fabric::ideal();
        let mut rd = ResultDeliver::new(fabric, vec![]);
        let batch: Vec<WorkflowMessage> = (0..2).map(msg).collect();
        assert!(rd
            .deliver_batch(&batch)
            .iter()
            .all(|d| *d == Delivery::Dropped));
        assert_eq!(rd.counts(), (0, 2));
    }

    #[test]
    fn no_hops_drops() {
        let fabric = Fabric::ideal();
        let mut rd = ResultDeliver::new(fabric, vec![]);
        assert_eq!(rd.deliver(&msg(0)), Delivery::Dropped);
        assert_eq!(rd.counts(), (0, 1));
    }

    #[test]
    fn per_app_routing_shared_instance() {
        // An instance shared by two workflows (§8.3) routes by app id.
        let fabric = Fabric::ideal();
        let mut ep_a = RdmaEndpoint::new(&fabric, RingConfig::default());
        let clock = Arc::new(ManualClock::new());
        let db = Arc::new(MemDb::new(clock, u64::MAX));
        let mut rd = ResultDeliver::new(fabric.clone(), vec![db.clone()]);
        rd.set_routes(vec![
            (AppId(1), vec![NextHop::Instance(ep_a.region_id())]),
            (AppId(2), vec![NextHop::Database]),
        ]);
        let mut m1 = msg(1);
        m1.header.app = AppId(1);
        let mut m2 = msg(2);
        m2.header.app = AppId(2);
        assert!(rd.deliver(&m1).ok());
        assert!(rd.deliver(&m2).ok());
        assert_eq!(ep_a.recv().unwrap().header.uid, m1.header.uid);
        assert!(db.fetch(m2.header.uid).is_some());
    }
}
