//! The workflow instance runtime: wires TaskManager + RS + TaskWorkers +
//! RD into a thread group around one RDMA ring endpoint.
//!
//! Thread layout per instance:
//! - **control** (TaskManager): polls the [`ControlPlane`] for assignment
//!   changes, reconfigures the queue / executor binding / RD hops,
//!   reports windowed utilization.
//! - **rs** (RequestScheduler): drains the ring buffer into the
//!   [`SchedQueue`] per the active mode, tagging each arrival with its
//!   [`crate::client::Priority`] from the set's
//!   [`crate::client::RequestTracker`], and dropping messages whose
//!   request was cancelled or whose deadline already passed (publishing
//!   a tombstone instead).
//! - **worker-i** (TaskWorkers): fetch → SLO check → execute app logic →
//!   SLO re-check → deliver. The re-check drops results whose deadline
//!   expired *during* execution — stage work past its deadline never
//!   reaches the next ring.
//!
//! In Collaboration Mode every worker executes the broadcast request (the
//! TP/PP ranks of §4.4) but only worker 0 delivers the aggregated result
//! (§4.5: "partial results from all workers are aggregated into a single
//! consolidated output before delivery").

use super::{Assignment, ControlPlane, Delivery, ResultDeliver, SchedQueue, StageRole};
use crate::client::{InFlightVerdict, RequestTracker};
use crate::config::SchedMode;
use crate::db::{EntryKind, MemDb};
use crate::metrics::UtilizationWindow;
use crate::rdma::{Fabric, RegionId};
use crate::ringbuf::RingConfig;
use crate::runtime::{ExecutorPool, StageExecutor};
use crate::transport::{RdmaEndpoint, StageId, WorkflowMessage};
use crate::util::{Clock, NodeId, Uid};
use crate::workflow::AppLogic;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// Instance construction parameters.
pub struct InstanceConfig {
    pub node: NodeId,
    pub ring: RingConfig,
    /// TaskManager poll period.
    pub control_poll: Duration,
    /// Utilization window for NM reporting.
    pub util_window: Duration,
    /// Max workers this instance can spin up (threads are created up
    /// front; the assignment's `workers` count activates a subset).
    pub max_workers: usize,
    /// Write per-hop recovery checkpoints (the wset enables this only
    /// when `nm.instance_timeout_ms` turns the failure detector on —
    /// without it nothing ever replays them, so the default is off,
    /// mirroring the detector's own default).
    pub checkpointing: bool,
}

impl Default for InstanceConfig {
    fn default() -> Self {
        Self {
            node: NodeId(0),
            ring: RingConfig::default(),
            control_poll: Duration::from_millis(5),
            util_window: Duration::from_millis(500),
            max_workers: 4,
            checkpointing: false,
        }
    }
}

/// Live instance statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct InstanceStats {
    pub processed: u64,
    pub delivered: u64,
    pub dropped: u64,
    pub errors: u64,
    /// In-flight work dropped by the SLO checks (cancelled requests and
    /// deadline-expired stage work).
    pub sla_dropped: u64,
}

/// How many 1 ms park-and-requeue rounds a message may spend on a
/// roleless instance before it is declared lost. The promotion race this
/// protects against (a recovery replay lands before the control thread
/// applies the new assignment) resolves within one or two control polls
/// (~5 ms); 100 rounds is a generous bound that still terminates stray
/// traffic to a persistently idle instance.
const MAX_ROLELESS_REQUEUES: u32 = 100;

/// Backstop bound on the parked-message counter map (entries for
/// messages that vanished mid-park, e.g. a queue reconfigure, would
/// otherwise accumulate).
const MAX_PARKED_ENTRIES: usize = 4096;

struct Shared {
    node: NodeId,
    queue: Arc<SchedQueue>,
    role: RwLock<Option<StageRole>>,
    version: AtomicU64,
    executor: RwLock<Option<StageExecutor>>,
    deliver: Mutex<ResultDeliver>,
    tracker: Arc<RequestTracker>,
    util: UtilizationWindow,
    /// Requeue counts for messages parked while the instance has no
    /// role (shared across workers so the patience bound does not
    /// multiply by worker count).
    parked: Mutex<std::collections::HashMap<Uid, u32>>,
    /// The set runs a recovery sweep (mirrors `checkpointing`): messages
    /// the data plane cannot progress are handed to it for checkpoint
    /// replay instead of being failed outright.
    recovery_enabled: bool,
    shutdown: AtomicBool,
    /// Crash injection (chaos testing): when set, every thread goes
    /// dormant — no heartbeats, no ring drains, no stage work — exactly
    /// as if the process died, but still joinable on shutdown.
    crashed: Arc<AtomicBool>,
    processed: AtomicU64,
    errors: AtomicU64,
    sla_dropped: AtomicU64,
}

impl Shared {
    /// Drop a request the control plane declared dead: publish the
    /// matching tombstone and count it. The tracker entry is
    /// deliberately **kept**: in Collaboration Mode the other ranks
    /// still hold broadcast copies and must see the same verdict, and a
    /// cancelled UID must keep dropping late-arriving messages. The
    /// entry is released when the client's handle consumes the
    /// tombstone, or by the housekeeper's tracker sweep.
    fn drop_for(&self, uid: Uid, verdict: InFlightVerdict) {
        let kind = match verdict {
            InFlightVerdict::Cancelled => EntryKind::Cancelled,
            InFlightVerdict::DeadlineExceeded => EntryKind::DeadlineExceeded,
            InFlightVerdict::Failed => EntryKind::Failed,
            InFlightVerdict::Proceed => return,
        };
        self.deliver.lock().unwrap().tombstone(uid, kind);
        self.sla_dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// Declare `uid` lost (no downstream capacity, or stranded on a
    /// roleless instance) — a case the recovery sweep can never reach
    /// because this instance's ring owner is alive. Tracked requests get
    /// a terminal `Failed` tombstone; an already-cancelled or
    /// deadline-expired request keeps its own verdict (and tombstone
    /// kind); untracked messages keep the paper's silent-drop semantics.
    fn fail_for(&self, uid: Uid) {
        match self.tracker.verdict(uid) {
            InFlightVerdict::Proceed => {
                if self.tracker.mark_failed(uid) {
                    self.deliver.lock().unwrap().tombstone(uid, EntryKind::Failed);
                }
            }
            verdict => self.drop_for(uid, verdict),
        }
    }

    /// A message the data plane cannot progress (role changed mid-queue
    /// during a donor steal, persistently roleless, downstream refused):
    /// hand the request to the recovery sweep for a checkpoint replay
    /// when the subsystem is on — these requests can still complete —
    /// else fail it terminally rather than strand the client.
    fn strand_or_fail(&self, uid: Uid) {
        if self.recovery_enabled && self.tracker.strand(uid) {
            return; // the sweep replays it from its checkpoint
        }
        self.fail_for(uid);
    }
}

/// Remote-control switch for crash injection: lets the set's chaos
/// driver (housekeeper) kill an instance it does not own. Cloneable and
/// cheap; killing is idempotent.
#[derive(Clone)]
pub struct CrashHandle {
    crashed: Arc<AtomicBool>,
}

impl CrashHandle {
    /// Simulate an instance crash: all threads go dormant immediately.
    pub fn kill(&self) {
        self.crashed.store(true, Ordering::SeqCst);
    }

    /// True once the instance was killed.
    pub fn is_crashed(&self) -> bool {
        self.crashed.load(Ordering::SeqCst)
    }
}

/// A running workflow instance.
pub struct Instance {
    shared: Arc<Shared>,
    region_id: RegionId,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Instance {
    /// Spawn the instance's thread group.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        cfg: InstanceConfig,
        fabric: &Fabric,
        control: Arc<dyn ControlPlane>,
        logic: Arc<dyn AppLogic>,
        pool: ExecutorPool,
        dbs: Vec<Arc<MemDb>>,
        tracker: Arc<RequestTracker>,
        clock: Arc<dyn Clock>,
    ) -> Self {
        let mut endpoint = RdmaEndpoint::new(fabric, cfg.ring);
        let region_id = endpoint.region_id();
        let queue = SchedQueue::new(SchedMode::Individual, cfg.max_workers);
        let mut rd = ResultDeliver::new(fabric.clone(), dbs);
        rd.set_checkpointing(cfg.checkpointing);
        let shared = Arc::new(Shared {
            node: cfg.node,
            queue: queue.clone(),
            role: RwLock::new(None),
            version: AtomicU64::new(u64::MAX),
            executor: RwLock::new(None),
            deliver: Mutex::new(rd),
            tracker,
            util: UtilizationWindow::new(clock, cfg.util_window.as_nanos() as u64),
            parked: Mutex::new(std::collections::HashMap::new()),
            recovery_enabled: cfg.checkpointing,
            shutdown: AtomicBool::new(false),
            crashed: Arc::new(AtomicBool::new(false)),
            processed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            sla_dropped: AtomicU64::new(0),
        });

        let mut threads = Vec::new();

        // --- control thread (TaskManager) ---
        {
            let shared = shared.clone();
            let pool = pool.clone();
            let poll = cfg.control_poll;
            threads.push(std::thread::spawn(move || {
                while !shared.shutdown.load(Ordering::SeqCst) {
                    // A crashed instance stops heartbeating (the
                    // utilization report doubles as liveness, §8.2) —
                    // this is what the NM's failure detector observes.
                    if shared.crashed.load(Ordering::SeqCst) {
                        std::thread::sleep(poll);
                        continue;
                    }
                    let a: Assignment = control.get_assignment(shared.node);
                    if a.version != shared.version.load(Ordering::SeqCst) {
                        Self::apply_assignment(&shared, &pool, &a);
                        shared.version.store(a.version, Ordering::SeqCst);
                    }
                    control.report_utilization(shared.node, shared.util.value());
                    std::thread::sleep(poll);
                }
            }));
        }

        // --- RS thread ---
        {
            let shared = shared.clone();
            threads.push(std::thread::spawn(move || {
                while !shared.shutdown.load(Ordering::SeqCst) {
                    if shared.crashed.load(Ordering::SeqCst) {
                        // Crashed: the ring fills and messages strand —
                        // the recovery sweep replays them elsewhere.
                        std::thread::sleep(Duration::from_millis(5));
                        continue;
                    }
                    match endpoint.recv() {
                        Some(msg) => {
                            let uid = msg.header.uid;
                            match shared.tracker.verdict(uid) {
                                InFlightVerdict::Proceed => {
                                    let prio = shared.tracker.priority_of(uid);
                                    shared.queue.dispatch(msg, prio);
                                }
                                // Cancelled / past-deadline arrivals never
                                // reach a worker.
                                verdict => shared.drop_for(uid, verdict),
                            }
                        }
                        None => std::thread::sleep(Duration::from_micros(100)),
                    }
                }
            }));
        }

        // --- worker threads ---
        for widx in 0..cfg.max_workers {
            let shared = shared.clone();
            let logic = logic.clone();
            threads.push(std::thread::spawn(move || {
                Self::worker_loop(&shared, &*logic, widx);
            }));
        }

        Self { shared, region_id, threads }
    }

    fn apply_assignment(shared: &Arc<Shared>, pool: &ExecutorPool, a: &Assignment) {
        match &a.role {
            Some(role) => {
                let exec = pool.get(&role.stage_name).cloned();
                *shared.executor.write().unwrap() = exec;
                // A mode/shape change drains the queue; strand the
                // displaced work for the recovery sweep (route-only
                // updates preserve it — see SchedQueue::reconfigure).
                for m in shared.queue.reconfigure(role.mode, role.workers) {
                    shared.strand_or_fail(m.header.uid);
                }
                shared
                    .deliver
                    .lock()
                    .unwrap()
                    .set_routes(role.routes.clone());
                *shared.role.write().unwrap() = Some(role.clone());
            }
            None => {
                // Parked in the idle pool (§8.2): no executor, no hops.
                // Strand pending work (one copy per request — CM
                // broadcast copies are deduplicated) so it reaches the
                // recovery path instead of circulating, and normalize
                // the queue so later stray arrivals hold single copies.
                *shared.executor.write().unwrap() = None;
                *shared.role.write().unwrap() = None;
                for m in shared.queue.drain_pending() {
                    shared.strand_or_fail(m.header.uid);
                }
                let _ = shared.queue.reconfigure(SchedMode::Individual, 1);
            }
        }
    }

    fn worker_loop(shared: &Arc<Shared>, logic: &dyn AppLogic, widx: usize) {
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            if shared.crashed.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            let Some(msg) = shared.queue.fetch(widx, Duration::from_millis(20)) else {
                continue;
            };
            let (role, exec) = {
                let r = shared.role.read().unwrap();
                let e = shared.executor.read().unwrap();
                match (r.clone(), e.clone()) {
                    (Some(r), Some(e)) => (r, e),
                    _ => {
                        // No role (yet): the control thread may be
                        // mid-apply of a promotion and recovery replays
                        // race it — park the message back instead of
                        // dropping it, up to a patience bound. In CM the
                        // queue holds one broadcast copy per worker and
                        // a re-dispatch would re-broadcast: only rank 0
                        // parks its copy, siblings drop theirs.
                        if shared.queue.mode() == SchedMode::Collaboration && widx != 0
                        {
                            continue;
                        }
                        let uid = msg.header.uid;
                        let exhausted = {
                            let mut parked = shared.parked.lock().unwrap();
                            if parked.len() > MAX_PARKED_ENTRIES {
                                parked.clear();
                            }
                            let n = parked.entry(uid).or_insert(0);
                            *n += 1;
                            let exhausted = *n > MAX_ROLELESS_REQUEUES;
                            if exhausted {
                                parked.remove(&uid);
                            }
                            exhausted
                        };
                        if exhausted {
                            // Persistently roleless: the message will
                            // never execute here — hand it to the
                            // recovery sweep (or fail terminally).
                            shared.strand_or_fail(uid);
                            continue;
                        }
                        std::thread::sleep(Duration::from_millis(1));
                        let prio = shared.tracker.priority_of(uid);
                        shared.queue.dispatch(msg, prio);
                        continue;
                    }
                }
            };
            {
                let mut parked = shared.parked.lock().unwrap();
                if !parked.is_empty() {
                    parked.remove(&msg.header.uid);
                }
            }
            // In CM every worker holds a broadcast copy; rank 0 is the
            // one that delivers, so it alone accounts SLO drops and
            // strands displaced work.
            let lead = role.mode != SchedMode::Collaboration || widx == 0;
            let uid = msg.header.uid;
            // Stage sanity: a message that survived an idle-parking
            // requeue (or drained into a donor-stolen instance) must not
            // execute under a different stage role — its request can
            // still complete via a checkpoint replay (routine donor
            // steals must not turn into request failures), so strand it
            // for the recovery sweep rather than computing garbage.
            // Applies to every app the role serves: shared apps alias at
            // the same stage index (§8.3 `share_stage` usage — the
            // worker stamps `role.stage_index + 1` on every output, so
            // same-index aliasing is already a standing assumption), and
            // a message for an app with no route here could never be
            // delivered after execution anyway.
            let served = msg.header.app == role.app
                || role.routes.iter().any(|(a, _)| *a == msg.header.app);
            if !served || msg.header.stage.0 != role.stage_index {
                if lead {
                    shared.strand_or_fail(uid);
                }
                continue;
            }
            // SLO check before spending compute (the request may have
            // been cancelled / expired while queued).
            match shared.tracker.verdict(uid) {
                InFlightVerdict::Proceed => {}
                verdict => {
                    if lead {
                        shared.drop_for(uid, verdict);
                    }
                    continue;
                }
            }
            shared.tracker.note_stage(uid, role.stage_index);
            shared.util.busy();
            let result = logic.execute(&role.stage_name, &exec, &msg);
            shared.util.idle();
            match result {
                Ok(payload) => {
                    // A crash that fired mid-execution kills the output
                    // too — a dead process delivers nothing.
                    if shared.crashed.load(Ordering::SeqCst) {
                        continue;
                    }
                    shared.processed.fetch_add(1, Ordering::Relaxed);
                    // CM: all workers computed (TP ranks); rank 0 delivers
                    // the aggregated output.
                    if !lead {
                        continue;
                    }
                    // SLO re-check: the deadline may have expired during
                    // execution — drop the stage output instead of
                    // forwarding work that can no longer meet its SLO.
                    match shared.tracker.verdict(uid) {
                        InFlightVerdict::Proceed => {}
                        verdict => {
                            shared.drop_for(uid, verdict);
                            continue;
                        }
                    }
                    let out = WorkflowMessage {
                        header: crate::transport::MessageHeader {
                            stage: StageId(role.stage_index + 1),
                            ..msg.header
                        },
                        payload,
                    };
                    let delivery = shared.deliver.lock().unwrap().deliver(&out);
                    match delivery {
                        // Tell the control plane where the request went
                        // — if that instance dies, the recovery sweep
                        // finds the request by this location.
                        Delivery::Sent(region) => {
                            shared.tracker.note_location(uid, region)
                        }
                        Delivery::Stored => {}
                        Delivery::Dropped => {
                            // No downstream capacity (the next stage
                            // lost every instance, or its ring refused
                            // the write). A transient full ring can
                            // still clear — strand for a checkpoint
                            // replay; otherwise a terminal tombstone
                            // beats a silent §9 loss the client would
                            // wait out.
                            shared.strand_or_fail(uid);
                        }
                    }
                }
                Err(_) => {
                    shared.errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// The instance's inbox ring region (senders route here).
    pub fn region_id(&self) -> RegionId {
        self.region_id
    }

    /// Node id.
    pub fn node(&self) -> NodeId {
        self.shared.node
    }

    /// Windowed utilization (what the TaskManager reports to the NM).
    pub fn utilization(&self) -> f64 {
        self.shared.util.value()
    }

    /// Crash injection: simulate this instance dying. All threads go
    /// dormant (no heartbeats, no ring drains, no stage work); the NM's
    /// failure detector notices the missing utilization reports and the
    /// recovery sweep repairs routing and replays stranded requests.
    pub fn inject_crash(&self) {
        self.shared.crashed.store(true, Ordering::SeqCst);
    }

    /// True once [`Instance::inject_crash`] (or a [`CrashHandle`]) fired.
    pub fn is_crashed(&self) -> bool {
        self.shared.crashed.load(Ordering::SeqCst)
    }

    /// Remote-control switch for the set's chaos driver.
    pub fn crash_handle(&self) -> CrashHandle {
        CrashHandle { crashed: self.shared.crashed.clone() }
    }

    /// Stats snapshot.
    pub fn stats(&self) -> InstanceStats {
        let (delivered, dropped) = self.shared.deliver.lock().unwrap().counts();
        InstanceStats {
            processed: self.shared.processed.load(Ordering::Relaxed),
            delivered,
            dropped,
            errors: self.shared.errors.load(Ordering::Relaxed),
            sla_dropped: self.shared.sla_dropped.load(Ordering::Relaxed),
        }
    }

    /// Stop all threads and join.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::Priority;
    use crate::metrics::Registry;
    use crate::transport::{AppId, MessageHeader, Payload};
    use crate::util::{SystemClock, Uid};
    use crate::workflow::{EchoLogic, NextHop};

    /// Static control plane for tests.
    struct FixedControl(Assignment);

    impl ControlPlane for FixedControl {
        fn get_assignment(&self, _node: NodeId) -> Assignment {
            self.0.clone()
        }
        fn report_utilization(&self, _node: NodeId, _util: f64) {}
    }

    fn mk_msg(i: u32, stage: u32) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(i as u128),
                ts_ns: 0,
                app: AppId(1),
                stage: StageId(stage),
                origin: NodeId(0),
            },
            payload: Payload::Bytes(vec![i as u8; 8]),
        }
    }

    fn mk_tracker(clock: &Arc<dyn Clock>) -> Arc<RequestTracker> {
        Arc::new(RequestTracker::new(clock.clone(), Registry::new()))
    }

    fn echo_assignment() -> Assignment {
        Assignment {
            version: 1,
            role: Some(StageRole {
                app: AppId(1),
                stage_index: 0,
                stage_name: "echo".into(),
                mode: SchedMode::Individual,
                workers: 2,
                routes: vec![(AppId(1), vec![NextHop::Database])],
            }),
        }
    }

    #[test]
    fn instance_processes_and_stores() {
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let db = Arc::new(MemDb::new(clock.clone(), u64::MAX));
        let mut pool = ExecutorPool::new();
        pool.insert("echo", StageExecutor::Simulated { busy: Duration::from_micros(50) });

        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(1), ..Default::default() },
            &fabric,
            Arc::new(FixedControl(echo_assignment())),
            Arc::new(EchoLogic),
            pool,
            vec![db.clone()],
            mk_tracker(&clock),
            clock,
        );

        // Wait for the control thread to apply the assignment, then feed
        // requests through the ring.
        std::thread::sleep(Duration::from_millis(50));
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id());
        for i in 0..5 {
            assert!(tx.send(&mk_msg(i, 0)));
        }

        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while db.len() < 5 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(db.len(), 5, "all results stored");
        // Delivered messages carry the advanced stage id.
        let stored = db.fetch(Uid(0)).unwrap();
        let m = WorkflowMessage::decode(&stored).unwrap();
        assert_eq!(m.header.stage, StageId(1));
        let stats = inst.stats();
        assert_eq!(stats.processed, 5);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.sla_dropped, 0);
        inst.shutdown();
    }

    #[test]
    fn idle_instance_ignores_traffic() {
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(2), ..Default::default() },
            &fabric,
            Arc::new(FixedControl(Assignment { version: 1, role: None })),
            Arc::new(EchoLogic),
            ExecutorPool::new(),
            vec![],
            mk_tracker(&clock),
            clock,
        );
        std::thread::sleep(Duration::from_millis(30));
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id());
        tx.send(&mk_msg(1, 0));
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(inst.stats().processed, 0);
        inst.shutdown();
    }

    #[test]
    fn crashed_instance_goes_dormant_but_shuts_down() {
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let db = Arc::new(MemDb::new(clock.clone(), u64::MAX));
        let mut pool = ExecutorPool::new();
        pool.insert("echo", StageExecutor::Simulated { busy: Duration::ZERO });
        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(4), ..Default::default() },
            &fabric,
            Arc::new(FixedControl(echo_assignment())),
            Arc::new(EchoLogic),
            pool,
            vec![db.clone()],
            mk_tracker(&clock),
            clock,
        );
        std::thread::sleep(Duration::from_millis(50));
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id());
        assert!(tx.send(&mk_msg(1, 0)));
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while inst.stats().processed < 1 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(inst.stats().processed, 1);

        let handle = inst.crash_handle();
        handle.kill();
        assert!(handle.is_crashed() && inst.is_crashed());
        // Messages after the crash strand in the ring: no processing, no
        // stores — exactly a dead process, but still joinable.
        assert!(tx.send(&mk_msg(2, 0)));
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(inst.stats().processed, 1, "crashed instance does no work");
        assert_eq!(db.len(), 1);
        inst.shutdown();
    }

    #[test]
    fn cancelled_request_is_dropped_with_tombstone() {
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let db = Arc::new(MemDb::new(clock.clone(), u64::MAX));
        let mut pool = ExecutorPool::new();
        pool.insert("echo", StageExecutor::Simulated { busy: Duration::ZERO });
        let tracker = mk_tracker(&clock);

        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(3), ..Default::default() },
            &fabric,
            Arc::new(FixedControl(echo_assignment())),
            Arc::new(EchoLogic),
            pool,
            vec![db.clone()],
            tracker.clone(),
            clock,
        );
        std::thread::sleep(Duration::from_millis(50));

        // Register + cancel BEFORE the message arrives: the RS drop path.
        let m = mk_msg(9, 0);
        tracker.register(m.header.uid, Priority::Standard, None);
        tracker.cancel(m.header.uid);
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id());
        assert!(tx.send(&m));

        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while inst.stats().sla_dropped < 1 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(inst.stats().sla_dropped, 1);
        assert_eq!(inst.stats().processed, 0, "no compute spent on cancelled work");
        assert_eq!(
            db.fetch_entry(m.header.uid),
            Some((EntryKind::Cancelled, vec![])),
            "tombstone published instead of a result"
        );
        // The entry stays so late copies (CM ranks, delayed ring writes)
        // keep dropping; the handle or the housekeeper sweep removes it.
        assert_eq!(
            tracker.verdict(m.header.uid),
            InFlightVerdict::Cancelled,
            "late copies of a dropped request must still drop"
        );
        inst.shutdown();
    }
}
