//! The workflow instance runtime: wires TaskManager + RS + TaskWorkers +
//! RD into a thread group around one RDMA ring endpoint.
//!
//! Thread layout per instance:
//! - **control** (TaskManager): polls the [`ControlPlane`] for assignment
//!   changes, reconfigures the queue / executor binding / RD hops,
//!   reports windowed utilization.
//! - **rs** (RequestScheduler): drains the ring buffer into the
//!   [`SchedQueue`] per the active mode, tagging each arrival with its
//!   [`crate::client::Priority`] from the set's
//!   [`crate::client::RequestTracker`], and dropping messages whose
//!   request was cancelled or whose deadline already passed (publishing
//!   a tombstone instead).
//! - **worker-i** (TaskWorkers): fetch → SLO check → execute app logic →
//!   SLO re-check → deliver. The re-check drops results whose deadline
//!   expired *during* execution — stage work past its deadline never
//!   reaches the next ring.
//!
//! In Collaboration Mode every worker executes the broadcast request (the
//! TP/PP ranks of §4.4) but only worker 0 delivers the aggregated result
//! (§4.5: "partial results from all workers are aggregated into a single
//! consolidated output before delivery").

use super::{Assignment, ControlPlane, ResultDeliver, SchedQueue, StageRole};
use crate::client::{InFlightVerdict, RequestTracker};
use crate::config::SchedMode;
use crate::db::{EntryKind, MemDb};
use crate::metrics::UtilizationWindow;
use crate::rdma::{Fabric, RegionId};
use crate::ringbuf::RingConfig;
use crate::runtime::{ExecutorPool, StageExecutor};
use crate::transport::{RdmaEndpoint, StageId, WorkflowMessage};
use crate::util::{Clock, NodeId, Uid};
use crate::workflow::AppLogic;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// Instance construction parameters.
pub struct InstanceConfig {
    pub node: NodeId,
    pub ring: RingConfig,
    /// TaskManager poll period.
    pub control_poll: Duration,
    /// Utilization window for NM reporting.
    pub util_window: Duration,
    /// Max workers this instance can spin up (threads are created up
    /// front; the assignment's `workers` count activates a subset).
    pub max_workers: usize,
}

impl Default for InstanceConfig {
    fn default() -> Self {
        Self {
            node: NodeId(0),
            ring: RingConfig::default(),
            control_poll: Duration::from_millis(5),
            util_window: Duration::from_millis(500),
            max_workers: 4,
        }
    }
}

/// Live instance statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct InstanceStats {
    pub processed: u64,
    pub delivered: u64,
    pub dropped: u64,
    pub errors: u64,
    /// In-flight work dropped by the SLO checks (cancelled requests and
    /// deadline-expired stage work).
    pub sla_dropped: u64,
}

struct Shared {
    node: NodeId,
    queue: Arc<SchedQueue>,
    role: RwLock<Option<StageRole>>,
    version: AtomicU64,
    executor: RwLock<Option<StageExecutor>>,
    deliver: Mutex<ResultDeliver>,
    tracker: Arc<RequestTracker>,
    util: UtilizationWindow,
    shutdown: AtomicBool,
    processed: AtomicU64,
    errors: AtomicU64,
    sla_dropped: AtomicU64,
}

impl Shared {
    /// Drop a request the control plane declared dead: publish the
    /// matching tombstone and count it. The tracker entry is
    /// deliberately **kept**: in Collaboration Mode the other ranks
    /// still hold broadcast copies and must see the same verdict, and a
    /// cancelled UID must keep dropping late-arriving messages. The
    /// entry is released when the client's handle consumes the
    /// tombstone, or by the housekeeper's tracker sweep.
    fn drop_for(&self, uid: Uid, verdict: InFlightVerdict) {
        let kind = match verdict {
            InFlightVerdict::Cancelled => EntryKind::Cancelled,
            InFlightVerdict::DeadlineExceeded => EntryKind::DeadlineExceeded,
            InFlightVerdict::Proceed => return,
        };
        self.deliver.lock().unwrap().tombstone(uid, kind);
        self.sla_dropped.fetch_add(1, Ordering::Relaxed);
    }
}

/// A running workflow instance.
pub struct Instance {
    shared: Arc<Shared>,
    region_id: RegionId,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Instance {
    /// Spawn the instance's thread group.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        cfg: InstanceConfig,
        fabric: &Fabric,
        control: Arc<dyn ControlPlane>,
        logic: Arc<dyn AppLogic>,
        pool: ExecutorPool,
        dbs: Vec<Arc<MemDb>>,
        tracker: Arc<RequestTracker>,
        clock: Arc<dyn Clock>,
    ) -> Self {
        let mut endpoint = RdmaEndpoint::new(fabric, cfg.ring);
        let region_id = endpoint.region_id();
        let queue = SchedQueue::new(SchedMode::Individual, cfg.max_workers);
        let shared = Arc::new(Shared {
            node: cfg.node,
            queue: queue.clone(),
            role: RwLock::new(None),
            version: AtomicU64::new(u64::MAX),
            executor: RwLock::new(None),
            deliver: Mutex::new(ResultDeliver::new(fabric.clone(), dbs)),
            tracker,
            util: UtilizationWindow::new(clock, cfg.util_window.as_nanos() as u64),
            shutdown: AtomicBool::new(false),
            processed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            sla_dropped: AtomicU64::new(0),
        });

        let mut threads = Vec::new();

        // --- control thread (TaskManager) ---
        {
            let shared = shared.clone();
            let pool = pool.clone();
            let poll = cfg.control_poll;
            threads.push(std::thread::spawn(move || {
                while !shared.shutdown.load(Ordering::SeqCst) {
                    let a: Assignment = control.get_assignment(shared.node);
                    if a.version != shared.version.load(Ordering::SeqCst) {
                        Self::apply_assignment(&shared, &pool, &a);
                        shared.version.store(a.version, Ordering::SeqCst);
                    }
                    control.report_utilization(shared.node, shared.util.value());
                    std::thread::sleep(poll);
                }
            }));
        }

        // --- RS thread ---
        {
            let shared = shared.clone();
            threads.push(std::thread::spawn(move || {
                while !shared.shutdown.load(Ordering::SeqCst) {
                    match endpoint.recv() {
                        Some(msg) => {
                            let uid = msg.header.uid;
                            match shared.tracker.verdict(uid) {
                                InFlightVerdict::Proceed => {
                                    let prio = shared.tracker.priority_of(uid);
                                    shared.queue.dispatch(msg, prio);
                                }
                                // Cancelled / past-deadline arrivals never
                                // reach a worker.
                                verdict => shared.drop_for(uid, verdict),
                            }
                        }
                        None => std::thread::sleep(Duration::from_micros(100)),
                    }
                }
            }));
        }

        // --- worker threads ---
        for widx in 0..cfg.max_workers {
            let shared = shared.clone();
            let logic = logic.clone();
            threads.push(std::thread::spawn(move || {
                Self::worker_loop(&shared, &*logic, widx);
            }));
        }

        Self { shared, region_id, threads }
    }

    fn apply_assignment(shared: &Arc<Shared>, pool: &ExecutorPool, a: &Assignment) {
        match &a.role {
            Some(role) => {
                let exec = pool.get(&role.stage_name).cloned();
                *shared.executor.write().unwrap() = exec;
                shared.queue.reconfigure(role.mode, role.workers);
                shared
                    .deliver
                    .lock()
                    .unwrap()
                    .set_routes(role.routes.clone());
                *shared.role.write().unwrap() = Some(role.clone());
            }
            None => {
                // Parked in the idle pool (§8.2): no executor, no hops.
                *shared.executor.write().unwrap() = None;
                *shared.role.write().unwrap() = None;
            }
        }
    }

    fn worker_loop(shared: &Arc<Shared>, logic: &dyn AppLogic, widx: usize) {
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let Some(msg) = shared.queue.fetch(widx, Duration::from_millis(20)) else {
                continue;
            };
            let (role, exec) = {
                let r = shared.role.read().unwrap();
                let e = shared.executor.read().unwrap();
                match (r.clone(), e.clone()) {
                    (Some(r), Some(e)) => (r, e),
                    _ => continue, // reassigned to idle mid-flight: drop
                }
            };
            // In CM every worker holds a broadcast copy; rank 0 is the
            // one that delivers, so it alone accounts SLO drops.
            let lead = role.mode != SchedMode::Collaboration || widx == 0;
            let uid = msg.header.uid;
            // SLO check before spending compute (the request may have
            // been cancelled / expired while queued).
            match shared.tracker.verdict(uid) {
                InFlightVerdict::Proceed => {}
                verdict => {
                    if lead {
                        shared.drop_for(uid, verdict);
                    }
                    continue;
                }
            }
            shared.tracker.note_stage(uid, role.stage_index);
            shared.util.busy();
            let result = logic.execute(&role.stage_name, &exec, &msg);
            shared.util.idle();
            match result {
                Ok(payload) => {
                    shared.processed.fetch_add(1, Ordering::Relaxed);
                    // CM: all workers computed (TP ranks); rank 0 delivers
                    // the aggregated output.
                    if !lead {
                        continue;
                    }
                    // SLO re-check: the deadline may have expired during
                    // execution — drop the stage output instead of
                    // forwarding work that can no longer meet its SLO.
                    match shared.tracker.verdict(uid) {
                        InFlightVerdict::Proceed => {}
                        verdict => {
                            shared.drop_for(uid, verdict);
                            continue;
                        }
                    }
                    let out = WorkflowMessage {
                        header: crate::transport::MessageHeader {
                            stage: StageId(role.stage_index + 1),
                            ..msg.header
                        },
                        payload,
                    };
                    shared.deliver.lock().unwrap().deliver(&out);
                }
                Err(_) => {
                    shared.errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// The instance's inbox ring region (senders route here).
    pub fn region_id(&self) -> RegionId {
        self.region_id
    }

    /// Node id.
    pub fn node(&self) -> NodeId {
        self.shared.node
    }

    /// Windowed utilization (what the TaskManager reports to the NM).
    pub fn utilization(&self) -> f64 {
        self.shared.util.value()
    }

    /// Stats snapshot.
    pub fn stats(&self) -> InstanceStats {
        let (delivered, dropped) = self.shared.deliver.lock().unwrap().counts();
        InstanceStats {
            processed: self.shared.processed.load(Ordering::Relaxed),
            delivered,
            dropped,
            errors: self.shared.errors.load(Ordering::Relaxed),
            sla_dropped: self.shared.sla_dropped.load(Ordering::Relaxed),
        }
    }

    /// Stop all threads and join.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::Priority;
    use crate::metrics::Registry;
    use crate::transport::{AppId, MessageHeader, Payload};
    use crate::util::{SystemClock, Uid};
    use crate::workflow::{EchoLogic, NextHop};

    /// Static control plane for tests.
    struct FixedControl(Assignment);

    impl ControlPlane for FixedControl {
        fn get_assignment(&self, _node: NodeId) -> Assignment {
            self.0.clone()
        }
        fn report_utilization(&self, _node: NodeId, _util: f64) {}
    }

    fn mk_msg(i: u32, stage: u32) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(i as u128),
                ts_ns: 0,
                app: AppId(1),
                stage: StageId(stage),
                origin: NodeId(0),
            },
            payload: Payload::Bytes(vec![i as u8; 8]),
        }
    }

    fn mk_tracker(clock: &Arc<dyn Clock>) -> Arc<RequestTracker> {
        Arc::new(RequestTracker::new(clock.clone(), Registry::new()))
    }

    fn echo_assignment() -> Assignment {
        Assignment {
            version: 1,
            role: Some(StageRole {
                app: AppId(1),
                stage_index: 0,
                stage_name: "echo".into(),
                mode: SchedMode::Individual,
                workers: 2,
                routes: vec![(AppId(1), vec![NextHop::Database])],
            }),
        }
    }

    #[test]
    fn instance_processes_and_stores() {
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let db = Arc::new(MemDb::new(clock.clone(), u64::MAX));
        let mut pool = ExecutorPool::new();
        pool.insert("echo", StageExecutor::Simulated { busy: Duration::from_micros(50) });

        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(1), ..Default::default() },
            &fabric,
            Arc::new(FixedControl(echo_assignment())),
            Arc::new(EchoLogic),
            pool,
            vec![db.clone()],
            mk_tracker(&clock),
            clock,
        );

        // Wait for the control thread to apply the assignment, then feed
        // requests through the ring.
        std::thread::sleep(Duration::from_millis(50));
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id());
        for i in 0..5 {
            assert!(tx.send(&mk_msg(i, 0)));
        }

        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while db.len() < 5 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(db.len(), 5, "all results stored");
        // Delivered messages carry the advanced stage id.
        let stored = db.fetch(Uid(0)).unwrap();
        let m = WorkflowMessage::decode(&stored).unwrap();
        assert_eq!(m.header.stage, StageId(1));
        let stats = inst.stats();
        assert_eq!(stats.processed, 5);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.sla_dropped, 0);
        inst.shutdown();
    }

    #[test]
    fn idle_instance_ignores_traffic() {
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(2), ..Default::default() },
            &fabric,
            Arc::new(FixedControl(Assignment { version: 1, role: None })),
            Arc::new(EchoLogic),
            ExecutorPool::new(),
            vec![],
            mk_tracker(&clock),
            clock,
        );
        std::thread::sleep(Duration::from_millis(30));
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id());
        tx.send(&mk_msg(1, 0));
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(inst.stats().processed, 0);
        inst.shutdown();
    }

    #[test]
    fn cancelled_request_is_dropped_with_tombstone() {
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let db = Arc::new(MemDb::new(clock.clone(), u64::MAX));
        let mut pool = ExecutorPool::new();
        pool.insert("echo", StageExecutor::Simulated { busy: Duration::ZERO });
        let tracker = mk_tracker(&clock);

        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(3), ..Default::default() },
            &fabric,
            Arc::new(FixedControl(echo_assignment())),
            Arc::new(EchoLogic),
            pool,
            vec![db.clone()],
            tracker.clone(),
            clock,
        );
        std::thread::sleep(Duration::from_millis(50));

        // Register + cancel BEFORE the message arrives: the RS drop path.
        let m = mk_msg(9, 0);
        tracker.register(m.header.uid, Priority::Standard, None);
        tracker.cancel(m.header.uid);
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id());
        assert!(tx.send(&m));

        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while inst.stats().sla_dropped < 1 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(inst.stats().sla_dropped, 1);
        assert_eq!(inst.stats().processed, 0, "no compute spent on cancelled work");
        assert_eq!(
            db.fetch_entry(m.header.uid),
            Some((EntryKind::Cancelled, vec![])),
            "tombstone published instead of a result"
        );
        // The entry stays so late copies (CM ranks, delayed ring writes)
        // keep dropping; the handle or the housekeeper sweep removes it.
        assert_eq!(
            tracker.verdict(m.header.uid),
            InFlightVerdict::Cancelled,
            "late copies of a dropped request must still drop"
        );
        inst.shutdown();
    }
}
